"""Repo-root pytest config: make `python/` importable so the suite can be
invoked either as `pytest python/tests/` (from the repo root) or as
`cd python && pytest tests/`."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
