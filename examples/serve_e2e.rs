//! END-TO-END serving driver (see docs/ARCHITECTURE.md): start the full
//! coordinator (XLA engine + continuous batcher + HTTP server) in-process,
//! fire a concurrent batched workload of real infilling requests over HTTP,
//! and report latency/throughput/NFE — the paper's serving claim exercised
//! through every layer (Pallas kernels -> HLO artifact -> PJRT -> decode
//! machines -> batcher -> HTTP).
//!
//!     make artifacts && make models
//!     cargo run --release --example serve_e2e
//!
//! Env: ASARM_E2E_REQS (default 24), ASARM_E2E_CONC (default 6),
//!      ASARM_E2E_REPLICAS (default 2 — engine replicas behind the shared
//!      admission queue; each replica loads its own copy of the model).
//!
//! After the blocking sweep, a streaming leg drives `POST /infill/stream`
//! over a real socket: SSE commit events reassemble to the same text the
//! blocking path returns, and TTFT (first commit) is reported against
//! total latency.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use asarm::coordinator::http::{http_get, http_post, http_post_stream, HttpServer};
use asarm::coordinator::{self, Metrics, SchedulerConfig};
use asarm::data::stories;
use asarm::runtime::PoolConfig;
use asarm::util::json::Json;
use asarm::util::rng::Rng;
use asarm::util::stats::{percentile, Summary};
use asarm::util::threadpool::ThreadPool;

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ckpt = std::path::Path::new(artifacts).join("ckpt_stories_ft.bin");
    if !ckpt.exists() {
        eprintln!("serve_e2e: missing {}; run `make models`", ckpt.display());
        return Ok(());
    }
    let n_reqs: usize = std::env::var("ASARM_E2E_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let conc: usize = std::env::var("ASARM_E2E_CONC")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);
    let replicas: usize = std::env::var("ASARM_E2E_REPLICAS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);

    // --- full stack, in-process ---
    let metrics = Metrics::new();
    let handle = coordinator::start_xla(
        artifacts,
        Some(ckpt),
        PoolConfig { replicas },
        SchedulerConfig {
            max_batch: 4,
            ..Default::default()
        },
        metrics.clone(),
    );
    // keep a scheduler handle for the streaming-leg TTFT measurement
    let sched = handle.clone();
    let server = HttpServer::bind("127.0.0.1:0", handle, metrics.clone(), conc + 2)?;
    let addr = server.serve_background();
    println!("coordinator serving on http://{addr} ({replicas} engine replicas)");

    let (code, body) = http_get(&addr, "/healthz")?;
    anyhow::ensure!(code == 200, "healthz failed: {body}");

    // --- workload: stories with randomly blanked spans, mixed samplers ---
    let mut rng = Rng::new(2024);
    let mut requests = vec![];
    for i in 0..n_reqs {
        // Keep stories within the model window (drop trailing sentences).
        let mut story = stories::story_text(&mut rng);
        while story.len() > 126 {
            match story[..story.len() - 1].rfind('.') {
                Some(p) => story.truncate(p + 1),
                None => story.truncate(126),
            }
        }
        let mut bytes = story.into_bytes();
        // blank a random ~30% span of the story
        let span = bytes.len() * 3 / 10;
        let start = rng.below(bytes.len() - span);
        for b in &mut bytes[start..start + span] {
            if *b != b' ' || rng.below(4) > 0 {
                *b = b'_';
            }
        }
        let text = String::from_utf8_lossy(&bytes).into_owned();
        // Rotate through the drafter sweep: label = sampler/draft combo.
        let (label, sampler, draft_kind, adaptive) = [
            ("assd", "assd", "self", false),
            ("assd_adaptive", "assd", "self", true),
            ("assd_ngram", "assd_ngram", "bigram", false),
            ("assd_lookup", "assd", "lookup", false),
            ("sequential", "sequential", "self", false),
        ][i % 5];
        let body = Json::obj(vec![
            ("text", Json::str(text)),
            ("sampler", Json::str(sampler)),
            (
                "draft",
                Json::obj(vec![
                    ("kind", Json::str(draft_kind)),
                    ("max_len", Json::num(5.0)),
                    ("adaptive", Json::Bool(adaptive)),
                ]),
            ),
            ("seed", Json::num(i as f64)),
        ])
        .to_string();
        requests.push((label.to_string(), body));
    }

    // --- concurrent client load over HTTP ---
    let pool = ThreadPool::new(conc);
    let results: Arc<Mutex<Vec<(String, f64, Json)>>> = Arc::new(Mutex::new(vec![]));
    let t0 = Instant::now();
    let jobs: Vec<_> = requests
        .into_iter()
        .map(|(sampler, body)| {
            let results = Arc::clone(&results);
            move || {
                let t = Instant::now();
                let (code, resp) = http_post(&addr, "/v1/infill", &body).expect("http");
                assert_eq!(code, 200, "bad response: {resp}");
                let j = Json::parse(&resp).expect("json");
                results
                    .lock()
                    .unwrap()
                    .push((sampler, t.elapsed().as_secs_f64(), j));
            }
        })
        .collect();
    pool.scoped_run(jobs);
    let wall = t0.elapsed().as_secs_f64();

    // --- report ---
    let results = results.lock().unwrap();
    let mut total_tokens = 0.0;
    println!("\n=== end-to-end serving results ===");
    for label in [
        "assd",
        "assd_adaptive",
        "assd_ngram",
        "assd_lookup",
        "sequential",
    ] {
        let lat: Vec<f64> = results
            .iter()
            .filter(|(s, _, _)| s == label)
            .map(|(_, l, _)| *l)
            .collect();
        if lat.is_empty() {
            continue;
        }
        let mut nfe = Summary::new();
        let mut accept = Summary::new();
        let mut gen = 0.0;
        for (_, _, j) in results.iter().filter(|(s, _, _)| s == label) {
            nfe.push(j.get("model_nfe").unwrap().as_f64().unwrap());
            accept.push(j.get("acceptance_rate").unwrap().as_f64().unwrap());
            gen += j.get("n_generated").unwrap().as_f64().unwrap();
        }
        total_tokens += gen;
        println!(
            "{label:14} n={:2}  latency p50 {:6.3}s p95 {:6.3}s  model NFE {}  accept {:.3}",
            lat.len(),
            percentile(&lat, 50.0),
            percentile(&lat, 95.0),
            nfe.fmt_pm(),
            accept.mean(),
        );
    }
    println!(
        "\n{} requests in {wall:.2}s  ({:.2} req/s, {:.1} generated tokens/s)",
        results.len(),
        results.len() as f64 / wall,
        total_tokens / wall
    );
    // --- streaming leg: SSE over a real socket -------------------------
    println!("\n=== streaming (POST /infill/stream) ===");
    let stream_body = Json::obj(vec![
        ("text", Json::str("Tom went to the ____ and saw a ____.")),
        ("sampler", Json::str("assd")),
        ("seed", Json::num(7.0)),
    ])
    .to_string();
    // blocking reference first: same request, same seed
    let (code, blocking) = http_post(&addr, "/v1/infill", &stream_body)?;
    anyhow::ensure!(code == 200, "blocking reference failed: {blocking}");
    let blocking_text = Json::parse(&blocking)
        .expect("json")
        .get("text")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    let t0 = Instant::now();
    let resp = http_post_stream(&addr, "/infill/stream", &stream_body)?;
    let total_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(resp.status == 200, "stream failed: {}", resp.body);
    let mut streamed = String::from("Tom went to the ____ and saw a ____.").into_bytes();
    let mut commits = 0usize;
    let mut done_text = String::new();
    for ev in &resp.events {
        let j = Json::parse(&ev.data).expect("event json");
        match ev.event.as_str() {
            "commit" => {
                let ps = j.get("positions").unwrap().as_arr().unwrap();
                let ts = j.get("tokens").unwrap().as_arr().unwrap();
                for (p, t) in ps.iter().zip(ts) {
                    streamed[p.as_usize().unwrap()] = t.as_usize().unwrap() as u8;
                    commits += 1;
                }
            }
            "done" => done_text = j.get("text").unwrap().as_str().unwrap().to_string(),
            other => panic!("unexpected event {other}: {}", ev.data),
        }
    }
    let streamed = String::from_utf8_lossy(&streamed).into_owned();
    anyhow::ensure!(
        streamed == blocking_text && done_text == blocking_text,
        "SSE reassembly diverged from the blocking path:\n  sse      {streamed:?}\n  blocking {blocking_text:?}"
    );
    println!(
        "streamed {commits} tokens over {} events; reassembles to the blocking text exactly",
        resp.events.len()
    );
    // TTFT for THIS workload, measured at the event channel of one more
    // identical request (the /metrics ttft aggregate mixes in the whole
    // blocking sweep above, so it demonstrates nothing by itself).
    {
        use asarm::coordinator::{Event, InfillRequest};
        let t0 = Instant::now();
        let rh = sched
            .submit(InfillRequest {
                text: "Tom went to the ____ and saw a ____.".into(),
                seed: 8,
                ..Default::default()
            })
            .expect("submit");
        let mut ttft_s = None;
        loop {
            match rh.next_event().expect("stream died") {
                Event::Committed { .. } => {
                    ttft_s.get_or_insert_with(|| t0.elapsed().as_secs_f64());
                }
                Event::Done(_) => break,
                Event::Error(e) => panic!("streaming request failed: {e}"),
            }
        }
        let done_s = t0.elapsed().as_secs_f64();
        println!(
            "TTFT {:.1}ms vs total {:.1}ms (same request; SSE leg over the socket took {:.1}ms)",
            ttft_s.expect("no commit before done") * 1e3,
            done_s * 1e3,
            total_s * 1e3
        );
    }

    let (_, m) = http_get(&addr, "/metrics")?;
    println!("\n/metrics: {m}");
    let (_, r) = http_get(&addr, "/replicas")?;
    println!("/replicas: {r}");
    println!("\nE2E OK: all layers composed (Pallas->HLO->PJRT->ASSD->batcher->HTTP+SSE).");
    Ok(())
}
