//! Train a small AS-ARM from scratch through the AOT train_step artifact
//! and watch the teacher-forced joint loss (Eq. 7) fall.
//!
//!     make artifacts
//!     cargo run --release --example train_small
//!
//! This is the training-loop counterpart of serve_e2e: python authored the
//! optimizer math once; rust owns data, schedules, and the loop.

use asarm::data::{pack_chunks, split_chunks, stories};
use asarm::runtime::engine::TrainRunner;
use asarm::runtime::XlaEngine;
use asarm::train::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(artifacts).join("train_step_b4.hlo.txt").exists() {
        eprintln!("train_small: run `make artifacts` first");
        return Ok(());
    }
    let steps: usize = std::env::var("ASARM_TRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);

    let mut runner = TrainRunner::load(artifacts, 4)?;
    let chunks = pack_chunks(&stories::corpus(7, 2000), runner.meta.seq_len);
    let (train_chunks, val_chunks) = split_chunks(chunks, 0.05, 3);
    println!(
        "training {} params on {} chunks for {steps} steps",
        runner.meta.n_params,
        train_chunks.len()
    );

    let mut val_engine = XlaEngine::load(artifacts, None)?;
    let cfg = TrainConfig {
        steps,
        lr_max: 3e-4,
        warmup_steps: steps / 10,
        decay_steps: steps,
        log_every: (steps / 12).max(1),
        val_every: (steps / 3).max(1),
        val_batches: 2,
        checkpoint: Some(std::path::PathBuf::from("/tmp/asarm_train_small.bin")),
        ..Default::default()
    };
    let logs = train(&mut runner, &train_chunks, &val_chunks, &cfg, Some(&mut val_engine))?;

    println!("\nloss curve:");
    for l in &logs {
        let bar_len = ((l.loss as f64) * 8.0) as usize;
        println!(
            "  step {:4}  loss {:7.4}  {}{}",
            l.step,
            l.loss,
            "#".repeat(bar_len.min(60)),
            l.val_nll_per_token
                .map(|v| format!("   val_nll/tok {v:.4}"))
                .unwrap_or_default()
        );
    }
    let first = logs.first().unwrap().loss;
    let last = logs.last().unwrap().loss;
    println!("\nloss {first:.4} -> {last:.4} ({:+.1}%)", 100.0 * (last - first) / first);
    anyhow::ensure!(last < first, "loss did not decrease");
    println!("train_small OK");
    Ok(())
}
