//! Quickstart: load the AS-ARM artifacts and infill a masked sentence with
//! Any-Subset Speculative Decoding.
//!
//!     make artifacts && make models     # once
//!     cargo run --release --example quickstart
//!
//! Demonstrates the minimal public API: engine -> ordering -> ASSD machine
//! -> completed text, with the NFE accounting that Theorem 1 bounds.

use asarm::data::masking::lattice_sigma;
use asarm::decode::assd::AssdMachine;
use asarm::decode::{init_tokens, run_machine};
use asarm::draft::DraftKind;
use asarm::model::mask::Ordering;
use asarm::runtime::{Engine, XlaEngine};
use asarm::tokenizer::{ByteTokenizer, MASK};
use asarm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ckpt = std::path::Path::new(artifacts).join("ckpt_stories_ft.bin");
    let params = if ckpt.exists() { Some(ckpt.as_path()) } else { None };
    let engine = XlaEngine::load(artifacts, params)?;
    println!(
        "loaded AS-ARM: {} params, N={}, V={}",
        engine.meta.n_params,
        engine.seq_len(),
        engine.vocab()
    );

    // A prompt with blanks anywhere (any-subset!): '_' marks positions to fill.
    let text = "Ana went to the lake. Ana wanted ______. Ana picked up a ____. Then it started to rain. Ana felt glad at the end.";
    let tok = ByteTokenizer::new();
    let n = engine.seq_len();
    let mut tokens = tok.encode_fixed(text, n);
    let mut visible = vec![];
    for (i, t) in tokens.iter_mut().enumerate() {
        if i < text.len() && text.as_bytes()[i] == b'_' {
            *t = MASK;
        } else {
            visible.push(i);
        }
    }
    let m = visible.len();
    let ord = Ordering::new(lattice_sigma(&visible, n), m);
    let prompt: Vec<(usize, u32)> = visible.iter().map(|&p| (p, tokens[p])).collect();
    let toks = init_tokens(&ord, &prompt);

    let machine = AssdMachine::with_kind(
        ord.clone(),
        toks,
        engine.vocab(),
        /*k=*/ 5,
        /*temperature=*/ 1.0,
        Rng::new(42),
        DraftKind::SelfModel,
    );
    let out = run_machine(&engine, Box::new(machine))?;

    println!("\nprompt : {text}");
    println!("infill : {}", tok.decode(&out.tokens[..text.len()]));
    println!(
        "\n{} tokens generated in {} forward passes ({} iterations, {:.2} tokens/iter)",
        ord.n_targets(),
        out.model_nfe,
        out.iterations,
        out.tokens_per_iteration(ord.n_targets())
    );
    println!(
        "Theorem 1 bound respected: {} <= {}",
        out.model_nfe,
        ord.n_targets()
    );
    Ok(())
}
