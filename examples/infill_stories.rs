//! Story infilling showcase: blank the middle sentence(s) of five-sentence
//! stories (the paper's Table 2 task) and compare every decoder side by
//! side on the same story — outputs, NFE, and acceptance statistics.
//!
//!     make artifacts && make models
//!     cargo run --release --example infill_stories

use asarm::coordinator::SamplerKind;
use asarm::eval::harness::{masked_span_text, run_sampler, story_infill_workload};
use asarm::eval::rouge::rouge_triple;
use asarm::runtime::{Engine, XlaEngine};
use asarm::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ckpt = std::path::Path::new(artifacts).join("ckpt_stories_ft.bin");
    if !ckpt.exists() {
        eprintln!("infill_stories: missing checkpoint; run `make models`");
        return Ok(());
    }
    let engine = XlaEngine::load(artifacts, Some(&ckpt))?;
    let tok = ByteTokenizer::new();
    let work = story_infill_workload(engine.seq_len(), 2, false, 31);

    for (idx, (item, reference_mid)) in work.iter().enumerate() {
        let masked_text = tok.decode(&item.tokens);
        println!("\n================ story {idx} ================");
        println!("prompt   : {}", masked_text.trim_end_matches('\u{0}'));
        println!("reference: {reference_mid}");
        for (label, sampler, k) in [
            ("sequential", SamplerKind::Sequential, 1),
            ("assd k=5", SamplerKind::Assd, 5),
            ("assd k=15", SamplerKind::Assd, 15),
            ("assd+ngram", SamplerKind::AssdNgram, 5),
            ("diffusion-8", SamplerKind::Diffusion, 5),
        ] {
            let (out, secs) =
                run_sampler(&engine, item, sampler, k, 8, 1.0, 500 + idx as u64)?;
            let span = masked_span_text(item, &out.tokens);
            let (r1, _, _) = rouge_triple(&span, reference_mid);
            println!(
                "{label:12} NFE {:3} (+{} aux)  {:5.2}s  R1 {:4.1}  -> {span}",
                out.model_nfe,
                out.aux_nfe,
                secs,
                r1 * 100.0
            );
        }
    }
    println!("\ninfill_stories OK");
    Ok(())
}
