//! Model-side substrates: metadata introspection, parameter I/O, and the
//! attention-mask builders (the rust half of the paper's "query the
//! architecture differently" design).

pub mod mask;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Mirror of python/compile/config.py's ModelConfig + flat-theta layout,
/// parsed from artifacts/model_meta.json.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub mask_id: u32,
    pub pad_id: u32,
    pub n_params: usize,
    /// Row-gather width `R` of the compact `fwd_ord_b{B}` artifacts in this
    /// set (absent in pre-compact artifact sets, which then serve through
    /// the dense fallback — see docs/ARCHITECTURE.md §Compact forward ABI).
    pub ord_rows: Option<usize>,
    /// Active-row width of the incremental `fwd_inc_b{B}` artifacts
    /// (absent in pre-incremental sets, which then serve through the
    /// compact path — see docs/ARCHITECTURE.md §Incremental forward &
    /// KV cache). The per-lane cache shape itself is derived from
    /// `(n_layers, seq_len, d_model)`.
    pub inc_rows: Option<usize>,
    pub params: Vec<(String, usize, Vec<usize>)>, // (name, offset, shape)
}

impl ModelMeta {
    pub fn load(path: impl AsRef<Path>) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ModelMeta> {
        let j = Json::parse(text).context("parsing model_meta.json")?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("missing field {k}"))
        };
        let mut params = vec![];
        if let Some(Json::Obj(m)) = j.get("params") {
            for (name, spec) in m {
                let offset = spec.get("offset").and_then(|v| v.as_usize()).unwrap_or(0);
                let shape: Vec<usize> = spec
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default();
                params.push((name.clone(), offset, shape));
            }
        } else {
            bail!("model_meta.json missing params object");
        }
        params.sort_by_key(|(_, off, _)| *off);
        let meta = ModelMeta {
            vocab: get("vocab")?,
            seq_len: get("seq_len")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            mask_id: get("mask_id")? as u32,
            pad_id: get("pad_id")? as u32,
            n_params: get("n_params")?,
            ord_rows: j.get("ord_rows").and_then(|v| v.as_usize()).filter(|&r| r > 0),
            inc_rows: j.get("inc_rows").and_then(|v| v.as_usize()).filter(|&r| r > 0),
            params,
        };
        // The recorded per-lane cache shape is informational (rust derives
        // it from the dims), but if present it must AGREE with the dims —
        // a mismatch means the artifact set and this runtime disagree
        // about the fwd_inc ABI, which would corrupt every lane.
        if let Some(cache) = j.get("inc_cache") {
            let field = |k: &str| cache.get(k).and_then(|v| v.as_usize());
            let want = [
                ("layers", meta.n_layers),
                ("slots", meta.seq_len),
                ("d_model", meta.d_model),
            ];
            for (k, expect) in want {
                match field(k) {
                    Some(got) if got == expect => {}
                    got => bail!(
                        "model_meta.json inc_cache.{k} = {got:?} disagrees with the model \
                         dims ({expect}) — mismatched incremental artifact set"
                    ),
                }
            }
        }
        Ok(meta)
    }

    /// Validate the layout is contiguous and totals n_params.
    pub fn validate(&self) -> Result<()> {
        let mut expect = 0usize;
        for (name, off, shape) in &self.params {
            if *off != expect {
                bail!("param {name} offset {off}, expected {expect}");
            }
            expect += shape.iter().product::<usize>();
        }
        if expect != self.n_params {
            bail!("layout totals {expect}, meta says {}", self.n_params);
        }
        Ok(())
    }
}

/// Load a flat little-endian f32 parameter file (params_init.bin or a
/// trainer checkpoint).
pub fn load_params(path: impl AsRef<Path>, expect_len: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() != expect_len * 4 {
        bail!(
            "param file {} has {} bytes, expected {}",
            path.as_ref().display(),
            bytes.len(),
            expect_len * 4
        );
    }
    let mut out = Vec::with_capacity(expect_len);
    for c in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(out)
}

/// Save a flat f32 parameter vector (checkpoints).
pub fn save_params(path: impl AsRef<Path>, theta: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(theta.len() * 4);
    for x in theta {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path.as_ref(), bytes)
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
      "vocab": 258, "seq_len": 128, "d_model": 128, "n_layers": 4,
      "n_heads": 4, "d_ff": 512, "mask_id": 256, "pad_id": 257,
      "n_params": 20,
      "params": {
        "a": {"offset": 0, "shape": [2, 5]},
        "b": {"offset": 10, "shape": [10]}
      }
    }"#;

    #[test]
    fn parse_and_validate() {
        let m = ModelMeta::parse(META).unwrap();
        assert_eq!(m.vocab, 258);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].0, "a");
        m.validate().unwrap();
    }

    #[test]
    fn ord_rows_optional_and_parsed() {
        // Pre-compact artifact sets carry no ord_rows field.
        assert_eq!(ModelMeta::parse(META).unwrap().ord_rows, None);
        let with = META.replace("\"n_params\": 20,", "\"n_params\": 20, \"ord_rows\": 32,");
        assert_eq!(ModelMeta::parse(&with).unwrap().ord_rows, Some(32));
        // A malformed 0 is treated as absent, not as an empty gather.
        let zero = META.replace("\"n_params\": 20,", "\"n_params\": 20, \"ord_rows\": 0,");
        assert_eq!(ModelMeta::parse(&zero).unwrap().ord_rows, None);
    }

    #[test]
    fn inc_rows_optional_and_parsed() {
        // Pre-incremental artifact sets carry no inc_rows field.
        assert_eq!(ModelMeta::parse(META).unwrap().inc_rows, None);
        let with = META.replace("\"n_params\": 20,", "\"n_params\": 20, \"inc_rows\": 64,");
        assert_eq!(ModelMeta::parse(&with).unwrap().inc_rows, Some(64));
        let zero = META.replace("\"n_params\": 20,", "\"n_params\": 20, \"inc_rows\": 0,");
        assert_eq!(ModelMeta::parse(&zero).unwrap().inc_rows, None);
    }

    #[test]
    fn inc_cache_shape_validated_against_dims() {
        let good = META.replace(
            "\"n_params\": 20,",
            "\"n_params\": 20, \"inc_cache\": {\"layers\": 4, \"slots\": 128, \"d_model\": 128},",
        );
        ModelMeta::parse(&good).unwrap();
        // A recorded cache shape that disagrees with the dims is a
        // mismatched artifact set, not a tolerable variation.
        let bad = META.replace(
            "\"n_params\": 20,",
            "\"n_params\": 20, \"inc_cache\": {\"layers\": 4, \"slots\": 64, \"d_model\": 128},",
        );
        assert!(ModelMeta::parse(&bad).unwrap_err().to_string().contains("inc_cache.slots"));
    }

    #[test]
    fn validate_catches_gap() {
        let bad = META.replace("\"offset\": 10", "\"offset\": 11");
        let m = ModelMeta::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn params_roundtrip(){
        let dir = std::env::temp_dir().join("asarm_test_params");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let theta: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 3.0).collect();
        save_params(&path, &theta).unwrap();
        let got = load_params(&path, 100).unwrap();
        assert_eq!(theta, got);
        assert!(load_params(&path, 99).is_err());
    }

    #[test]
    fn real_meta_artifact_parses_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/model_meta.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = ModelMeta::parse(&text).unwrap();
            m.validate().unwrap();
            assert_eq!(m.vocab, 258);
            assert_eq!(m.params[0].0, "tok_emb");
        }
    }
}
