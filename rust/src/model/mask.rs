//! Attention-mask construction (AUTHORITATIVE; python/compile/masks.py is
//! the mirror used for golden fixtures).
//!
//! See python/compile/masks.py for the full semantics discussion. In short,
//! for a generation state (sigma, m, n):
//!
//!  * verify masks (Fig. 1b) depend on (sigma, m) only: prompt rows attend
//!    the full prompt; target rows attend prompt + strictly-earlier
//!    targets; content stream (h) additionally sees itself.
//!  * draft masks (Fig. 1a) at state n: KNOWN rows identical to verify
//!    (this is what makes Lemma 1 exact); UNKNOWN query rows attend
//!    exactly the known set (order < n); nothing attends unknown columns.
//!
//! Masks are row-major [N*N] f32 with 1.0 = may-attend, matching the dense
//! HLO artifact inputs. Both families are projections of one scalar
//! predicate, [`g_allows`] — the compact forward ABI
//! (`fwd_ord_b{B}.hlo.txt`, see docs/ARCHITECTURE.md §Compact forward ABI)
//! re-evaluates the same predicate *inside* the compiled graph from
//! `(order, m, known)`, so these builders double as the fixture/reference
//! path for the on-device construction.

/// A generation ordering: sigma (order -> position) with prompt size m.
#[derive(Clone, Debug)]
pub struct Ordering {
    pub sigma: Vec<usize>,
    /// position -> order index
    pub order: Vec<usize>,
    pub m: usize,
}

impl Ordering {
    pub fn new(sigma: Vec<usize>, m: usize) -> Self {
        let n = sigma.len();
        assert!(m <= n, "prompt larger than sequence");
        let mut order = vec![usize::MAX; n];
        for (i, &pos) in sigma.iter().enumerate() {
            assert!(pos < n, "sigma out of range");
            assert_eq!(order[pos], usize::MAX, "sigma not a bijection");
            order[pos] = i;
        }
        Ordering { sigma, order, m }
    }

    pub fn n(&self) -> usize {
        self.sigma.len()
    }

    /// Number of target tokens.
    pub fn n_targets(&self) -> usize {
        self.n() - self.m
    }

    pub fn is_prompt_pos(&self, pos: usize) -> bool {
        self.order[pos] < self.m
    }
}

/// The scalar mask predicate every construction path shares: may the
/// query-stream row with order `oa` attend the column with order `ob`,
/// given prompt size `m` and decode state `known` (orders `< known` hold
/// committed tokens)?
///
/// `known == n` yields the verify masks (Fig. 1b); `m <= known < n` the
/// draft masks at that state (Fig. 1a). The dense builders below, the
/// MockEngine's native compact forward, and — semantically — the on-device
/// construction baked into the `fwd_ord_b{B}` HLO artifacts
/// (`python/compile/model.py::masks_from_order_batched`) all evaluate
/// exactly this predicate, so they cannot diverge independently.
#[inline]
pub fn g_allows(oa: usize, ob: usize, m: usize, known: usize) -> bool {
    if oa < m {
        // prompt row: full prompt attention
        ob < m
    } else if oa < known {
        // known target row: prompt + strictly-earlier known targets
        ob < m || (ob < known && ob < oa)
    } else {
        // unknown row: attend exactly the known set
        ob < known
    }
}

/// Write the verify-mode (mask_h, mask_g) into row-major buffers.
pub fn verify_masks_into(ord: &Ordering, mask_h: &mut [f32], mask_g: &mut [f32]) {
    draft_masks_into(ord, ord.n(), mask_h, mask_g);
}

/// Write the draft-mode (mask_h, mask_g) at decode state `n_known`.
pub fn draft_masks_into(ord: &Ordering, n_known: usize, mask_h: &mut [f32], mask_g: &mut [f32]) {
    let n = ord.n();
    assert!(n_known >= ord.m && n_known <= n);
    assert_eq!(mask_h.len(), n * n);
    assert_eq!(mask_g.len(), n * n);
    for a in 0..n {
        let oa = ord.order[a];
        let row_g = &mut mask_g[a * n..(a + 1) * n];
        for (b, cell) in row_g.iter_mut().enumerate() {
            *cell = if g_allows(oa, ord.order[b], ord.m, n_known) {
                1.0
            } else {
                0.0
            };
        }
    }
    mask_h.copy_from_slice(mask_g);
    for a in 0..n {
        mask_h[a * n + a] = 1.0;
    }
}

/// Allocating conveniences (tests / non-hot paths).
pub fn verify_masks(ord: &Ordering) -> (Vec<f32>, Vec<f32>) {
    let n = ord.n();
    let mut h = vec![0.0; n * n];
    let mut g = vec![0.0; n * n];
    verify_masks_into(ord, &mut h, &mut g);
    (h, g)
}

pub fn draft_masks(ord: &Ordering, n_known: usize) -> (Vec<f32>, Vec<f32>) {
    let n = ord.n();
    let mut h = vec![0.0; n * n];
    let mut g = vec![0.0; n * n];
    draft_masks_into(ord, n_known, &mut h, &mut g);
    (h, g)
}

/// Incremental draft-mask update: advance the decode state from `n_prev` to
/// `n_new` in-place. Only rows/columns involving the newly-known orders
/// change, so this is O((n_new - n_prev) * N) instead of O(N^2).
pub fn advance_draft_masks(
    ord: &Ordering,
    n_prev: usize,
    n_new: usize,
    mask_h: &mut [f32],
    mask_g: &mut [f32],
) {
    let n = ord.n();
    debug_assert!(ord.m <= n_prev && n_prev <= n_new && n_new <= n);
    if n_prev == n_new {
        return;
    }
    // 1. newly-known rows become causal rows
    for i in n_prev..n_new {
        let a = ord.sigma[i];
        let row_g = &mut mask_g[a * n..(a + 1) * n];
        for (b, cell) in row_g.iter_mut().enumerate() {
            *cell = if g_allows(i, ord.order[b], ord.m, n_new) {
                1.0
            } else {
                0.0
            };
        }
    }
    // 2. unknown rows gain the newly-known columns
    for i in n_new..n {
        let a = ord.sigma[i];
        let row_g = &mut mask_g[a * n..(a + 1) * n];
        for j in n_prev..n_new {
            row_g[ord.sigma[j]] = 1.0;
        }
    }
    // 3. mirror to content stream (h = g + self)
    for i in n_prev..n_new.max(n_prev) {
        let a = ord.sigma[i];
        mask_h[a * n..(a + 1) * n].copy_from_slice(&mask_g[a * n..(a + 1) * n]);
        mask_h[a * n + a] = 1.0;
    }
    for i in n_new..n {
        let a = ord.sigma[i];
        mask_h[a * n..(a + 1) * n].copy_from_slice(&mask_g[a * n..(a + 1) * n]);
        mask_h[a * n + a] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::{lattice_sigma, sample_sigma, OrderProtocol};
    use crate::util::{propcheck, rng::Rng};

    fn random_ordering(rng: &mut Rng, nmax: usize) -> Ordering {
        let n = rng.range(2, nmax);
        let m = rng.range(1, n);
        let sigma = sample_sigma(rng, n, m, OrderProtocol::Lattice);
        Ordering::new(sigma, m)
    }

    #[test]
    fn ordering_rejects_non_bijection() {
        let r = std::panic::catch_unwind(|| Ordering::new(vec![0, 0, 1], 1));
        assert!(r.is_err());
    }

    #[test]
    fn verify_known_case() {
        // n=4, visible {1,3}: sigma = [1,3,0,2], m=2
        let ord = Ordering::new(lattice_sigma(&[1, 3], 4), 2);
        let (h, g) = verify_masks(&ord);
        // prompt rows (pos 1,3) attend prompt only
        assert_eq!(&g[4..8], &[0.0, 1.0, 0.0, 1.0]); // row 1
        assert_eq!(&g[12..16], &[0.0, 1.0, 0.0, 1.0]); // row 3
        // first target (pos 0, order 2) attends prompt only
        assert_eq!(&g[0..4], &[0.0, 1.0, 0.0, 1.0]);
        // second target (pos 2, order 3) attends prompt + pos 0
        assert_eq!(&g[8..12], &[1.0, 1.0, 0.0, 1.0]);
        // h = g + diagonal
        for a in 0..4 {
            assert_eq!(h[a * 4 + a], 1.0);
        }
    }

    #[test]
    fn draft_equals_verify_at_full_knowledge() {
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let ord = random_ordering(&mut rng, 24);
            let (vh, vg) = verify_masks(&ord);
            let (dh, dg) = draft_masks(&ord, ord.n());
            assert_eq!(vh, dh);
            assert_eq!(vg, dg);
        }
    }

    #[test]
    fn prop_draft_invariants() {
        propcheck::check_no_shrink(
            7,
            150,
            |r: &mut Rng| {
                let ord = random_ordering(r, 24);
                let nk = r.range(ord.m, ord.n() + 1);
                (ord, nk)
            },
            |(ord, nk)| {
                let n = ord.n();
                let (dh, dg) = draft_masks(ord, *nk);
                let (vh, vg) = verify_masks(ord);
                for a in 0..n {
                    let oa = ord.order[a];
                    for b in 0..n {
                        let ob = ord.order[b];
                        let g = dg[a * n + b];
                        let h = dh[a * n + b];
                        // nothing attends unknown columns (except self in h)
                        if ob >= *nk && g != 0.0 {
                            return Err(format!("g[{a}][{b}] attends unknown"));
                        }
                        if ob >= *nk && a != b && h != 0.0 {
                            return Err(format!("h[{a}][{b}] attends unknown"));
                        }
                        // known rows match verify
                        if oa < *nk && (g != vg[a * n + b] || h != vh[a * n + b]) {
                            return Err(format!("known row {a} differs from verify"));
                        }
                        // unknown rows attend exactly the known set
                        if oa >= *nk {
                            let want = if ob < *nk { 1.0 } else { 0.0 };
                            if g != want {
                                return Err(format!("unknown row {a} col {b}"));
                            }
                        }
                    }
                    if dh[a * n + a] != 1.0 {
                        return Err(format!("h diagonal missing at {a}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_incremental_update_matches_full_build() {
        propcheck::check_no_shrink(
            8,
            150,
            |r: &mut Rng| {
                let ord = random_ordering(r, 24);
                let n0 = r.range(ord.m, ord.n() + 1);
                let n1 = r.range(n0, ord.n() + 1);
                (ord, n0, n1)
            },
            |(ord, n0, n1)| {
                let (mut h, mut g) = draft_masks(ord, *n0);
                advance_draft_masks(ord, *n0, *n1, &mut h, &mut g);
                let (wh, wg) = draft_masks(ord, *n1);
                if h != wh {
                    return Err("h mismatch after incremental update".into());
                }
                if g != wg {
                    return Err("g mismatch after incremental update".into());
                }
                Ok(())
            },
        );
    }

    /// Golden parity with the python reference (artifacts/fixtures/
    /// masks.json, generated by `python/compile/fixtures.py` and committed
    /// to the repo): the rust builders must byte-match the python
    /// `verify_masks`/`draft_masks` output over a sweep of
    /// (N, m, sigma, known). The same fixture semantics anchor the
    /// on-device construction (python tests compare `masks_from_order`
    /// against the dense builders), so all three paths are pinned to one
    /// reference.
    #[test]
    fn golden_fixtures_match_python() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/fixtures/masks.json");
        let text = std::fs::read_to_string(path)
            .expect("artifacts/fixtures/masks.json missing — run `make fixtures`");
        let cases = crate::util::json::Json::parse(&text).unwrap();
        let cases = cases.as_arr().unwrap();
        assert!(cases.len() >= 10, "suspiciously few fixture cases");
        let mut draft_cases = 0usize;
        for case in cases {
            let n = case.get("n").unwrap().as_usize().unwrap();
            let m = case.get("m").unwrap().as_usize().unwrap();
            let sigma: Vec<usize> = case
                .get("sigma")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect();
            let ord = Ordering::new(sigma, m);
            let to_vec = |j: &crate::util::json::Json, key: &str| -> Vec<f32> {
                j.get(key)
                    .unwrap_or_else(|| panic!("fixture missing key {key}"))
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|x| x.as_f64().unwrap() as f32)
                    .collect()
            };
            let (vh, vg) = verify_masks(&ord);
            assert_eq!(vh, to_vec(case, "verify_h"), "verify_h n={n} m={m}");
            assert_eq!(vg, to_vec(case, "verify_g"), "verify_g n={n} m={m}");
            for d in case.get("drafts").unwrap().as_arr().unwrap() {
                let nk = d.get("n_known").unwrap().as_usize().unwrap();
                let (dh, dg) = draft_masks(&ord, nk);
                assert_eq!(dh, to_vec(d, "h"), "draft_h n={n} m={m} nk={nk}");
                assert_eq!(dg, to_vec(d, "g"), "draft_g n={n} m={m} nk={nk}");
                draft_cases += 1;
            }
        }
        assert!(draft_cases >= 20, "draft sweep too thin: {draft_cases}");
    }

    /// The scalar predicate is the single source of truth: builders are its
    /// projection at every (m, known) state.
    #[test]
    fn prop_g_allows_matches_builders() {
        propcheck::check_no_shrink(
            9,
            150,
            |r: &mut Rng| {
                let ord = random_ordering(r, 20);
                let nk = r.range(ord.m, ord.n() + 1);
                (ord, nk)
            },
            |(ord, nk)| {
                let n = ord.n();
                let (dh, dg) = draft_masks(ord, *nk);
                for a in 0..n {
                    for b in 0..n {
                        let want = g_allows(ord.order[a], ord.order[b], ord.m, *nk);
                        if (dg[a * n + b] > 0.0) != want {
                            return Err(format!("g[{a}][{b}] != g_allows at nk={nk}"));
                        }
                        let want_h = want || a == b;
                        if (dh[a * n + b] > 0.0) != want_h {
                            return Err(format!("h[{a}][{b}] != g_allows at nk={nk}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
