//! asarm — the leader binary.
//!
//! Subcommands:
//!   serve    — start the HTTP serving coordinator (continuous batching)
//!   train    — train the AS-ARM via the AOT train_step artifact
//!   infill   — one-shot infilling from the CLI
//!   corpus   — emit the synthetic corpora (stories / prose / exprlang)
//!   smoke    — PJRT liveness check

use std::path::PathBuf;

use anyhow::{bail, Result};

use asarm::coordinator::{self, DraftSpec, InfillRequest, Metrics, SamplerKind, SchedulerConfig};
use asarm::data::masking::{MaskRateSchedule, OrderProtocol, PromptDist};
use asarm::data::{pack_chunks, split_chunks, stories};
use asarm::draft::{DraftKind, DraftOptions};
use asarm::runtime::engine::TrainRunner;
use asarm::runtime::{ChaosConfig, PagedKvConfig, PoolConfig, XlaEngine};
use asarm::train::TrainConfig;
use asarm::util::args::Args;
use asarm::util::rng::Rng;

const USAGE: &str = "usage: asarm <serve|train|infill|corpus|smoke> [--flags]
  serve  --artifacts DIR --params FILE --addr 127.0.0.1:8080 --max-batch 4
         --replicas 1   (engine replicas, one scheduler worker each)
         --draft self|bigram|lookup --draft-max-len 5 --adaptive
         (default draft config for requests without a \"draft\" field)
         --queue-depth 1024   (admission queue bound; full => HTTP 429)
         --event-buffer 256   (per-request event-channel capacity;
         lagging streaming clients beyond it are cancelled)
         --block-size 16      (rows per K/V cache block)
         --cache-blocks N     (per-replica K/V block-pool size; bounds
         engine cache memory and caps concurrent lanes — default
         8 x blocks-per-sequence. Both unset => engine defaults)
         --trace on|off       (request tracing: per-request span
         timelines at GET /trace/{id}, index at /trace/recent.
         Default on; 'off' drops the builders for zero overhead)
         --trace-capacity 256 (retired traces retained per replica;
         the ring drops oldest first)
         --flight-sample-rate 0.05 (fraction of requests whose
         speculation flight is recorded — per-window accept/reject
         anatomy at GET /debug/flight/{id}, aggregates at
         /debug/vars and the /debug/dashboard page. Deterministic
         id-hash sampling; 0 disables the recorder)
         --flight-capacity 64 (retired flight records retained per
         replica; heatmap aggregates survive ring eviction)
         --chaos-rate 0.0     (deterministic fault injection: per-call
         fault probability wrapped around every replica's engine;
         0 disables. For chaos drills, not production)
         --chaos-seed 0       (fault-schedule seed; same seed + rate
         = same fault sequence)
         --retry-budget 8     (transient forward failures tolerated per
         request before it fails; surfaced per replica at
         GET /replicas)
  train  --artifacts DIR --steps N --lr 3e-4 --batch 4 --corpus stories|expr
         --protocol lattice|permutation --prompt-lo F --prompt-hi F
         --out CKPT.bin --seed S
  infill --artifacts DIR --params FILE --text 'Tom went to ____.'
         --sampler assd|assd_ngram|sequential|diffusion --k 5 --seed 0
         --draft self|bigram|lookup --adaptive --timeout-ms 0 (0 = none)
  corpus --kind stories|prose|expr --n 10
  smoke";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("train") => cmd_train(&args),
        Some("infill") => cmd_infill(&args),
        Some("corpus") => cmd_corpus(&args),
        Some("smoke") | None => {
            let client = asarm::runtime::cpu_client()?;
            println!("platform = {}", client.platform_name());
            Ok(())
        }
        Some(other) => {
            eprintln!("{USAGE}");
            bail!("unknown subcommand '{other}'")
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

fn draft_options(args: &Args, len_key: &str) -> Result<DraftOptions> {
    Ok(DraftOptions {
        kind: DraftKind::parse(&args.str("draft", "self"))?,
        max_len: args.usize(len_key, 5).max(1),
        adaptive: args.bool("adaptive"),
    })
}

/// Optional paged-KV pool sizing from `--block-size` / `--cache-blocks`.
/// Either flag alone works (0 = "derive the default for the artifact's
/// window" — only the engine knows the sequence length); both unset
/// defers sizing to the engine entirely.
fn kv_config(args: &Args) -> Option<PagedKvConfig> {
    let block_rows = args.usize("block-size", 0);
    let total_blocks = args.usize("cache-blocks", 0);
    if block_rows == 0 && total_blocks == 0 {
        return None;
    }
    Some(PagedKvConfig {
        block_rows,
        total_blocks,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let metrics = Metrics::new();
    let params = args.opt("params").map(PathBuf::from);
    let replicas = args.usize("replicas", 1);
    let handle = coordinator::start_xla_with(
        artifacts_dir(args),
        params,
        PoolConfig { replicas },
        SchedulerConfig {
            max_batch: args.usize("max-batch", 4),
            default_draft: draft_options(args, "draft-max-len")?,
            queue_depth: args.usize("queue-depth", 1024).max(1),
            event_capacity: args.usize("event-buffer", 256).max(8),
            trace: args.str("trace", "on") != "off",
            trace_capacity: args.usize("trace-capacity", 256).max(1),
            flight_sample_rate: args.f64("flight-sample-rate", 0.05),
            flight_capacity: args.usize("flight-capacity", 64).max(1),
            chaos: ChaosConfig {
                seed: args.u64("chaos-seed", 0),
                rate: args.f64("chaos-rate", 0.0),
                ..Default::default()
            },
            retry_budget: args.u64("retry-budget", 8) as u32,
            ..Default::default()
        },
        metrics.clone(),
        kv_config(args),
    );
    let addr = args.str("addr", "127.0.0.1:8080");
    let server =
        coordinator::http::HttpServer::bind(&addr, handle, metrics, args.usize("workers", 8))?;
    println!(
        "serving on http://{} ({replicas} engine replica{})",
        server.addr,
        if replicas == 1 { "" } else { "s" }
    );
    println!(
        "  POST /v1/infill   POST /infill/stream (SSE)   GET /metrics   GET /replicas   GET /healthz"
    );
    println!(
        "  POST /drain (checkpoint + refuse admissions; ?resume=1 lifts)   GET /drain"
    );
    println!(
        "  GET /trace/{{id}}   GET /trace/recent   GET /metrics (Accept: text/plain => Prometheus)"
    );
    println!(
        "  GET /debug/vars   GET /debug/flight/{{id}}   GET /debug/dashboard (live HTML)"
    );
    server.serve()
}

/// Build a packed training corpus of the requested kind.
pub fn corpus_chunks(kind: &str, n_docs: usize, seq_len: usize, seed: u64) -> Vec<Vec<u32>> {
    match kind {
        "stories" => pack_chunks(&stories::corpus(seed, n_docs), seq_len),
        "expr" => {
            let mut rng = Rng::new(seed);
            let docs: Vec<String> = (0..n_docs)
                .map(|_| {
                    let lines = rng.range(3, 7);
                    asarm::eval::exprlang::gen_program(&mut rng, lines)
                })
                .collect();
            pack_chunks(&docs, seq_len)
        }
        other => panic!("unknown corpus kind '{other}'"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let batch = args.usize("batch", 4);
    let mut runner = TrainRunner::load(&dir, batch)?;
    if let Some(init) = args.opt("init") {
        // Resume from a checkpoint (fresh optimizer state).
        let theta = asarm::model::load_params(init, runner.meta.n_params)?;
        runner.reset(theta);
        eprintln!("resumed parameters from {init}");
    }
    let n = runner.meta.seq_len;

    let kind = args.str("corpus", "stories");
    let n_docs = args.usize("docs", 4000);
    let chunks = corpus_chunks(&kind, n_docs, n, args.u64("data-seed", 1234));
    let (train_chunks, val_chunks) = split_chunks(chunks, 0.05, 7);
    eprintln!(
        "corpus '{kind}': {} train chunks, {} val chunks of {n} tokens",
        train_chunks.len(),
        val_chunks.len()
    );

    let protocol = match args.str("protocol", "lattice").as_str() {
        "lattice" => OrderProtocol::Lattice,
        "permutation" => OrderProtocol::Permutation,
        other => bail!("unknown protocol '{other}'"),
    };
    let prompt_dist = match (args.opt("prompt-lo"), args.opt("prompt-hi")) {
        (Some(lo), Some(hi)) => Some(PromptDist::new(lo.parse()?, hi.parse()?)),
        _ => None,
    };
    let steps = args.usize("steps", 400);
    let cfg = TrainConfig {
        steps,
        lr_max: args.f64("lr", 3e-4) as f32,
        warmup_steps: args.usize("warmup", (steps / 10).max(1)),
        decay_steps: args.usize("decay", steps),
        mask_schedule: MaskRateSchedule::paper_default(),
        prompt_dist,
        protocol,
        seed: args.u64("seed", 0),
        log_every: args.usize("log-every", 20),
        val_every: args.usize("val-every", 100),
        val_batches: args.usize("val-batches", 2),
        checkpoint: Some(PathBuf::from(
            args.str("out", &format!("artifacts/ckpt_{kind}.bin")),
        )),
    };
    let mut val_engine = XlaEngine::load(&dir, None)?;
    let logs = asarm::train::train(
        &mut runner,
        &train_chunks,
        &val_chunks,
        &cfg,
        Some(&mut val_engine),
    )?;
    if let Some(last) = logs.last() {
        println!("final loss {:.4}", last.loss);
    }
    Ok(())
}

fn cmd_infill(args: &Args) -> Result<()> {
    let metrics = Metrics::new();
    let params = args.opt("params").map(PathBuf::from);
    let handle = coordinator::start_xla_with(
        artifacts_dir(args),
        params,
        PoolConfig::default(),
        SchedulerConfig::default(),
        metrics,
        kv_config(args),
    );
    let req = InfillRequest {
        text: args.str("text", "Tom went to the ____."),
        mask_char: '_',
        sampler: SamplerKind::parse(&args.str("sampler", "assd"))?,
        draft: DraftSpec::from_options(draft_options(args, "k")?),
        steps: args.usize("steps", 32),
        temperature: args.f64("temperature", 1.0) as f32,
        seed: args.u64("seed", 0),
        timeout_ms: match args.u64("timeout-ms", 0) {
            0 => None,
            t => Some(t),
        },
    };
    let resp = handle.infill(req)?;
    println!("{}", resp.to_json());
    Ok(())
}

fn cmd_corpus(args: &Args) -> Result<()> {
    let kind = args.str("kind", "stories");
    let n = args.usize("n", 10);
    let mut rng = Rng::new(args.u64("seed", 0));
    for _ in 0..n {
        match kind.as_str() {
            "stories" => println!("{}", stories::story_text(&mut rng)),
            "prose" => println!("{}", stories::prose(&mut rng, 400)),
            "expr" => println!("{}\n", asarm::eval::exprlang::gen_program(&mut rng, 5)),
            other => bail!("unknown corpus kind '{other}'"),
        }
    }
    Ok(())
}
