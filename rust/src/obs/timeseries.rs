//! Rolling time-series engine: a zero-dependency, fixed-resolution ring
//! of per-second buckets backing `GET /debug/vars` and the live
//! dashboard.
//!
//! Design notes:
//!
//! - **Explicit clock.** Every write takes the bucket second (`at_sec`)
//!   as a parameter — seconds since a shared pool `Instant` origin in
//!   production, a fake clock in tests. The ring itself never reads a
//!   wall clock, which makes rotation under skew/stall directly
//!   property-testable.
//! - **Rotation.** A write at a *newer* second rotates the ring forward,
//!   zeroing every skipped bucket (a stalled producer must not leave
//!   stale data where idle seconds belong). A jump of `>= capacity`
//!   seconds clears the whole ring. A write at an *older* second (clock
//!   skew across replica threads, NTP step) is clamped into the newest
//!   bucket — data is never dropped and never lands in the past where a
//!   snapshot could double-report it.
//! - **Counters vs gauges.** Counter fields (`tokens`, `model_nfe`, …)
//!   accumulate deltas; gauge fields (`queue_depth`, `kv_blocks_free`,
//!   …) are last-write-wins within their second. [`CounterFold`] turns
//!   the cumulative counters the replicas expose into per-tick deltas,
//!   tolerating resets (replica restart ⇒ cumulative value drops ⇒ the
//!   new cumulative value *is* the delta).
//! - **Cross-replica merge.** [`merge`] aligns per-replica snapshots by
//!   absolute second and sums field-wise (gauges included: summed
//!   occupancy / free blocks across the pool is the fleet view). The
//!   merge-equivalence property (merged == field-wise sum) is tested.
//!
//! Memory is `capacity * sizeof(Bucket)` per ring, fixed at
//! construction. All methods take `&self`; interior mutability is a
//! single short-held mutex (writes are a few adds per scheduler
//! iteration — far off the decode hot path).

use std::sync::Mutex;

use crate::util::json::Json;

/// One second of aggregated activity. Counter fields accumulate;
/// gauge fields hold the last value written within the second.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Bucket {
    /// Absolute second (since the pool origin) this bucket covers.
    pub sec: u64,
    // --- counters (summed within the second, deltas folded in) ---
    pub tokens: u64,
    pub model_nfe: u64,
    pub aux_nfe: u64,
    pub proposed: u64,
    pub accepted: u64,
    pub requests: u64,
    pub errors_transient: u64,
    pub errors_lane_corrupt: u64,
    pub errors_fatal: u64,
    // --- gauges (last write wins within the second) ---
    pub queue_depth: u64,
    pub kv_blocks_free: u64,
    pub kv_blocks_total: u64,
    pub batch_occupancy: u64,
    /// 1 if the producing replica was serving when it last ticked
    /// (summed across replicas by [`merge`] ⇒ count of serving replicas).
    pub serving: u64,
}

impl Bucket {
    /// Field-wise sum used by [`merge`]. Gauges sum too: the merged view
    /// is the pool aggregate (total queue depth, total free blocks,
    /// number of serving replicas).
    fn add(&mut self, o: &Bucket) {
        self.tokens += o.tokens;
        self.model_nfe += o.model_nfe;
        self.aux_nfe += o.aux_nfe;
        self.proposed += o.proposed;
        self.accepted += o.accepted;
        self.requests += o.requests;
        self.errors_transient += o.errors_transient;
        self.errors_lane_corrupt += o.errors_lane_corrupt;
        self.errors_fatal += o.errors_fatal;
        self.queue_depth += o.queue_depth;
        self.kv_blocks_free += o.kv_blocks_free;
        self.kv_blocks_total += o.kv_blocks_total;
        self.batch_occupancy += o.batch_occupancy;
        self.serving += o.serving;
    }

    /// JSON object for `/debug/vars` (field names are the public wire
    /// contract — the dashboard reads them).
    pub fn to_json(&self) -> Json {
        let accept_rate = if self.proposed > 0 {
            self.accepted as f64 / self.proposed as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("sec", Json::num(self.sec as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("model_nfe", Json::num(self.model_nfe as f64)),
            ("aux_nfe", Json::num(self.aux_nfe as f64)),
            ("proposed", Json::num(self.proposed as f64)),
            ("accepted", Json::num(self.accepted as f64)),
            ("accept_rate", Json::num(accept_rate)),
            ("requests", Json::num(self.requests as f64)),
            ("errors_transient", Json::num(self.errors_transient as f64)),
            (
                "errors_lane_corrupt",
                Json::num(self.errors_lane_corrupt as f64),
            ),
            ("errors_fatal", Json::num(self.errors_fatal as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("kv_blocks_free", Json::num(self.kv_blocks_free as f64)),
            ("kv_blocks_total", Json::num(self.kv_blocks_total as f64)),
            ("batch_occupancy", Json::num(self.batch_occupancy as f64)),
            ("serving", Json::num(self.serving as f64)),
        ])
    }
}

struct RingInner {
    /// `buckets[i]` covers second `newest_sec - (head_distance)` — see
    /// `snapshot` for the layout walk. Slot `head` is the newest bucket.
    buckets: Vec<Bucket>,
    head: usize,
    newest_sec: u64,
    /// No writes yet; `snapshot` returns empty.
    started: bool,
}

/// Fixed-capacity ring of per-second [`Bucket`]s.
pub struct TsRing {
    inner: Mutex<RingInner>,
    capacity: usize,
}

impl TsRing {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        TsRing {
            inner: Mutex::new(RingInner {
                buckets: vec![Bucket::default(); capacity],
                head: 0,
                newest_sec: 0,
                started: false,
            }),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Apply `f` to the bucket covering `at_sec`, rotating the ring
    /// forward as needed. Writes in the past (skew) clamp to the newest
    /// bucket; see the module docs for the full rotation contract.
    pub fn record_at<F: FnOnce(&mut Bucket)>(&self, at_sec: u64, f: F) {
        let mut g = self.inner.lock().unwrap();
        if !g.started {
            g.started = true;
            g.newest_sec = at_sec;
            g.head = 0;
            g.buckets[0] = Bucket {
                sec: at_sec,
                ..Bucket::default()
            };
        } else if at_sec > g.newest_sec {
            let jump = at_sec - g.newest_sec;
            if jump >= self.capacity as u64 {
                // The whole window went idle (or the producer stalled
                // past the horizon): every retained bucket is stale.
                for b in g.buckets.iter_mut() {
                    *b = Bucket::default();
                }
                g.head = 0;
                g.buckets[0].sec = at_sec;
            } else {
                // Zero each skipped second so idle gaps read as zeros,
                // not as leftovers from `capacity` seconds ago.
                for s in 1..=jump {
                    let head = (g.head + 1) % self.capacity;
                    g.head = head;
                    g.buckets[head] = Bucket {
                        sec: g.newest_sec + s,
                        ..Bucket::default()
                    };
                }
            }
            g.newest_sec = at_sec;
        }
        // at_sec <= newest_sec (skew) folds into the newest bucket.
        let head = g.head;
        f(&mut g.buckets[head]);
    }

    /// The most recent `window` buckets, oldest first. Buckets that were
    /// never written (ring not yet full) are omitted, so callers see
    /// only real seconds.
    pub fn snapshot(&self, window: usize) -> Vec<Bucket> {
        let g = self.inner.lock().unwrap();
        if !g.started {
            return Vec::new();
        }
        let window = window.clamp(1, self.capacity);
        let mut out = Vec::with_capacity(window);
        // Walk back from head, collect live buckets, reverse.
        for k in 0..window {
            let idx = (g.head + self.capacity - k) % self.capacity;
            let b = g.buckets[idx];
            // A live bucket at walk-back distance k covers exactly
            // newest_sec - k; anything else is unwritten wrap-around
            // residue (ring younger than the window).
            if k > 0 {
                match g.newest_sec.checked_sub(k as u64) {
                    Some(want) if b.sec == want => {}
                    _ => break,
                }
            }
            out.push(b);
        }
        out.reverse();
        out
    }
}

/// Merge per-replica snapshots into one pool-level series: align by
/// absolute second, field-wise sum. Result is sorted oldest first.
pub fn merge(snapshots: &[Vec<Bucket>]) -> Vec<Bucket> {
    let mut merged: Vec<Bucket> = Vec::new();
    for snap in snapshots {
        for b in snap {
            match merged.binary_search_by_key(&b.sec, |m| m.sec) {
                Ok(i) => merged[i].add(b),
                Err(i) => merged.insert(i, *b),
            }
        }
    }
    merged
}

/// Turns a monotonically-nondecreasing cumulative counter into per-tick
/// deltas. On reset (replica restart: cumulative drops below the last
/// seen value) the new cumulative value is taken as the whole delta.
#[derive(Debug, Default, Clone, Copy)]
pub struct CounterFold {
    last: u64,
}

impl CounterFold {
    pub fn new() -> Self {
        CounterFold::default()
    }

    pub fn fold(&mut self, cumulative: u64) -> u64 {
        let delta = if cumulative >= self.last {
            cumulative - self.last
        } else {
            cumulative
        };
        self.last = cumulative;
        delta
    }
}

/// JSON array of buckets for `/debug/vars`.
pub fn series_json(buckets: &[Bucket]) -> Json {
    Json::Arr(buckets.iter().map(|b| b.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tick(ring: &TsRing, sec: u64, tokens: u64) {
        ring.record_at(sec, |b| b.tokens += tokens);
    }

    #[test]
    fn buckets_accumulate_within_a_second() {
        let ring = TsRing::new(8);
        tick(&ring, 10, 3);
        tick(&ring, 10, 4);
        let snap = ring.snapshot(8);
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].sec, 10);
        assert_eq!(snap[0].tokens, 7);
    }

    #[test]
    fn forward_rotation_zeroes_skipped_seconds() {
        let ring = TsRing::new(8);
        tick(&ring, 100, 1);
        tick(&ring, 103, 5); // skips 101, 102
        let snap = ring.snapshot(8);
        assert_eq!(
            snap.iter().map(|b| (b.sec, b.tokens)).collect::<Vec<_>>(),
            vec![(100, 1), (101, 0), (102, 0), (103, 5)]
        );
    }

    #[test]
    fn jump_past_capacity_clears_the_ring() {
        let ring = TsRing::new(4);
        for s in 0..4 {
            tick(&ring, s, 1);
        }
        tick(&ring, 1000, 9);
        let snap = ring.snapshot(4);
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].sec, 1000);
        assert_eq!(snap[0].tokens, 9);
    }

    #[test]
    fn backward_skew_clamps_into_newest_bucket() {
        let ring = TsRing::new(8);
        tick(&ring, 50, 1);
        tick(&ring, 52, 1);
        tick(&ring, 51, 7); // skewed write: folds into sec 52
        let snap = ring.snapshot(8);
        assert_eq!(
            snap.iter().map(|b| (b.sec, b.tokens)).collect::<Vec<_>>(),
            vec![(50, 1), (51, 0), (52, 8)]
        );
    }

    #[test]
    fn gauges_last_write_wins_counters_accumulate() {
        let ring = TsRing::new(4);
        ring.record_at(7, |b| {
            b.tokens += 2;
            b.queue_depth = 5;
        });
        ring.record_at(7, |b| {
            b.tokens += 3;
            b.queue_depth = 1;
        });
        let snap = ring.snapshot(4);
        assert_eq!(snap[0].tokens, 5);
        assert_eq!(snap[0].queue_depth, 1);
    }

    #[test]
    fn snapshot_window_clamps_and_orders_oldest_first() {
        let ring = TsRing::new(4);
        for s in 0..10u64 {
            tick(&ring, s, s);
        }
        // Only the last 4 seconds survive; window larger than capacity
        // clamps.
        let snap = ring.snapshot(100);
        assert_eq!(
            snap.iter().map(|b| b.sec).collect::<Vec<_>>(),
            vec![6, 7, 8, 9]
        );
        let snap2 = ring.snapshot(2);
        assert_eq!(
            snap2.iter().map(|b| b.sec).collect::<Vec<_>>(),
            vec![8, 9]
        );
    }

    /// Property: under an arbitrary mix of forward jumps, stalls, and
    /// backward skew, (a) snapshots are strictly increasing in `sec`,
    /// (b) no write is ever lost — total tokens across the live window
    /// equals the sum of writes whose target second is still inside the
    /// window horizon.
    #[test]
    fn property_rotation_under_skew_and_stalls() {
        let mut rng = Rng::new(20260808);
        for trial in 0..50 {
            let cap = 2 + (rng.next_u64() % 14) as usize;
            let ring = TsRing::new(cap);
            let mut clock: u64 = 1_000;
            // Model of what the ring should hold: (sec -> tokens) for
            // every write after clamping, pruned to the live horizon.
            let mut model: Vec<(u64, u64)> = Vec::new();
            let mut newest = 0u64;
            let mut started = false;
            for _ in 0..200 {
                // Clock behaviour: stall (same sec), step, jump, skew.
                match rng.next_u64() % 10 {
                    0..=3 => {}                                  // stall
                    4..=6 => clock += 1,                         // step
                    7 | 8 => clock += rng.next_u64() % (2 * cap as u64 + 2), // jump
                    _ => clock = clock.saturating_sub(1 + rng.next_u64() % 3), // skew
                }
                let amount = 1 + rng.next_u64() % 5;
                tick(&ring, clock, amount);
                // Mirror the clamping contract in the model.
                let eff = if !started {
                    started = true;
                    newest = clock;
                    clock
                } else if clock > newest {
                    newest = clock;
                    clock
                } else {
                    newest
                };
                match model.binary_search_by_key(&eff, |m| m.0) {
                    Ok(i) => model[i].1 += amount,
                    Err(i) => model.insert(i, (eff, amount)),
                }
            }
            let snap = ring.snapshot(cap);
            // (a) strictly increasing, contiguous seconds.
            for w in snap.windows(2) {
                assert_eq!(
                    w[0].sec + 1,
                    w[1].sec,
                    "trial {trial}: snapshot seconds not contiguous"
                );
            }
            assert_eq!(snap.last().map(|b| b.sec), Some(newest));
            // (b) every in-horizon write survived with its full amount.
            let horizon = newest.saturating_sub(cap as u64 - 1);
            for &(sec, tokens) in model.iter().filter(|m| m.0 >= horizon) {
                let got = snap
                    .iter()
                    .find(|b| b.sec == sec)
                    .map(|b| b.tokens)
                    .unwrap_or(0);
                assert_eq!(
                    got, tokens,
                    "trial {trial}: sec {sec} holds {got}, wrote {tokens}"
                );
            }
        }
    }

    #[test]
    fn counter_fold_deltas_and_reset() {
        let mut f = CounterFold::new();
        assert_eq!(f.fold(5), 5);
        assert_eq!(f.fold(5), 0);
        assert_eq!(f.fold(12), 7);
        // Reset: cumulative drops (replica restarted) — the new
        // cumulative is the delta, nothing negative, nothing lost twice.
        assert_eq!(f.fold(3), 3);
        assert_eq!(f.fold(4), 1);
    }

    /// Property: folding any nondecreasing cumulative sequence recovers
    /// exactly the increments (sum of deltas == final cumulative).
    #[test]
    fn property_monotonic_counter_folding() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let mut f = CounterFold::new();
            let mut cum = 0u64;
            let mut total = 0u64;
            for _ in 0..100 {
                cum += rng.next_u64() % 9;
                total += f.fold(cum);
            }
            assert_eq!(total, cum);
        }
    }

    /// Property: cross-replica merge == field-wise sum of per-replica
    /// buckets at every second.
    #[test]
    fn property_cross_replica_merge_equivalence() {
        let mut rng = Rng::new(99);
        for _ in 0..30 {
            let n_replicas = 1 + (rng.next_u64() % 4) as usize;
            let rings: Vec<TsRing> = (0..n_replicas).map(|_| TsRing::new(16)).collect();
            for ring in &rings {
                let mut sec = 500 + rng.next_u64() % 4;
                for _ in 0..40 {
                    if rng.next_u64() % 3 == 0 {
                        sec += rng.next_u64() % 3;
                    }
                    let t = rng.next_u64() % 7;
                    let q = rng.next_u64() % 5;
                    ring.record_at(sec, |b| {
                        b.tokens += t;
                        b.proposed += t;
                        b.accepted += t / 2;
                        b.queue_depth = q;
                        b.serving = 1;
                    });
                }
            }
            let snaps: Vec<Vec<Bucket>> = rings.iter().map(|r| r.snapshot(16)).collect();
            let merged = merge(&snaps);
            // Merged at second s must equal the field-wise sum of every
            // per-replica bucket at s.
            for m in &merged {
                let mut want = Bucket {
                    sec: m.sec,
                    ..Bucket::default()
                };
                for snap in &snaps {
                    if let Some(b) = snap.iter().find(|b| b.sec == m.sec) {
                        want.add(b);
                    }
                }
                assert_eq!(*m, want, "merge diverged at sec {}", m.sec);
            }
            // And merge introduces no phantom seconds.
            for snap in &snaps {
                for b in snap {
                    assert!(merged.iter().any(|m| m.sec == b.sec));
                }
            }
            // Sorted oldest first.
            for w in merged.windows(2) {
                assert!(w[0].sec < w[1].sec);
            }
        }
    }

    #[test]
    fn json_shape_includes_accept_rate() {
        let ring = TsRing::new(4);
        ring.record_at(3, |b| {
            b.proposed += 4;
            b.accepted += 3;
        });
        let j = series_json(&ring.snapshot(4));
        let s = j.to_string();
        assert!(s.contains("\"accept_rate\":0.75"), "{s}");
        assert!(s.contains("\"sec\":3"), "{s}");
    }
}
