//! Chrome trace-event-format export.
//!
//! `GET /trace/{request_id}` returns the JSON object form of the format
//! (`{"traceEvents": [...]}`) so it loads directly in `chrome://tracing`
//! and Perfetto's legacy importer. Every span becomes a complete event
//! (`"ph": "X"`) with microsecond `ts`/`dur` relative to the request's
//! submit instant; the request is one process (`pid` = request id) with
//! a single track (`tid` 0), so `ts` is monotone per track by
//! construction — the worker records stages in execution order.

use crate::util::json::Json;

use super::{rungs_str, RequestTrace, Rung, Span, SpanKind};

fn span_args(s: &Span) -> Json {
    let mut pairs = vec![("iter", Json::num(s.iter as f64))];
    match s.kind {
        SpanKind::Forward => {
            let rung = match s.a {
                x if x == Rung::Inc as u64 => Rung::Inc,
                x if x == Rung::Ord as u64 => Rung::Ord,
                _ => Rung::Dense,
            };
            pairs.push(("rung", Json::str(rung.name())));
            pairs.push(("batch", Json::num(s.b as f64)));
        }
        SpanKind::Draft => {
            pairs.push(("window", Json::num(s.a as f64)));
            pairs.push(("aux_nfe", Json::num(s.b as f64)));
        }
        SpanKind::Verify => {
            pairs.push(("accepted", Json::num(s.a as f64)));
            pairs.push(("proposed", Json::num(s.b as f64)));
        }
        SpanKind::Decode | SpanKind::Commit => {
            pairs.push(("tokens", Json::num(s.a as f64)));
        }
        SpanKind::Admit => {
            pairs.push(("n_targets", Json::num(s.a as f64)));
        }
        SpanKind::QueueWait => {}
    }
    Json::obj(pairs)
}

/// Render one request's trace as a Chrome trace-event JSON object.
pub fn trace_json(t: &RequestTrace) -> Json {
    let pid = t.request_id as f64;
    let mut events: Vec<Json> = Vec::with_capacity(t.spans.len() + 2);
    // Metadata events name the process/track in the viewer UI.
    events.push(Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid)),
        ("tid", Json::num(0.0)),
        (
            "args",
            Json::obj(vec![(
                "name",
                Json::str(format!("request {} ({})", t.request_id, t.sampler)),
            )]),
        ),
    ]));
    events.push(Json::obj(vec![
        ("name", Json::str("thread_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid)),
        ("tid", Json::num(0.0)),
        (
            "args",
            Json::obj(vec![("name", Json::str(format!("replica {}", t.replica)))]),
        ),
    ]));
    for s in &t.spans {
        events.push(Json::obj(vec![
            ("name", Json::str(s.kind.name())),
            ("cat", Json::str("request")),
            ("ph", Json::str("X")),
            ("ts", Json::num(s.start_us as f64)),
            ("dur", Json::num(s.dur_us as f64)),
            ("pid", Json::num(pid)),
            ("tid", Json::num(0.0)),
            ("args", span_args(s)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        ("otherData", t.summary_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TraceBuilder;
    use std::time::Instant;

    fn sample_trace() -> RequestTrace {
        let mut b = TraceBuilder::new(42, 1, "assd", Instant::now(), 32);
        b.push_at(SpanKind::QueueWait, 0, 0, 120, 0, 0);
        b.push_at(SpanKind::Admit, 0, 120, 30, 8, 0);
        b.push_at(SpanKind::Forward, 1, 150, 400, Rung::Inc as u64, 2);
        b.push_at(SpanKind::Draft, 1, 550, 25, 5, 0);
        b.push_at(SpanKind::Forward, 1, 575, 380, Rung::Inc as u64, 2);
        b.push_at(SpanKind::Verify, 1, 955, 40, 4, 5);
        b.push_at(SpanKind::Commit, 1, 995, 5, 5, 0);
        b.note_rung(Rung::Inc);
        b.add_commits(5);
        b.finish(true, 2, 0, 1, 5, 4, "self".to_string())
    }

    #[test]
    fn output_is_valid_json_with_trace_events() {
        let t = sample_trace();
        let s = trace_json(&t).to_string();
        let parsed = Json::parse(&s).expect("chrome trace must be valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata events + 7 spans.
        assert_eq!(events.len(), 9);
        assert_eq!(
            parsed.get("otherData").unwrap().get("model_nfe").unwrap().as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn ts_is_monotone_per_track() {
        let t = sample_trace();
        let rendered = trace_json(&t);
        let events = rendered.get("traceEvents").unwrap().as_arr().unwrap();
        let mut last_ts = f64::NEG_INFINITY;
        for e in events {
            if e.get("ph").unwrap().as_str() == Some("M") {
                continue;
            }
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last_ts, "ts regressed: {ts} < {last_ts}");
            last_ts = ts;
        }
    }

    #[test]
    fn forward_spans_carry_rung_names() {
        let t = sample_trace();
        let rendered = trace_json(&t);
        let events = rendered.get("traceEvents").unwrap().as_arr().unwrap();
        let fwd: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("name").unwrap().as_str() == Some("forward"))
            .collect();
        assert_eq!(fwd.len(), 2);
        for f in fwd {
            assert_eq!(f.get("args").unwrap().get("rung").unwrap().as_str(), Some("inc"));
        }
        assert_eq!(rungs_str(t.rungs), "inc");
    }
}
