//! Speculation flight recorder: sampled per-request capture of *why*
//! speculative tokens were accepted or rejected, not just when.
//!
//! PR 7's span tracer records phase timings; this layer records the
//! decode-quality signals those phases throw away — per-window-position
//! accept/reject outcomes, the rejection cause (residual resample vs
//! numerically-empty residual), and the draft/target predictive
//! entropies of each verified row — then folds them into a positional
//! acceptance heatmap (accept rate × window position × drafter) and
//! entropy-bucketed acceptance curves. Those aggregates are exactly the
//! evidence the ROADMAP's "dependency-guided order and window
//! selection" item needs before order sampling or window membership can
//! be biased by per-position signal.
//!
//! Design constraints, in order:
//!
//! 1. **Bit-identity by construction.** The machines write flight
//!    events only through the thread-local tap below, and only compute
//!    the (O(vocab)) row entropies when the tap is enabled. Every read
//!    is of a buffer the machine already filled for sampling
//!    (`q_buf`, the drafter's distributions, `prob_buf`); the decode
//!    RNG is never touched. Whether the recorder is on or off therefore
//!    cannot change a single sampled token — proven across every
//!    sampler × drafter by `flight_on_vs_off_outputs_bit_identical` in
//!    the scheduler tests.
//! 2. **No signature changes.** Machines stay behind the existing
//!    `DecodeMachine` trait; the scheduler worker arms the tap around
//!    `absorb` and drains it after, exactly like the engine-side
//!    [`super::tap`] (machines are thread-pinned to their worker).
//! 3. **Bounded memory.** Requests are sampled by a deterministic hash
//!    of the request id (`--flight-sample-rate`); per-request window
//!    records are capped ([`WINDOW_CAP`]) with drop counting; retired
//!    records live in a fixed drop-oldest ring per replica
//!    (`--flight-capacity`), mirroring `SpanRecorder`.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;
use crate::util::stats::Histogram;

// ---------------------------------------------------------------------
// Thread-local tap (machine side)
// ---------------------------------------------------------------------

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static EVENTS: RefCell<Vec<FlightEvent>> = const { RefCell::new(Vec::new()) };
}

/// Is the current slot's absorb being flight-recorded? Machines gate
/// all event construction (and the entropy computations feeding it)
/// behind this — when false the decode path does no extra work.
#[inline]
pub fn enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Arm (or disarm) the tap for the absorb the worker is about to run.
/// Arming always starts from an empty buffer so events from a previous
/// absorb that never drained (e.g. a machine panic unwound past the
/// drain) cannot leak into the next request's record.
pub fn begin(on: bool) {
    ENABLED.with(|e| e.set(on));
    EVENTS.with(|ev| ev.borrow_mut().clear());
}

/// Append an event (no-op when the tap is disarmed).
pub fn record(ev: FlightEvent) {
    if !enabled() {
        return;
    }
    EVENTS.with(|e| e.borrow_mut().push(ev));
}

/// Disarm and drain everything recorded since [`begin`].
pub fn take(into: &mut Vec<FlightEvent>) {
    ENABLED.with(|e| e.set(false));
    EVENTS.with(|e| into.append(&mut e.borrow_mut()));
}

/// Clear all tap state (worker start).
pub fn reset() {
    ENABLED.with(|e| e.set(false));
    EVENTS.with(|e| e.borrow_mut().clear());
}

// ---------------------------------------------------------------------
// Events (what a machine emits per absorb)
// ---------------------------------------------------------------------

/// Why a window position's verification ended the way it did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowOutcome {
    /// `r < min(1, q/p)` — the drafted token stands.
    Accepted,
    /// Rejected with a non-empty residual: resampled from `(q - p)_+`
    /// (the principled correction — target and draft genuinely
    /// disagreed on this row).
    RejectedResidual,
    /// Rejected but the residual was numerically empty (`q == p` to
    /// float precision): resampled from `q` directly. A "rejection"
    /// that carries no distributional disagreement.
    RejectedFull,
}

impl WindowOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            WindowOutcome::Accepted => "accept",
            WindowOutcome::RejectedResidual => "reject_residual",
            WindowOutcome::RejectedFull => "reject_full",
        }
    }

    pub fn is_accept(&self) -> bool {
        matches!(self, WindowOutcome::Accepted)
    }
}

/// One verified window position: the outcome plus the signals the
/// verify pass already held in its buffers.
#[derive(Clone, Copy, Debug)]
pub struct PosOutcome {
    pub outcome: WindowOutcome,
    /// Shannon entropy (nats) of the drafter's distribution for this row.
    pub draft_entropy: f32,
    /// Shannon entropy (nats) of the target (verify-pass) distribution.
    pub target_entropy: f32,
    /// `min(1, q_i/p_i)` — the acceptance probability the test used.
    pub accept_prob: f32,
}

/// What one absorb contributes to the flight record.
#[derive(Clone, Debug)]
pub enum FlightEvent {
    /// A speculation window's verification (or the Lemma-1 shortcut,
    /// which is a size-1 window accepted by construction). `outcomes`
    /// covers positions up to and including the first rejection;
    /// later positions were rolled back unverified.
    Window {
        size: usize,
        outcomes: Vec<PosOutcome>,
    },
    /// One sampled row of a non-speculative machine (sequential /
    /// diffusion): no accept test, but the target entropy still feeds
    /// the per-request record.
    Decode { target_entropy: f32 },
}

/// Shannon entropy in nats of a (not necessarily exactly normalised)
/// probability vector. Pure read — callers gate on [`enabled`] since
/// this is O(len).
pub fn entropy(probs: &[f32]) -> f32 {
    let mut h = 0.0f64;
    for &p in probs {
        if p > 0.0 {
            let p = p as f64;
            h -= p * p.ln();
        }
    }
    h as f32
}

// ---------------------------------------------------------------------
// Request sampling
// ---------------------------------------------------------------------

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic per-request sampling decision: a hash of the request
/// id against `rate`, never the decode RNG (which must stay
/// bit-identical whether or not the recorder runs). Same id + rate ⇒
/// same decision on every replica and every retry.
pub fn sampled(request_id: u64, rate: f64) -> bool {
    if rate <= 0.0 {
        return false;
    }
    if rate >= 1.0 {
        return true;
    }
    let h = splitmix64(request_id);
    ((h >> 11) as f64 / (1u64 << 53) as f64) < rate
}

// ---------------------------------------------------------------------
// Per-request record
// ---------------------------------------------------------------------

/// Per-request cap on retained window records; further windows are
/// counted as dropped, never stored (the bounded-memory contract).
pub const WINDOW_CAP: usize = 512;

/// One speculation window as retained in the record.
#[derive(Clone, Debug)]
pub struct WindowRecord {
    pub size: u32,
    pub outcomes: Vec<PosOutcome>,
}

/// A retired request's flight record.
#[derive(Clone, Debug)]
pub struct FlightRecord {
    pub request_id: u64,
    pub replica: usize,
    pub sampler: &'static str,
    pub drafter: String,
    pub completed: bool,
    pub windows: Vec<WindowRecord>,
    pub dropped_windows: u64,
    pub decode_rows: u64,
    pub decode_entropy_sum: f64,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
}

impl FlightRecord {
    pub fn proposed(&self) -> u64 {
        self.windows.iter().map(|w| w.outcomes.len() as u64).sum()
    }

    pub fn accepted(&self) -> u64 {
        self.windows
            .iter()
            .flat_map(|w| &w.outcomes)
            .filter(|o| o.outcome.is_accept())
            .count() as u64
    }

    /// Full record for `GET /debug/flight/{id}`.
    pub fn to_json(&self) -> Json {
        let windows = Json::Arr(
            self.windows
                .iter()
                .map(|w| {
                    let positions = Json::Arr(
                        w.outcomes
                            .iter()
                            .enumerate()
                            .map(|(i, o)| {
                                Json::obj(vec![
                                    ("pos", Json::num(i as f64)),
                                    ("outcome", Json::str(o.outcome.name())),
                                    ("draft_entropy", Json::num(o.draft_entropy as f64)),
                                    ("target_entropy", Json::num(o.target_entropy as f64)),
                                    ("accept_prob", Json::num(o.accept_prob as f64)),
                                ])
                            })
                            .collect(),
                    );
                    Json::obj(vec![
                        ("size", Json::num(w.size as f64)),
                        ("positions", positions),
                    ])
                })
                .collect(),
        );
        let trajectory = Json::Arr(
            self.windows
                .iter()
                .map(|w| Json::num(w.size as f64))
                .collect(),
        );
        let mean_decode_entropy = if self.decode_rows > 0 {
            self.decode_entropy_sum / self.decode_rows as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("request_id", Json::num(self.request_id as f64)),
            ("replica", Json::num(self.replica as f64)),
            ("sampler", Json::str(self.sampler)),
            ("drafter", Json::str(self.drafter.clone())),
            ("completed", Json::Bool(self.completed)),
            ("proposed", Json::num(self.proposed() as f64)),
            ("accepted", Json::num(self.accepted() as f64)),
            ("windows", windows),
            ("window_trajectory", trajectory),
            ("dropped_windows", Json::num(self.dropped_windows as f64)),
            ("decode_rows", Json::num(self.decode_rows as f64)),
            ("decode_mean_entropy", Json::num(mean_decode_entropy)),
            ("prefix_hits", Json::num(self.prefix_hits as f64)),
            ("prefix_misses", Json::num(self.prefix_misses as f64)),
        ])
    }
}

/// Hot-path builder owned by a scheduler slot (single-threaded, like
/// `TraceBuilder`). `Some(FlightBuilder)` on a slot is the *only*
/// signal that arms the tap for that slot's absorbs.
#[derive(Debug)]
pub struct FlightBuilder {
    record: FlightRecord,
}

impl FlightBuilder {
    pub fn new(request_id: u64, replica: usize, sampler: &'static str) -> Self {
        FlightBuilder {
            record: FlightRecord {
                request_id,
                replica,
                sampler,
                drafter: String::new(),
                completed: false,
                windows: Vec::new(),
                dropped_windows: 0,
                decode_rows: 0,
                decode_entropy_sum: 0.0,
                prefix_hits: 0,
                prefix_misses: 0,
            },
        }
    }

    pub fn request_id(&self) -> u64 {
        self.record.request_id
    }

    /// Disarm the thread-local tap and fold everything the machine
    /// emitted during the absorb into this record.
    pub fn drain_tap(&mut self) {
        let mut events = Vec::new();
        take(&mut events);
        for ev in events {
            match ev {
                FlightEvent::Window { size, outcomes } => {
                    if self.record.windows.len() < WINDOW_CAP {
                        self.record.windows.push(WindowRecord {
                            size: size as u32,
                            outcomes,
                        });
                    } else {
                        self.record.dropped_windows += 1;
                    }
                }
                FlightEvent::Decode { target_entropy } => {
                    self.record.decode_rows += 1;
                    self.record.decode_entropy_sum += target_entropy as f64;
                }
            }
        }
    }

    pub fn note_prefix_probe(&mut self, hit: bool) {
        if hit {
            self.record.prefix_hits += 1;
        } else {
            self.record.prefix_misses += 1;
        }
    }

    pub fn finish(mut self, completed: bool, drafter: String) -> FlightRecord {
        self.record.completed = completed;
        self.record.drafter = drafter;
        self.record
    }
}

// ---------------------------------------------------------------------
// Aggregates: positional heatmap + entropy-bucketed acceptance curves
// ---------------------------------------------------------------------

/// Positional heatmap width: window positions at or beyond this clamp
/// into the last cell (adaptive windows in this codebase are far
/// smaller; the clamp just bounds the export).
pub const MAX_HEAT_POS: usize = 32;

/// Target-entropy bucket upper bounds (nats) for the acceptance curves;
/// one overflow bucket past the last bound.
pub const ENTROPY_BOUNDS: [f64; 7] = [0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

fn entropy_bucket(e: f64) -> usize {
    ENTROPY_BOUNDS
        .iter()
        .position(|&b| e <= b)
        .unwrap_or(ENTROPY_BOUNDS.len())
}

/// Per-drafter acceptance aggregates, folded at record time and merged
/// across replicas at export time.
#[derive(Clone, Debug)]
pub struct DrafterHeat {
    pub drafter: String,
    pub windows: u64,
    /// `(proposed, accepted)` by window position (clamped to
    /// [`MAX_HEAT_POS`] cells).
    pub pos: Vec<(u64, u64)>,
    /// `(proposed, accepted)` by target-entropy bucket
    /// ([`ENTROPY_BOUNDS`] + overflow).
    pub entropy: Vec<(u64, u64)>,
    /// Target-entropy distribution over verified rows (Prometheus
    /// histogram export).
    pub target_entropy: Histogram,
}

impl DrafterHeat {
    fn new(drafter: &str) -> Self {
        DrafterHeat {
            drafter: drafter.to_string(),
            windows: 0,
            pos: vec![(0, 0); MAX_HEAT_POS],
            entropy: vec![(0, 0); ENTROPY_BOUNDS.len() + 1],
            target_entropy: Histogram::with_bounds(ENTROPY_BOUNDS.to_vec()),
        }
    }

    fn fold(&mut self, rec: &FlightRecord) {
        for w in &rec.windows {
            self.windows += 1;
            for (i, o) in w.outcomes.iter().enumerate() {
                let cell = &mut self.pos[i.min(MAX_HEAT_POS - 1)];
                cell.0 += 1;
                let e = o.target_entropy as f64;
                let eb = &mut self.entropy[entropy_bucket(e)];
                eb.0 += 1;
                if o.outcome.is_accept() {
                    cell.1 += 1;
                    eb.1 += 1;
                }
                self.target_entropy.record(e);
            }
        }
    }

    fn merge(&mut self, other: &DrafterHeat) {
        self.windows += other.windows;
        for (a, b) in self.pos.iter_mut().zip(&other.pos) {
            a.0 += b.0;
            a.1 += b.1;
        }
        for (a, b) in self.entropy.iter_mut().zip(&other.entropy) {
            a.0 += b.0;
            a.1 += b.1;
        }
        self.target_entropy.merge(&other.target_entropy);
    }

    /// JSON for `/debug/vars`: positional cells and entropy curve, with
    /// accept rates precomputed (the dashboard charts these directly).
    pub fn to_json(&self) -> Json {
        let rate = |p: u64, a: u64| {
            if p > 0 {
                a as f64 / p as f64
            } else {
                0.0
            }
        };
        let positions = Json::Arr(
            self.pos
                .iter()
                .enumerate()
                .filter(|(_, c)| c.0 > 0)
                .map(|(i, &(p, a))| {
                    Json::obj(vec![
                        ("pos", Json::num(i as f64)),
                        ("proposed", Json::num(p as f64)),
                        ("accepted", Json::num(a as f64)),
                        ("accept_rate", Json::num(rate(p, a))),
                    ])
                })
                .collect(),
        );
        let curve = Json::Arr(
            self.entropy
                .iter()
                .enumerate()
                .map(|(i, &(p, a))| {
                    let le = ENTROPY_BOUNDS
                        .get(i)
                        .map(|b| b.to_string())
                        .unwrap_or_else(|| "+Inf".to_string());
                    Json::obj(vec![
                        ("le", Json::str(le)),
                        ("proposed", Json::num(p as f64)),
                        ("accepted", Json::num(a as f64)),
                        ("accept_rate", Json::num(rate(p, a))),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("drafter", Json::str(self.drafter.clone())),
            ("windows", Json::num(self.windows as f64)),
            ("positions", positions),
            ("entropy_curve", curve),
        ])
    }
}

/// Merge per-replica heat snapshots into one pool view, aligned by
/// drafter name and sorted for stable export order.
pub fn merge_heat(snaps: Vec<Vec<DrafterHeat>>) -> Vec<DrafterHeat> {
    let mut merged: Vec<DrafterHeat> = Vec::new();
    for snap in snaps {
        for h in snap {
            match merged.iter_mut().find(|m| m.drafter == h.drafter) {
                Some(m) => m.merge(&h),
                None => merged.push(h),
            }
        }
    }
    merged.sort_by(|a, b| a.drafter.cmp(&b.drafter));
    merged
}

pub fn heat_json(heat: &[DrafterHeat]) -> Json {
    Json::Arr(heat.iter().map(|h| h.to_json()).collect())
}

// ---------------------------------------------------------------------
// Per-replica recorder
// ---------------------------------------------------------------------

struct RecorderInner {
    ring: VecDeque<Arc<FlightRecord>>,
    recorded: u64,
    dropped: u64,
    heat: Vec<DrafterHeat>,
}

/// Fixed-capacity, drop-oldest ring of retired flight records plus the
/// running heat aggregates — one per replica, `SpanRecorder`-shaped.
/// Aggregates survive ring eviction (they fold at record time), so the
/// heatmap covers every sampled request since boot, not just the ring.
pub struct FlightRecorder {
    inner: Mutex<RecorderInner>,
    capacity: usize,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> Self {
        FlightRecorder {
            inner: Mutex::new(RecorderInner {
                ring: VecDeque::with_capacity(capacity.max(1)),
                recorded: 0,
                dropped: 0,
                heat: Vec::new(),
            }),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn record(&self, rec: FlightRecord) {
        let mut g = self.inner.lock().unwrap();
        g.recorded += 1;
        match g.heat.iter_mut().find(|h| h.drafter == rec.drafter) {
            Some(h) => h.fold(&rec),
            None => {
                let mut h = DrafterHeat::new(&rec.drafter);
                h.fold(&rec);
                g.heat.push(h);
            }
        }
        if g.ring.len() == self.capacity {
            g.ring.pop_front();
            g.dropped += 1;
        }
        g.ring.push_back(Arc::new(rec));
    }

    pub fn get(&self, request_id: u64) -> Option<Arc<FlightRecord>> {
        let g = self.inner.lock().unwrap();
        g.ring
            .iter()
            .rev()
            .find(|r| r.request_id == request_id)
            .cloned()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn recorded(&self) -> u64 {
        self.inner.lock().unwrap().recorded
    }

    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    pub fn heat(&self) -> Vec<DrafterHeat> {
        self.inner.lock().unwrap().heat.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pos(outcome: WindowOutcome, target_entropy: f32) -> PosOutcome {
        PosOutcome {
            outcome,
            draft_entropy: 0.5,
            target_entropy,
            accept_prob: 0.9,
        }
    }

    fn record_with(id: u64, drafter: &str, windows: Vec<WindowRecord>) -> FlightRecord {
        FlightRecord {
            request_id: id,
            replica: 0,
            sampler: "assd",
            drafter: drafter.to_string(),
            completed: true,
            windows,
            dropped_windows: 0,
            decode_rows: 0,
            decode_entropy_sum: 0.0,
            prefix_hits: 0,
            prefix_misses: 0,
        }
    }

    #[test]
    fn tap_is_inert_when_disarmed() {
        reset();
        assert!(!enabled());
        record(FlightEvent::Decode { target_entropy: 1.0 });
        let mut out = Vec::new();
        take(&mut out);
        assert!(out.is_empty(), "disarmed tap must record nothing");
    }

    #[test]
    fn tap_begin_clears_stale_events() {
        reset();
        begin(true);
        record(FlightEvent::Decode { target_entropy: 1.0 });
        // Simulate a panic unwinding past the drain: begin() for the
        // next absorb must not see the stale event.
        begin(true);
        record(FlightEvent::Decode { target_entropy: 2.0 });
        let mut out = Vec::new();
        take(&mut out);
        assert_eq!(out.len(), 1);
        assert!(!enabled(), "take disarms");
    }

    #[test]
    fn entropy_matches_closed_forms() {
        // Uniform over k: ln k. Point mass: 0.
        let u4 = [0.25f32; 4];
        assert!((entropy(&u4) - (4.0f32).ln()).abs() < 1e-6);
        assert_eq!(entropy(&[1.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn sampling_is_deterministic_and_rate_shaped() {
        assert!(!sampled(1, 0.0));
        assert!(sampled(1, 1.0));
        for id in 0..100u64 {
            assert_eq!(sampled(id, 0.3), sampled(id, 0.3));
        }
        let hits = (0..10_000u64).filter(|&id| sampled(id, 0.25)).count();
        assert!(
            (1_500..=3_500).contains(&hits),
            "rate 0.25 sampled {hits}/10000"
        );
    }

    #[test]
    fn builder_caps_windows_with_drop_counting() {
        begin(true);
        for _ in 0..(WINDOW_CAP + 5) {
            record(FlightEvent::Window {
                size: 2,
                outcomes: vec![pos(WindowOutcome::Accepted, 1.0)],
            });
        }
        let mut b = FlightBuilder::new(7, 0, "assd");
        b.drain_tap();
        let rec = b.finish(true, "self".to_string());
        assert_eq!(rec.windows.len(), WINDOW_CAP);
        assert_eq!(rec.dropped_windows, 5);
    }

    #[test]
    fn recorder_ring_drops_oldest_and_keeps_aggregates() {
        let rec = FlightRecorder::new(2);
        for id in 1..=5u64 {
            rec.record(record_with(
                id,
                "self",
                vec![WindowRecord {
                    size: 2,
                    outcomes: vec![
                        pos(WindowOutcome::Accepted, 0.3),
                        pos(WindowOutcome::RejectedResidual, 2.5),
                    ],
                }],
            ));
        }
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.recorded(), 5);
        assert_eq!(rec.dropped(), 3);
        assert!(rec.get(1).is_none(), "evicted");
        assert!(rec.get(5).is_some());
        // Aggregates cover all 5 records despite eviction.
        let heat = rec.heat();
        assert_eq!(heat.len(), 1);
        assert_eq!(heat[0].windows, 5);
        assert_eq!(heat[0].pos[0], (5, 5), "position 0 all accepted");
        assert_eq!(heat[0].pos[1], (5, 0), "position 1 all rejected");
        // Entropy curve: 0.3 -> bucket le=0.5 accepted; 2.5 -> le=3.0
        // rejected.
        assert_eq!(heat[0].entropy[0], (5, 5));
        assert_eq!(heat[0].entropy[entropy_bucket(2.5)], (5, 0));
        assert_eq!(heat[0].target_entropy.count(), 10);
    }

    #[test]
    fn heat_merge_is_field_wise_sum_across_replicas() {
        let a = FlightRecorder::new(8);
        let b = FlightRecorder::new(8);
        a.record(record_with(
            1,
            "self",
            vec![WindowRecord {
                size: 1,
                outcomes: vec![pos(WindowOutcome::Accepted, 1.2)],
            }],
        ));
        b.record(record_with(
            2,
            "self",
            vec![WindowRecord {
                size: 1,
                outcomes: vec![pos(WindowOutcome::RejectedFull, 1.2)],
            }],
        ));
        b.record(record_with(
            3,
            "bigram",
            vec![WindowRecord {
                size: 1,
                outcomes: vec![pos(WindowOutcome::Accepted, 0.1)],
            }],
        ));
        let merged = merge_heat(vec![a.heat(), b.heat()]);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].drafter, "bigram", "sorted by drafter");
        let self_heat = &merged[1];
        assert_eq!(self_heat.windows, 2);
        assert_eq!(self_heat.pos[0], (2, 1));
        assert_eq!(self_heat.target_entropy.count(), 2);
    }

    #[test]
    fn record_json_carries_outcome_taxonomy() {
        let rec = record_with(
            9,
            "self",
            vec![WindowRecord {
                size: 3,
                outcomes: vec![
                    pos(WindowOutcome::Accepted, 0.4),
                    pos(WindowOutcome::RejectedResidual, 2.0),
                ],
            }],
        );
        let s = rec.to_json().to_string();
        assert!(s.contains("\"outcome\":\"accept\""), "{s}");
        assert!(s.contains("\"outcome\":\"reject_residual\""), "{s}");
        assert!(s.contains("\"window_trajectory\":[3]"), "{s}");
        assert!(s.contains("\"proposed\":2"), "{s}");
        assert!(s.contains("\"accepted\":1"), "{s}");
    }
}
