//! Observability substrate: per-request tracing and NFE accounting.
//!
//! The serving claims of the paper are *per-request* quantities — Theorem
//! 2's NFE bound, the adaptive window's trajectory, the prefix cache's
//! hit economics — but pool-wide averages (the `/metrics` endpoint) erase
//! exactly the granularity where they are decided. This module records a
//! typed span timeline per request and keeps the last N completed traces
//! per replica in a fixed-capacity ring, exported as Chrome
//! trace-event-format JSON (`GET /trace/{request_id}`, loadable in
//! `chrome://tracing` / Perfetto) and folded into Prometheus text
//! exposition on `/metrics`.
//!
//! Design constraints, in order:
//!
//! 1. **Zero hot-path allocation.** A [`TraceBuilder`] pre-allocates its
//!    span buffer at admission; once the cap is reached further spans are
//!    counted as dropped, never reallocated. The scheduler worker is the
//!    only writer, so the builder needs no locks at all.
//! 2. **Lock-light publication.** The per-replica [`SpanRecorder`] ring
//!    takes its mutex exactly twice per request lifetime: once when the
//!    finished trace is pushed, and once per HTTP read. Nothing on the
//!    per-iteration path touches it.
//! 3. **Bit-identity.** Tracing only *observes* — timers and counters
//!    around the decode loop, never inside the sampling path — so traced
//!    and untraced runs produce identical tokens, NFE, and speculation
//!    counters (proven by `tracing_on_vs_off_bit_identity` in the
//!    scheduler tests).
//!
//! Engine-side attribution (which fallback rung actually ran, whether a
//! lane's first forward hit the prefix cache) flows through the
//! thread-local taps in [`tap`] — engines are thread-pinned (the PJRT
//! client is single-threaded), so a thread-local written by the engine
//! and drained by the scheduler worker on the same thread is exact.

pub mod chrome;
pub mod flight;
pub mod prometheus;
pub mod tap;
pub mod timeseries;

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Which rung of the inc→ord→dense forward fallback ladder actually
/// served a batched call. Ordered weakest-first so a mixed call (part of
/// the batch routed to the dense fallback) reports the weakest rung that
/// ran — the pessimistic answer is the one worth alerting on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rung {
    /// Full-grid dense fallback (`forward_ord_dense`): O(N²) mask traffic.
    Dense = 0,
    /// Compact path (`forward_ord`): indices over, gathered rows back.
    Ord = 1,
    /// Incremental path (`forward_inc`): persistent per-lane K/V cache.
    Inc = 2,
}

impl Rung {
    pub fn name(&self) -> &'static str {
        match self {
            Rung::Dense => "dense",
            Rung::Ord => "ord",
            Rung::Inc => "inc",
        }
    }

    /// Bitmask bit for [`RequestTrace::rungs`].
    pub fn bit(&self) -> u8 {
        1 << (*self as u8)
    }
}

/// Span taxonomy — one variant per request lifecycle stage. The `a`/`b`
/// argument slots of [`Span`] are kind-specific (documented per variant)
/// so a span stays a fixed-size `Copy` record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Submit → admission. No args.
    QueueWait,
    /// Tokenize + ordering + machine construction. `a` = target count.
    Admit,
    /// One batched engine call this request rode in. `a` = [`Rung`] as
    /// u64, `b` = batch size (the duration is the whole batched call —
    /// batch-mates share it).
    Forward,
    /// Draft-phase absorb: window sampling + proposal. `a` = speculation
    /// window length, `b` = aux-NFE delta (external drafters).
    Draft,
    /// Verify-phase absorb: accept/reject + residual resample. `a` =
    /// accepted this iteration, `b` = proposed this iteration.
    Verify,
    /// Generic absorb for non-speculative machines (sequential,
    /// diffusion). `a` = tokens sampled this step.
    Decode,
    /// Commit drain + lifecycle event emission. `a` = tokens committed.
    Commit,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Admit => "admit",
            SpanKind::Forward => "forward",
            SpanKind::Draft => "draft",
            SpanKind::Verify => "verify",
            SpanKind::Decode => "decode",
            SpanKind::Commit => "commit",
        }
    }
}

/// One timed stage. Timestamps are microseconds since the request was
/// submitted (so every trace starts at ts 0 and is monotone by
/// construction: the single worker thread records stages in the order it
/// executes them).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub kind: SpanKind,
    /// Decode-loop iteration this span belongs to (0 for pre-loop spans).
    pub iter: u32,
    pub start_us: u64,
    pub dur_us: u64,
    /// Kind-specific args — see [`SpanKind`].
    pub a: u64,
    pub b: u64,
}

/// A completed (or aborted) request's trace: the span timeline plus the
/// per-request counters that make the paper's invariants observable.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub request_id: u64,
    pub replica: usize,
    pub sampler: &'static str,
    pub draft_kind: String,
    pub spans: Vec<Span>,
    /// Spans beyond the pre-allocated cap (counted, never stored — the
    /// no-hot-path-allocation contract).
    pub dropped_spans: u64,
    pub tokens_committed: u64,
    pub model_nfe: u64,
    pub aux_nfe: u64,
    pub iterations: u64,
    pub proposed: u64,
    pub accepted: u64,
    /// Adaptive-window trajectory summary (full trajectory lives in the
    /// Draft spans' `a` args): min/max/final window over the request.
    pub window_min: u64,
    pub window_max: u64,
    pub window_last: u64,
    /// Prefix-cache attribution for this request's lane seeding.
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// Bitmask of [`Rung::bit`]s observed across the request's forwards.
    pub rungs: u8,
    /// False when the request was aborted (cancel/deadline/disconnect/
    /// engine failure) before decoding finished.
    pub completed: bool,
    /// Theorem 2, checked per request: `model_nfe <= tokens_committed`.
    /// Only meaningful for completed requests (a request aborted between
    /// a draft forward and its commits legitimately sits one NFE ahead).
    pub theorem2_ok: bool,
    /// Submit → retirement, microseconds.
    pub total_us: u64,
}

impl RequestTrace {
    /// Wall-clock totals per phase (microseconds) — the per-request view
    /// the pool-level phase histograms aggregate.
    pub fn phase_us(&self, kind: SpanKind) -> u64 {
        self.spans
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.dur_us)
            .sum()
    }

    /// One-line index entry for `GET /trace/recent`.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("request_id", Json::num(self.request_id as f64)),
            ("replica", Json::num(self.replica as f64)),
            ("sampler", Json::str(self.sampler)),
            ("draft", Json::str(self.draft_kind.clone())),
            ("completed", Json::Bool(self.completed)),
            ("tokens_committed", Json::num(self.tokens_committed as f64)),
            ("model_nfe", Json::num(self.model_nfe as f64)),
            ("aux_nfe", Json::num(self.aux_nfe as f64)),
            ("iterations", Json::num(self.iterations as f64)),
            ("proposed", Json::num(self.proposed as f64)),
            ("accepted", Json::num(self.accepted as f64)),
            ("theorem2_ok", Json::Bool(self.theorem2_ok)),
            ("prefix_hits", Json::num(self.prefix_hits as f64)),
            ("prefix_misses", Json::num(self.prefix_misses as f64)),
            ("rungs", Json::str(rungs_str(self.rungs))),
            ("spans", Json::num(self.spans.len() as f64)),
            ("dropped_spans", Json::num(self.dropped_spans as f64)),
            ("total_us", Json::num(self.total_us as f64)),
        ])
    }
}

/// Human form of the rung bitmask ("inc", "inc|dense", "-" when no
/// forward ran).
pub fn rungs_str(mask: u8) -> String {
    let mut parts = vec![];
    for r in [Rung::Inc, Rung::Ord, Rung::Dense] {
        if mask & r.bit() != 0 {
            parts.push(r.name());
        }
    }
    if parts.is_empty() {
        "-".to_string()
    } else {
        parts.join("|")
    }
}

/// Per-request span cap: enough for queue/admit plus ~4 spans per
/// iteration over a full window-1 decode of the largest artifact window,
/// without ever growing mid-request.
pub const DEFAULT_SPAN_CAP: usize = 2048;

/// The hot-path trace writer owned by a scheduler slot. Single-threaded
/// by construction (one worker drives one slot); all buffers are
/// pre-allocated in `new`.
#[derive(Debug)]
pub struct TraceBuilder {
    trace: RequestTrace,
    epoch: Instant,
    span_cap: usize,
}

impl TraceBuilder {
    /// `submitted` anchors ts 0 (queue wait is part of the trace).
    pub fn new(
        request_id: u64,
        replica: usize,
        sampler: &'static str,
        submitted: Instant,
        span_cap: usize,
    ) -> Self {
        TraceBuilder {
            trace: RequestTrace {
                request_id,
                replica,
                sampler,
                draft_kind: String::new(),
                spans: Vec::with_capacity(span_cap),
                dropped_spans: 0,
                tokens_committed: 0,
                model_nfe: 0,
                aux_nfe: 0,
                iterations: 0,
                proposed: 0,
                accepted: 0,
                window_min: u64::MAX,
                window_max: 0,
                window_last: 0,
                prefix_hits: 0,
                prefix_misses: 0,
                rungs: 0,
                completed: false,
                theorem2_ok: true,
                total_us: 0,
            },
            epoch: submitted,
            span_cap,
        }
    }

    pub fn request_id(&self) -> u64 {
        self.trace.request_id
    }

    /// Microseconds since submit (the trace's clock).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros().min(u128::from(u64::MAX)) as u64
    }

    /// Record a span that ends now.
    pub fn push(&mut self, kind: SpanKind, iter: u32, start_us: u64, a: u64, b: u64) {
        let dur = self.now_us().saturating_sub(start_us);
        self.push_at(kind, iter, start_us, dur, a, b);
    }

    /// Record a span with an explicit duration (batched forward spans
    /// share one measured duration across batch-mates).
    pub fn push_at(&mut self, kind: SpanKind, iter: u32, start_us: u64, dur_us: u64, a: u64, b: u64) {
        if self.trace.spans.len() >= self.span_cap {
            self.trace.dropped_spans += 1;
            return;
        }
        self.trace.spans.push(Span {
            kind,
            iter,
            start_us,
            dur_us,
            a,
            b,
        });
    }

    pub fn note_rung(&mut self, r: Rung) {
        self.trace.rungs |= r.bit();
    }

    pub fn note_window(&mut self, w: usize) {
        let w = w as u64;
        self.trace.window_min = self.trace.window_min.min(w);
        self.trace.window_max = self.trace.window_max.max(w);
        self.trace.window_last = w;
    }

    pub fn note_prefix_probe(&mut self, hit: bool) {
        if hit {
            self.trace.prefix_hits += 1;
        } else {
            self.trace.prefix_misses += 1;
        }
    }

    pub fn add_commits(&mut self, n: usize) {
        self.trace.tokens_committed += n as u64;
    }

    pub fn tokens_committed(&self) -> u64 {
        self.trace.tokens_committed
    }

    /// Close the trace with the final counters. `completed` = false for
    /// aborted requests; the Theorem-2 flag is only asserted on completed
    /// ones (see [`RequestTrace::theorem2_ok`]).
    #[allow(clippy::too_many_arguments)]
    pub fn finish(
        mut self,
        completed: bool,
        model_nfe: u64,
        aux_nfe: u64,
        iterations: u64,
        proposed: u64,
        accepted: u64,
        draft_kind: String,
    ) -> RequestTrace {
        self.trace.total_us = self.now_us();
        self.trace.completed = completed;
        self.trace.model_nfe = model_nfe;
        self.trace.aux_nfe = aux_nfe;
        self.trace.iterations = iterations;
        self.trace.proposed = proposed;
        self.trace.accepted = accepted;
        self.trace.draft_kind = draft_kind;
        if self.trace.window_min == u64::MAX {
            self.trace.window_min = 0;
        }
        self.trace.theorem2_ok = !completed || model_nfe <= self.trace.tokens_committed;
        self.trace
    }
}

/// Fixed-capacity, drop-oldest ring of completed request traces — one per
/// replica, shared with the HTTP layer behind a mutex that is only taken
/// at request completion and on reads.
#[derive(Debug)]
pub struct SpanRecorder {
    inner: Mutex<VecDeque<Arc<RequestTrace>>>,
    capacity: usize,
}

impl SpanRecorder {
    pub fn new(capacity: usize) -> Self {
        SpanRecorder {
            inner: Mutex::new(VecDeque::with_capacity(capacity.max(1))),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Publish a finished trace, evicting the oldest at capacity.
    pub fn record(&self, t: RequestTrace) {
        let mut ring = self.inner.lock().unwrap();
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(Arc::new(t));
    }

    pub fn get(&self, request_id: u64) -> Option<Arc<RequestTrace>> {
        let ring = self.inner.lock().unwrap();
        ring.iter().rev().find(|t| t.request_id == request_id).cloned()
    }

    /// Newest-first, at most `limit`.
    pub fn recent(&self, limit: usize) -> Vec<Arc<RequestTrace>> {
        let ring = self.inner.lock().unwrap();
        ring.iter().rev().take(limit).cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finished(id: u64, committed: u64, nfe: u64) -> RequestTrace {
        let mut b = TraceBuilder::new(id, 0, "assd", Instant::now(), 16);
        let t0 = b.now_us();
        b.push(SpanKind::QueueWait, 0, 0, 0, 0);
        b.push(SpanKind::Admit, 0, t0, 4, 0);
        b.add_commits(committed as usize);
        b.finish(true, nfe, 0, 1, 0, 0, "self".to_string())
    }

    #[test]
    fn builder_caps_spans_without_growing() {
        let mut b = TraceBuilder::new(1, 0, "assd", Instant::now(), 4);
        let cap_before = b.trace.spans.capacity();
        for i in 0..10 {
            b.push(SpanKind::Forward, i, 0, 0, 0);
        }
        assert_eq!(b.trace.spans.len(), 4);
        assert_eq!(b.trace.dropped_spans, 6);
        assert_eq!(b.trace.spans.capacity(), cap_before, "no reallocation past the cap");
    }

    #[test]
    fn theorem2_flag_checks_completed_requests_only() {
        assert!(finished(1, 10, 10).theorem2_ok, "equality is within the bound");
        assert!(!finished(2, 3, 9).theorem2_ok, "NFE above commits must flag");
        // Aborted mid-iteration: one draft NFE ahead of commits is legal.
        let b = TraceBuilder::new(3, 0, "assd", Instant::now(), 4);
        let t = b.finish(false, 1, 0, 0, 0, 0, String::new());
        assert!(t.theorem2_ok, "aborted requests are not held to the bound");
        assert!(!t.completed);
    }

    #[test]
    fn ring_drops_oldest_under_churn() {
        let rec = SpanRecorder::new(3);
        for id in 1..=10u64 {
            rec.record(finished(id, 5, 5));
        }
        assert_eq!(rec.len(), 3);
        assert!(rec.get(7).is_none(), "evicted");
        for id in 8..=10 {
            assert!(rec.get(id).is_some(), "id {id} retained");
        }
        let recent = rec.recent(10);
        let ids: Vec<u64> = recent.iter().map(|t| t.request_id).collect();
        assert_eq!(ids, vec![10, 9, 8], "newest first");
    }

    #[test]
    fn window_and_rung_notes_fold_into_summary() {
        let mut b = TraceBuilder::new(9, 1, "assd", Instant::now(), 8);
        b.note_window(5);
        b.note_window(2);
        b.note_window(3);
        b.note_rung(Rung::Inc);
        b.note_rung(Rung::Dense);
        b.note_prefix_probe(true);
        let t = b.finish(true, 0, 0, 3, 0, 0, "self".to_string());
        assert_eq!((t.window_min, t.window_max, t.window_last), (2, 5, 3));
        assert_eq!(t.rungs, Rung::Inc.bit() | Rung::Dense.bit());
        assert_eq!(rungs_str(t.rungs), "inc|dense");
        assert_eq!((t.prefix_hits, t.prefix_misses), (1, 0));
        assert!(t.summary_json().get("theorem2_ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn phase_us_sums_by_kind() {
        let mut b = TraceBuilder::new(4, 0, "seq", Instant::now(), 8);
        b.push_at(SpanKind::Forward, 0, 0, 100, 0, 1);
        b.push_at(SpanKind::Forward, 1, 200, 50, 0, 1);
        b.push_at(SpanKind::Commit, 1, 260, 10, 2, 0);
        let t = b.finish(true, 2, 0, 2, 0, 0, String::new());
        assert_eq!(t.phase_us(SpanKind::Forward), 150);
        assert_eq!(t.phase_us(SpanKind::Commit), 10);
        assert_eq!(t.phase_us(SpanKind::Draft), 0);
    }
}
