//! Prometheus text exposition (format 0.0.4) without a client library.
//!
//! `GET /metrics` with `Accept: text/plain` renders the pool counters,
//! per-replica counters (labelled `{replica="i"}`), and the latency /
//! per-phase / acceptance histograms in the plain-text scrape format.
//! The writer is append-only over one `String`; metric families follow
//! the Prometheus naming conventions (`_total` counters, `_seconds`
//! histograms, base units).

use crate::util::stats::Histogram;

/// Content-Type for the 0.0.4 text exposition format.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Append-only text-format writer.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> Self {
        PromText { out: String::new() }
    }

    /// Open a metric family: `# HELP` then `# TYPE`. Exposition format
    /// 0.0.4 allows at most one header pair per family, before its
    /// samples — callers adding series to an existing family (see
    /// [`PromText::histogram_series`]) must not re-open it.
    pub fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n"));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    /// One sample line. Label values are escaped per the exposition
    /// format: backslash, double-quote, and line-feed must appear as
    /// `\\`, `\"`, and `\n` inside the quoted value.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label_value(v)));
            }
            self.out.push('}');
        }
        if value.fract() == 0.0 && value.abs() < 1e15 {
            self.out.push_str(&format!(" {}\n", value as i64));
        } else {
            self.out.push_str(&format!(" {value}\n"));
        }
    }

    /// A counter family with a single unlabelled sample.
    pub fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "counter");
        self.sample(name, &[], value);
    }

    /// A gauge family with a single unlabelled sample.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        self.sample(name, &[], value);
    }

    /// A counter family where every sample carries labels (e.g. one
    /// sample per replica). `samples` = (labels, value).
    pub fn labeled_counter(&mut self, name: &str, help: &str, samples: &[(&[(&str, &str)], f64)]) {
        self.header(name, help, "counter");
        for (labels, v) in samples {
            self.sample(name, labels, *v);
        }
    }

    /// Render a [`Histogram`] as a Prometheus histogram family:
    /// cumulative `_bucket{le=...}` samples, `_sum`, `_count`. Extra
    /// labels (e.g. `drafter="bigram"`) are prepended before `le`.
    pub fn histogram(&mut self, name: &str, help: &str, labels: &[(&str, &str)], h: &Histogram) {
        self.header(name, help, "histogram");
        self.histogram_series(name, labels, h);
    }

    /// Continue an already-opened histogram family with another labelled
    /// series (Prometheus allows one HELP/TYPE header per family).
    pub fn histogram_series(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let bucket = format!("{name}_bucket");
        let mut cum = 0u64;
        let mut owned: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        owned.push(("le".to_string(), String::new()));
        let le_idx = owned.len() - 1;
        for (i, &b) in h.bounds().iter().enumerate() {
            cum += h.counts()[i];
            owned[le_idx].1 = format!("{b}");
            let refs: Vec<(&str, &str)> = owned.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            self.sample(&bucket, &refs, cum as f64);
        }
        owned[le_idx].1 = "+Inf".to_string();
        let refs: Vec<(&str, &str)> = owned.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
        self.sample(&bucket, &refs, h.count() as f64);
        self.sample(&format!("{name}_sum"), labels, h.sum());
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Escape a label value for the text exposition format (`\\`, `\"`,
/// `\n`). Returns a borrowed slice when no escaping is needed — label
/// values are almost always clean identifiers.
fn escape_label_value(v: &str) -> std::borrow::Cow<'_, str> {
    if !v.contains(['\\', '"', '\n']) {
        return std::borrow::Cow::Borrowed(v);
    }
    let mut out = String::with_capacity(v.len() + 4);
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    std::borrow::Cow::Owned(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_in_text_format() {
        let mut w = PromText::new();
        w.counter("asarm_requests_total", "Completed requests.", 12.0);
        w.gauge("asarm_kv_blocks_free", "Free KV blocks.", 7.0);
        let s = w.finish();
        assert!(s.contains("# TYPE asarm_requests_total counter\n"));
        assert!(s.contains("asarm_requests_total 12\n"));
        assert!(s.contains("# TYPE asarm_kv_blocks_free gauge\n"));
        assert!(s.contains("asarm_kv_blocks_free 7\n"));
    }

    #[test]
    fn labeled_counter_emits_one_sample_per_label_set() {
        let mut w = PromText::new();
        let r0: &[(&str, &str)] = &[("replica", "0")];
        let r1: &[(&str, &str)] = &[("replica", "1")];
        w.labeled_counter(
            "asarm_replica_requests_total",
            "Per-replica completed requests.",
            &[(r0, 3.0), (r1, 4.0)],
        );
        let s = w.finish();
        assert!(s.contains("asarm_replica_requests_total{replica=\"0\"} 3\n"));
        assert!(s.contains("asarm_replica_requests_total{replica=\"1\"} 4\n"));
        assert_eq!(s.matches("# TYPE").count(), 1, "one header per family");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_in_inf() {
        let mut h = Histogram::with_bounds(vec![0.1, 1.0, 10.0]);
        h.record(0.05);
        h.record(0.5);
        h.record(0.5);
        h.record(100.0); // overflow bucket
        let mut w = PromText::new();
        w.histogram("asarm_latency_seconds", "Request latency.", &[], &h);
        let s = w.finish();
        assert!(s.contains("asarm_latency_seconds_bucket{le=\"0.1\"} 1\n"));
        assert!(s.contains("asarm_latency_seconds_bucket{le=\"1\"} 3\n"));
        assert!(s.contains("asarm_latency_seconds_bucket{le=\"10\"} 3\n"));
        assert!(s.contains("asarm_latency_seconds_bucket{le=\"+Inf\"} 4\n"));
        assert!(s.contains("asarm_latency_seconds_count 4\n"));
    }

    #[test]
    fn label_values_escape_backslash_quote_and_newline() {
        let mut w = PromText::new();
        w.header("asarm_errors_total", "Errors by message.", "counter");
        w.sample(
            "asarm_errors_total",
            &[("msg", "path \"C:\\tmp\"\nline2")],
            1.0,
        );
        let s = w.finish();
        assert!(
            s.contains(r#"msg="path \"C:\\tmp\"\nline2""#),
            "escaped label value missing: {s}"
        );
        // The sample stays a single line: the raw LF never reaches the
        // output.
        let sample_line = s.lines().find(|l| l.starts_with("asarm_errors_total{")).unwrap();
        assert!(sample_line.ends_with(" 1"));
    }

    #[test]
    fn histogram_series_shares_the_family_header() {
        let mut a = Histogram::unit();
        a.record(0.5);
        let mut b = Histogram::unit();
        b.record(0.9);
        let mut w = PromText::new();
        w.histogram(
            "asarm_acceptance_rate",
            "Per-request acceptance rate by drafter.",
            &[("drafter", "self")],
            &a,
        );
        w.histogram_series("asarm_acceptance_rate", &[("drafter", "bigram")], &b);
        let s = w.finish();
        assert_eq!(s.matches("# TYPE asarm_acceptance_rate histogram").count(), 1);
        assert!(s.contains("drafter=\"self\""));
        assert!(s.contains("drafter=\"bigram\""));
    }
}
