//! Thread-local attribution taps between the engines and the scheduler.
//!
//! Engines are thread-pinned (the PJRT client is single-threaded, so a
//! replica's engine lives and dies on its worker thread) — which makes a
//! thread-local the *exact* channel for per-call attribution: the engine
//! writes during a batched forward, the scheduler worker drains right
//! after the call returns, and no other thread can interleave.
//!
//! Two taps:
//!
//! - **Fallback rung** — `forward_ord_dense`, the native `forward_ord`
//!   paths, and the native `forward_inc` paths each note which rung of
//!   the inc→ord→dense ladder actually executed. A mixed batch (some
//!   specs routed down a rung) reports the WEAKEST rung that ran, which
//!   is the one that set the call's cost.
//! - **Prefix probes** — when a fresh lane's first incremental forward
//!   consults the prefix cache, the engine notes `(lane, hit)`, letting
//!   the scheduler attribute warm/cold admission to the exact request
//!   pinned to that lane (the pool-level counters cannot: they are
//!   cumulative across all lanes).
//!
//! Both taps are written unconditionally (a `Cell` store is a few ns) so
//! the engines stay tracing-agnostic; with tracing off the scheduler
//! simply never drains them and `reset` clears any residue at admission.

use std::cell::{Cell, RefCell};

use super::Rung;

thread_local! {
    static RUNG: Cell<Option<Rung>> = const { Cell::new(None) };
    static PROBES: RefCell<Vec<(usize, bool)>> = const { RefCell::new(Vec::new()) };
}

/// Note that rung `r` served (part of) the current batched call. Keeps
/// the weakest rung seen since the last [`take_rung`].
pub fn note_rung(r: Rung) {
    RUNG.with(|c| {
        let weakest = match c.get() {
            None => r,
            Some(prev) => prev.min(r),
        };
        c.set(Some(weakest));
    });
}

/// Drain the rung tap (None when no forward ran since the last drain).
pub fn take_rung() -> Option<Rung> {
    RUNG.with(|c| c.take())
}

/// Note a prefix-cache probe for `lane`'s first incremental forward.
pub fn note_prefix_probe(lane: usize, hit: bool) {
    PROBES.with(|p| p.borrow_mut().push((lane, hit)));
}

/// Drain pending probes into `into` (appends; the caller owns the scratch
/// buffer so steady-state draining allocates nothing).
pub fn take_prefix_probes(into: &mut Vec<(usize, bool)>) {
    PROBES.with(|p| into.append(&mut p.borrow_mut()));
}

/// Clear both taps (scheduler calls this before timed sections so stale
/// notes from untraced paths cannot leak into a trace).
pub fn reset() {
    RUNG.with(|c| c.set(None));
    PROBES.with(|p| p.borrow_mut().clear());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_tap_keeps_weakest_and_drains() {
        reset();
        assert_eq!(take_rung(), None);
        note_rung(Rung::Inc);
        note_rung(Rung::Dense);
        note_rung(Rung::Ord);
        assert_eq!(take_rung(), Some(Rung::Dense), "weakest rung wins");
        assert_eq!(take_rung(), None, "drained");
    }

    #[test]
    fn probe_tap_appends_into_caller_scratch() {
        reset();
        let mut scratch = vec![];
        note_prefix_probe(3, true);
        note_prefix_probe(5, false);
        take_prefix_probes(&mut scratch);
        assert_eq!(scratch, vec![(3, true), (5, false)]);
        scratch.clear();
        take_prefix_probes(&mut scratch);
        assert!(scratch.is_empty(), "drained");
    }
}
