//! Serving metrics: request counts, latency percentiles, NFE totals,
//! acceptance rates, throughput. Shared between the scheduler workers and
//! the HTTP workers; exported as JSON at GET /metrics.
//!
//! Two granularities:
//!
//! * [`Metrics`] — the POOL-LEVEL aggregate. Every scheduler worker records
//!   into the same shared instance, so totals and the latency histogram
//!   are exact across the whole pool (no post-hoc histogram merging).
//! * [`ReplicaStats`] — lock-free per-replica counters (one per scheduler
//!   worker), exported at GET /replicas. Counter invariant, asserted by
//!   the pool integration tests: the sum of every `ReplicaStats` counter
//!   equals the corresponding aggregate `Metrics` counter.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::runtime::KvStats;
use crate::util::json::Json;
use crate::util::stats::Histogram;

#[derive(Clone)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

struct Inner {
    started: Instant,
    requests: u64,
    failures: u64,
    tokens_generated: u64,
    model_nfe: u64,
    aux_nfe: u64,
    proposed: u64,
    accepted: u64,
    latency: Histogram,
    batch_occupancy_sum: u64,
    batch_iterations: u64,
    // --- streaming lifecycle (docs/ARCHITECTURE.md §Request lifecycle &
    //     streaming) ---
    /// Time-to-first-token: submit -> first committed chunk.
    ttft: Histogram,
    /// Inter-token latency: gap between commit events, normalized per
    /// token committed in the later chunk.
    itl: Histogram,
    /// Requests retired early by a client cancel, disconnect, lagging
    /// event channel, or dropped handle.
    cancelled: u64,
    /// Requests retired early because their deadline passed.
    deadline_expired: u64,
    /// Requests refused at admission because the queue was full (429).
    shed: u64,
    // --- paged KV / prefix cache (docs/ARCHITECTURE.md §Paged KV &
    //     prefix cache). Pool-wide totals, accumulated as per-iteration
    //     deltas by each worker from its replica's engine counters. ---
    /// Lane initializations served from a cached prefix (prefill skipped).
    prefix_hits: u64,
    /// Lane initializations that had to prefill from scratch.
    prefix_misses: u64,
    /// Sealed prefix-cache entries evicted (LRU) under block pressure.
    kv_evictions: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Arc::new(Mutex::new(Inner {
                started: Instant::now(),
                requests: 0,
                failures: 0,
                tokens_generated: 0,
                model_nfe: 0,
                aux_nfe: 0,
                proposed: 0,
                accepted: 0,
                latency: Histogram::latency(),
                batch_occupancy_sum: 0,
                batch_iterations: 0,
                ttft: Histogram::latency(),
                itl: Histogram::latency(),
                cancelled: 0,
                deadline_expired: 0,
                shed: 0,
                prefix_hits: 0,
                prefix_misses: 0,
                kv_evictions: 0,
            })),
        }
    }

    pub fn record_request(
        &self,
        latency_s: f64,
        tokens: u64,
        model_nfe: u64,
        aux_nfe: u64,
        proposed: u64,
        accepted: u64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.tokens_generated += tokens;
        m.model_nfe += model_nfe;
        m.aux_nfe += aux_nfe;
        m.proposed += proposed;
        m.accepted += accepted;
        m.latency.record(latency_s);
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failures += 1;
    }

    pub fn record_batch_iteration(&self, occupancy: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batch_occupancy_sum += occupancy as u64;
        m.batch_iterations += 1;
    }

    /// Submit -> first committed chunk, once per streamed request.
    pub fn record_ttft(&self, seconds: f64) {
        self.inner.lock().unwrap().ttft.record(seconds);
    }

    /// Per-token inter-token latency, once per post-first commit chunk.
    pub fn record_itl(&self, seconds_per_token: f64) {
        self.inner.lock().unwrap().itl.record(seconds_per_token);
    }

    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    pub fn record_deadline_expired(&self) {
        self.inner.lock().unwrap().deadline_expired += 1;
    }

    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Fold one worker's prefix-cache activity DELTAS (since its previous
    /// push) into the pool-wide totals. Engine counters are cumulative per
    /// replica, so workers difference them before recording here.
    pub fn record_prefix_cache(&self, hits: u64, misses: u64, evictions: u64) {
        if hits == 0 && misses == 0 && evictions == 0 {
            return;
        }
        let mut m = self.inner.lock().unwrap();
        m.prefix_hits += hits;
        m.prefix_misses += misses;
        m.kv_evictions += evictions;
    }

    pub fn prefix_hits(&self) -> u64 {
        self.inner.lock().unwrap().prefix_hits
    }

    pub fn prefix_misses(&self) -> u64 {
        self.inner.lock().unwrap().prefix_misses
    }

    pub fn kv_evictions(&self) -> u64 {
        self.inner.lock().unwrap().kv_evictions
    }

    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    pub fn cancelled(&self) -> u64 {
        self.inner.lock().unwrap().cancelled
    }

    pub fn deadline_expired(&self) -> u64 {
        self.inner.lock().unwrap().deadline_expired
    }

    pub fn shed(&self) -> u64 {
        self.inner.lock().unwrap().shed
    }

    pub fn snapshot_json(&self) -> Json {
        let m = self.inner.lock().unwrap();
        let elapsed = m.started.elapsed().as_secs_f64();
        let accept_rate = if m.proposed > 0 {
            m.accepted as f64 / m.proposed as f64
        } else {
            0.0
        };
        let mean_occ = if m.batch_iterations > 0 {
            m.batch_occupancy_sum as f64 / m.batch_iterations as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("uptime_s", Json::num(elapsed)),
            ("requests", Json::num(m.requests as f64)),
            ("failures", Json::num(m.failures as f64)),
            ("tokens_generated", Json::num(m.tokens_generated as f64)),
            (
                "tokens_per_second",
                Json::num(m.tokens_generated as f64 / elapsed.max(1e-9)),
            ),
            ("model_nfe", Json::num(m.model_nfe as f64)),
            ("aux_nfe", Json::num(m.aux_nfe as f64)),
            ("proposed", Json::num(m.proposed as f64)),
            ("accepted", Json::num(m.accepted as f64)),
            ("acceptance_rate", Json::num(accept_rate)),
            ("latency_p50_s", Json::num(m.latency.quantile(0.5))),
            ("latency_p95_s", Json::num(m.latency.quantile(0.95))),
            ("latency_p99_s", Json::num(m.latency.quantile(0.99))),
            ("latency_mean_s", Json::num(m.latency.mean())),
            ("mean_batch_occupancy", Json::num(mean_occ)),
            ("batch_iterations", Json::num(m.batch_iterations as f64)),
            ("ttft_p50_s", Json::num(m.ttft.quantile(0.5))),
            ("ttft_p95_s", Json::num(m.ttft.quantile(0.95))),
            ("ttft_mean_s", Json::num(m.ttft.mean())),
            ("itl_p50_s", Json::num(m.itl.quantile(0.5))),
            ("itl_p95_s", Json::num(m.itl.quantile(0.95))),
            ("itl_mean_s", Json::num(m.itl.mean())),
            ("cancelled", Json::num(m.cancelled as f64)),
            ("deadline_expired", Json::num(m.deadline_expired as f64)),
            ("shed", Json::num(m.shed as f64)),
            ("prefix_hits", Json::num(m.prefix_hits as f64)),
            ("prefix_misses", Json::num(m.prefix_misses as f64)),
            (
                "prefix_hit_rate",
                Json::num(if m.prefix_hits + m.prefix_misses > 0 {
                    m.prefix_hits as f64 / (m.prefix_hits + m.prefix_misses) as f64
                } else {
                    0.0
                }),
            ),
            ("kv_evictions", Json::num(m.kv_evictions as f64)),
        ])
    }
}

/// Lifecycle of one scheduler worker / engine replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    /// Worker spawned; engine not yet provisioned.
    Starting,
    /// Engine loaded; draining the admission queue.
    Running,
    /// Engine provisioning failed; worker exited without serving.
    Failed,
    /// Worker drained its slots and exited cleanly.
    Stopped,
}

impl ReplicaState {
    fn as_str(self) -> &'static str {
        match self {
            ReplicaState::Starting => "starting",
            ReplicaState::Running => "running",
            ReplicaState::Failed => "failed",
            ReplicaState::Stopped => "stopped",
        }
    }
}

/// Per-replica serving counters (lock-free; one instance per scheduler
/// worker, shared with every [`super::scheduler::SchedulerHandle`] clone).
pub struct ReplicaStats {
    /// Replica id (= worker index, = factory argument).
    pub id: usize,
    state: AtomicU8,
    requests: AtomicU64,
    failures: AtomicU64,
    tokens_generated: AtomicU64,
    model_nfe: AtomicU64,
    proposed: AtomicU64,
    accepted: AtomicU64,
    batch_iterations: AtomicU64,
    batch_occupancy_sum: AtomicU64,
    /// Slots this replica retired early (cancel, disconnect, abandoned
    /// handle, or deadline expiry).
    cancelled: AtomicU64,
    // --- paged-KV block pool (gauges + cumulative engine counters,
    //     overwritten wholesale from the replica's [`KvStats`] snapshot
    //     each scheduler iteration; 0 on engines without a native
    //     incremental path). ---
    kv_blocks_total: AtomicU64,
    kv_blocks_free: AtomicU64,
    kv_blocks_cached: AtomicU64,
    kv_blocks_evictable: AtomicU64,
    kv_sealed_entries: AtomicU64,
    kv_prefix_hits: AtomicU64,
    kv_prefix_misses: AtomicU64,
    kv_evictions: AtomicU64,
    kv_cow_copies: AtomicU64,
}

impl ReplicaStats {
    pub fn new(id: usize) -> ReplicaStats {
        ReplicaStats {
            id,
            state: AtomicU8::new(ReplicaState::Starting as u8),
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            model_nfe: AtomicU64::new(0),
            proposed: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            batch_iterations: AtomicU64::new(0),
            batch_occupancy_sum: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            kv_blocks_total: AtomicU64::new(0),
            kv_blocks_free: AtomicU64::new(0),
            kv_blocks_cached: AtomicU64::new(0),
            kv_blocks_evictable: AtomicU64::new(0),
            kv_sealed_entries: AtomicU64::new(0),
            kv_prefix_hits: AtomicU64::new(0),
            kv_prefix_misses: AtomicU64::new(0),
            kv_evictions: AtomicU64::new(0),
            kv_cow_copies: AtomicU64::new(0),
        }
    }

    pub fn set_state(&self, s: ReplicaState) {
        self.state.store(s as u8, Ordering::Release);
    }

    pub fn state(&self) -> ReplicaState {
        match self.state.load(Ordering::Acquire) {
            x if x == ReplicaState::Starting as u8 => ReplicaState::Starting,
            x if x == ReplicaState::Running as u8 => ReplicaState::Running,
            x if x == ReplicaState::Failed as u8 => ReplicaState::Failed,
            _ => ReplicaState::Stopped,
        }
    }

    pub fn record_request(&self, tokens: u64, model_nfe: u64, proposed: u64, accepted: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens, Ordering::Relaxed);
        self.model_nfe.fetch_add(model_nfe, Ordering::Relaxed);
        self.proposed.fetch_add(proposed, Ordering::Relaxed);
        self.accepted.fetch_add(accepted, Ordering::Relaxed);
    }

    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch_iteration(&self, occupancy: usize) {
        self.batch_iterations.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy_sum
            .fetch_add(occupancy as u64, Ordering::Relaxed);
    }

    /// Overwrite the block-pool gauges and cumulative prefix-cache
    /// counters from a fresh engine snapshot (workers push one per
    /// scheduler iteration and at lane retirement).
    pub fn record_kv(&self, s: &KvStats) {
        self.kv_blocks_total
            .store(s.total_blocks as u64, Ordering::Relaxed);
        self.kv_blocks_free
            .store(s.free_blocks as u64, Ordering::Relaxed);
        self.kv_blocks_cached
            .store(s.cached_blocks as u64, Ordering::Relaxed);
        self.kv_blocks_evictable
            .store(s.evictable_blocks as u64, Ordering::Relaxed);
        self.kv_sealed_entries
            .store(s.sealed_entries as u64, Ordering::Relaxed);
        self.kv_prefix_hits.store(s.prefix_hits, Ordering::Relaxed);
        self.kv_prefix_misses
            .store(s.prefix_misses, Ordering::Relaxed);
        self.kv_evictions.store(s.evictions, Ordering::Relaxed);
        self.kv_cow_copies.store(s.cow_copies, Ordering::Relaxed);
    }

    pub fn prefix_hits(&self) -> u64 {
        self.kv_prefix_hits.load(Ordering::Relaxed)
    }

    pub fn prefix_misses(&self) -> u64 {
        self.kv_prefix_misses.load(Ordering::Relaxed)
    }

    pub fn kv_evictions(&self) -> u64 {
        self.kv_evictions.load(Ordering::Relaxed)
    }

    pub fn kv_blocks_free(&self) -> u64 {
        self.kv_blocks_free.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn failures(&self) -> u64 {
        self.failures.load(Ordering::Relaxed)
    }

    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated.load(Ordering::Relaxed)
    }

    pub fn model_nfe(&self) -> u64 {
        self.model_nfe.load(Ordering::Relaxed)
    }

    pub fn proposed(&self) -> u64 {
        self.proposed.load(Ordering::Relaxed)
    }

    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    pub fn batch_iterations(&self) -> u64 {
        self.batch_iterations.load(Ordering::Relaxed)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn snapshot_json(&self) -> Json {
        let iters = self.batch_iterations.load(Ordering::Relaxed);
        let occ = if iters > 0 {
            self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / iters as f64
        } else {
            0.0
        };
        let proposed = self.proposed();
        let accept_rate = if proposed > 0 {
            self.accepted() as f64 / proposed as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("replica", Json::num(self.id as f64)),
            ("state", Json::str(self.state().as_str())),
            ("requests", Json::num(self.requests() as f64)),
            ("failures", Json::num(self.failures() as f64)),
            (
                "tokens_generated",
                Json::num(self.tokens_generated() as f64),
            ),
            ("model_nfe", Json::num(self.model_nfe() as f64)),
            ("proposed", Json::num(proposed as f64)),
            ("accepted", Json::num(self.accepted() as f64)),
            ("acceptance_rate", Json::num(accept_rate)),
            ("batch_iterations", Json::num(iters as f64)),
            ("mean_batch_occupancy", Json::num(occ)),
            ("cancelled", Json::num(self.cancelled() as f64)),
            (
                "kv_blocks_total",
                Json::num(self.kv_blocks_total.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_blocks_free",
                Json::num(self.kv_blocks_free.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_blocks_cached",
                Json::num(self.kv_blocks_cached.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_blocks_evictable",
                Json::num(self.kv_blocks_evictable.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_sealed_entries",
                Json::num(self.kv_sealed_entries.load(Ordering::Relaxed) as f64),
            ),
            ("prefix_hits", Json::num(self.prefix_hits() as f64)),
            ("prefix_misses", Json::num(self.prefix_misses() as f64)),
            ("kv_evictions", Json::num(self.kv_evictions() as f64)),
            (
                "kv_cow_copies",
                Json::num(self.kv_cow_copies.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(0.010, 100, 50, 0, 80, 60);
        m.record_request(0.020, 50, 25, 5, 40, 30);
        m.record_batch_iteration(3);
        m.record_batch_iteration(1);
        let j = m.snapshot_json();
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("tokens_generated").unwrap().as_f64(), Some(150.0));
        assert_eq!(j.get("model_nfe").unwrap().as_f64(), Some(75.0));
        assert_eq!(j.get("proposed").unwrap().as_f64(), Some(120.0));
        assert_eq!(j.get("accepted").unwrap().as_f64(), Some(90.0));
        let ar = j.get("acceptance_rate").unwrap().as_f64().unwrap();
        assert!((ar - 0.75).abs() < 1e-9);
        assert_eq!(j.get("mean_batch_occupancy").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn replica_stats_counts_and_states() {
        let r = ReplicaStats::new(2);
        assert_eq!(r.state(), ReplicaState::Starting);
        r.set_state(ReplicaState::Running);
        r.record_request(10, 4, 12, 9);
        r.record_request(6, 3, 8, 6);
        r.record_failure();
        r.record_batch_iteration(3);
        r.record_batch_iteration(1);
        let j = r.snapshot_json();
        assert_eq!(j.get("replica").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("state").unwrap().as_str(), Some("running"));
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("failures").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("tokens_generated").unwrap().as_f64(), Some(16.0));
        assert_eq!(j.get("model_nfe").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("proposed").unwrap().as_f64(), Some(20.0));
        assert_eq!(j.get("accepted").unwrap().as_f64(), Some(15.0));
        assert_eq!(j.get("acceptance_rate").unwrap().as_f64(), Some(0.75));
        assert_eq!(j.get("mean_batch_occupancy").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn lifecycle_counters_and_latency_split() {
        let m = Metrics::new();
        m.record_ttft(0.010);
        m.record_ttft(0.030);
        m.record_itl(0.002);
        m.record_cancelled();
        m.record_deadline_expired();
        m.record_shed();
        m.record_shed();
        let j = m.snapshot_json();
        assert!(j.get("ttft_mean_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("itl_mean_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("cancelled").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("deadline_expired").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("shed").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.cancelled(), 1);
        assert_eq!(m.shed(), 2);
        let r = ReplicaStats::new(0);
        r.record_cancelled();
        assert_eq!(r.cancelled(), 1);
        assert_eq!(
            r.snapshot_json().get("cancelled").unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn kv_counters_and_gauges() {
        let m = Metrics::new();
        m.record_prefix_cache(3, 1, 2);
        m.record_prefix_cache(0, 0, 0); // delta-free push is a no-op
        let j = m.snapshot_json();
        assert_eq!(j.get("prefix_hits").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("prefix_misses").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("kv_evictions").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("prefix_hit_rate").unwrap().as_f64(), Some(0.75));
        assert_eq!(m.prefix_hits(), 3);
        assert_eq!(m.prefix_misses(), 1);
        assert_eq!(m.kv_evictions(), 2);

        let r = ReplicaStats::new(0);
        let s = KvStats {
            block_rows: 16,
            total_blocks: 8,
            free_blocks: 5,
            cached_blocks: 2,
            evictable_blocks: 1,
            sealed_entries: 2,
            prefix_hits: 4,
            prefix_misses: 6,
            evictions: 1,
            cow_copies: 3,
        };
        r.record_kv(&s);
        let j = r.snapshot_json();
        assert_eq!(j.get("kv_blocks_total").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.get("kv_blocks_free").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("kv_blocks_cached").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("kv_blocks_evictable").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("kv_sealed_entries").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("prefix_hits").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("prefix_misses").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.get("kv_evictions").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("kv_cow_copies").unwrap().as_f64(), Some(3.0));
        // gauges overwrite, not accumulate
        r.record_kv(&KvStats { free_blocks: 8, ..s });
        assert_eq!(r.kv_blocks_free(), 8);
    }

    #[test]
    fn thread_safe() {
        let m = Metrics::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record_request(0.001, 1, 1, 0, 1, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.requests(), 800);
    }
}
