//! Serving metrics: request counts, latency percentiles, NFE totals,
//! acceptance rates, throughput. Shared between the scheduler workers and
//! the HTTP workers; exported as JSON at GET /metrics (and as Prometheus
//! text exposition under `Accept: text/plain` — see [`Metrics::prometheus`]).
//!
//! Two granularities:
//!
//! * [`Metrics`] — the POOL-LEVEL aggregate. Every scheduler worker records
//!   into the same shared instance, so totals and the latency histogram
//!   are exact across the whole pool (no post-hoc histogram merging).
//! * [`ReplicaStats`] — lock-free per-replica counters (one per scheduler
//!   worker), exported at GET /replicas. Counter invariant, asserted by
//!   the pool integration tests: the sum of every `ReplicaStats` counter
//!   equals the corresponding aggregate `Metrics` counter.
//!
//! Naming contract (the canonical counter table lives in
//! docs/ARCHITECTURE.md §Observability & tracing): a counter that exists
//! on both surfaces uses the SAME snake_case key in both JSON snapshots
//! and the `asarm_`-prefixed form in Prometheus (`asarm_<key>_total` for
//! counters); per-replica-only gauges (`kv_blocks_*`) and pool-only
//! distribution keys (`*_p50_s` etc.) are documented as single-surface.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::prometheus::PromText;
use crate::obs::{RequestTrace, SpanKind};
use crate::runtime::{ErrorClass, KvStats};
use crate::util::json::Json;
use crate::util::stats::Histogram;

#[derive(Clone)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

struct Inner {
    started: Instant,
    requests: u64,
    failures: u64,
    tokens_generated: u64,
    model_nfe: u64,
    aux_nfe: u64,
    proposed: u64,
    accepted: u64,
    latency: Histogram,
    batch_occupancy_sum: u64,
    batch_iterations: u64,
    // --- streaming lifecycle (docs/ARCHITECTURE.md §Request lifecycle &
    //     streaming) ---
    /// Time-to-first-token: submit -> first committed chunk.
    ttft: Histogram,
    /// Inter-token latency: gap between commit events, normalized per
    /// token committed in the later chunk.
    itl: Histogram,
    /// Requests retired early by a client cancel, disconnect, lagging
    /// event channel, or dropped handle.
    cancelled: u64,
    /// Requests retired early because their deadline passed.
    deadline_expired: u64,
    /// Requests refused at admission because the queue was full (429).
    shed: u64,
    // --- paged KV / prefix cache (docs/ARCHITECTURE.md §Paged KV &
    //     prefix cache). Pool-wide totals, accumulated as per-iteration
    //     deltas by each worker from its replica's engine counters. ---
    /// Lane initializations served from a cached prefix (prefill skipped).
    prefix_hits: u64,
    /// Lane initializations that had to prefill from scratch.
    prefix_misses: u64,
    /// Sealed prefix-cache entries evicted (LRU) under block pressure.
    kv_evictions: u64,
    /// Copy-on-write block copies (shared cached block mutated by a lane).
    kv_cow_copies: u64,
    // --- request-level tracing (docs/ARCHITECTURE.md §Observability &
    //     tracing). Folded once per retired request from its trace. ---
    /// Traces published to the per-replica span rings.
    traces_recorded: u64,
    /// Spans discarded because a request exceeded its span cap.
    trace_spans_dropped: u64,
    /// Completed requests whose trace violated Theorem 2
    /// (`model_nfe > tokens_committed`) — must stay 0.
    theorem2_violations: u64,
    /// Cumulative per-phase wall time (µs), summed over every traced
    /// request's spans; the per-replica counters fold to these exactly.
    phase_draft_us: u64,
    phase_forward_us: u64,
    phase_verify_us: u64,
    phase_commit_us: u64,
    /// Per-iteration phase latency distributions (one sample per span).
    phase_draft: Histogram,
    phase_forward: Histogram,
    phase_verify: Histogram,
    phase_commit: Histogram,
    /// Per-request acceptance-rate distribution, keyed by drafter kind.
    acceptance_by_drafter: Vec<(String, Histogram)>,
    // --- fault tolerance (docs/ARCHITECTURE.md §Fault tolerance &
    //     supervision) ---
    /// Batched/retry forward calls that returned a typed engine error,
    /// by class (transient / lane_corrupt / fatal).
    engine_errors_transient: u64,
    engine_errors_lane_corrupt: u64,
    engine_errors_fatal: u64,
    /// Per-slot recovery forwards issued after a failed batched call.
    forward_retries: u64,
    /// Engine incarnations re-provisioned by the replica supervisor.
    replica_restarts: u64,
    /// Requests failed by the fault-isolation layer (retry budget
    /// exhausted, fatal engine error, contained panic, or replica loss)
    /// — a subset of `failures` excluding client-caused retires.
    requests_failed: u64,
    // --- decode-state checkpointing (docs/ARCHITECTURE.md
    //     §Checkpointing, preemption & migration). Each counts one slot
    //     checkpointed and parked on the resume deque; none of these
    //     fail the request. ---
    /// Slots parked to relieve KV pressure (victim sealed + released its
    /// lane so batch-mates could allocate).
    preemptions: u64,
    /// Slots re-queued off a dead engine incarnation instead of failing
    /// with it.
    migrations: u64,
    /// Slots parked by the drain flag (POST /drain).
    drains: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Arc::new(Mutex::new(Inner {
                started: Instant::now(),
                requests: 0,
                failures: 0,
                tokens_generated: 0,
                model_nfe: 0,
                aux_nfe: 0,
                proposed: 0,
                accepted: 0,
                latency: Histogram::latency(),
                batch_occupancy_sum: 0,
                batch_iterations: 0,
                ttft: Histogram::latency(),
                itl: Histogram::latency(),
                cancelled: 0,
                deadline_expired: 0,
                shed: 0,
                prefix_hits: 0,
                prefix_misses: 0,
                kv_evictions: 0,
                kv_cow_copies: 0,
                traces_recorded: 0,
                trace_spans_dropped: 0,
                theorem2_violations: 0,
                phase_draft_us: 0,
                phase_forward_us: 0,
                phase_verify_us: 0,
                phase_commit_us: 0,
                phase_draft: Histogram::latency(),
                phase_forward: Histogram::latency(),
                phase_verify: Histogram::latency(),
                phase_commit: Histogram::latency(),
                acceptance_by_drafter: vec![],
                engine_errors_transient: 0,
                engine_errors_lane_corrupt: 0,
                engine_errors_fatal: 0,
                forward_retries: 0,
                replica_restarts: 0,
                requests_failed: 0,
                preemptions: 0,
                migrations: 0,
                drains: 0,
            })),
        }
    }

    pub fn record_request(
        &self,
        latency_s: f64,
        tokens: u64,
        model_nfe: u64,
        aux_nfe: u64,
        proposed: u64,
        accepted: u64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.tokens_generated += tokens;
        m.model_nfe += model_nfe;
        m.aux_nfe += aux_nfe;
        m.proposed += proposed;
        m.accepted += accepted;
        m.latency.record(latency_s);
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failures += 1;
    }

    /// One typed engine error observed on the forward surface (counted
    /// once per failed CALL, batched or retry).
    pub fn record_engine_error(&self, class: ErrorClass) {
        let mut m = self.inner.lock().unwrap();
        match class {
            ErrorClass::Transient => m.engine_errors_transient += 1,
            ErrorClass::LaneCorrupt => m.engine_errors_lane_corrupt += 1,
            ErrorClass::Fatal => m.engine_errors_fatal += 1,
        }
    }

    /// One per-slot recovery forward issued after a failed batched call.
    pub fn record_forward_retry(&self) {
        self.inner.lock().unwrap().forward_retries += 1;
    }

    /// One engine incarnation re-provisioned by the supervisor.
    pub fn record_replica_restart(&self) {
        self.inner.lock().unwrap().replica_restarts += 1;
    }

    /// One request failed by the fault-isolation layer (this is IN
    /// ADDITION to `record_failure`, which counts every errored retire).
    pub fn record_request_failed(&self) {
        self.inner.lock().unwrap().requests_failed += 1;
    }

    /// One slot checkpointed and parked to relieve KV pressure.
    pub fn record_preemption(&self) {
        self.inner.lock().unwrap().preemptions += 1;
    }

    /// One slot checkpointed off a dead engine incarnation and re-queued.
    pub fn record_migration(&self) {
        self.inner.lock().unwrap().migrations += 1;
    }

    /// One slot checkpointed and parked by the drain flag.
    pub fn record_drain(&self) {
        self.inner.lock().unwrap().drains += 1;
    }

    pub fn record_batch_iteration(&self, occupancy: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batch_occupancy_sum += occupancy as u64;
        m.batch_iterations += 1;
    }

    /// Submit -> first committed chunk, once per streamed request.
    pub fn record_ttft(&self, seconds: f64) {
        self.inner.lock().unwrap().ttft.record(seconds);
    }

    /// Per-token inter-token latency, once per post-first commit chunk.
    pub fn record_itl(&self, seconds_per_token: f64) {
        self.inner.lock().unwrap().itl.record(seconds_per_token);
    }

    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    pub fn record_deadline_expired(&self) {
        self.inner.lock().unwrap().deadline_expired += 1;
    }

    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Fold one worker's prefix-cache activity DELTAS (since its previous
    /// push) into the pool-wide totals. Engine counters are cumulative per
    /// replica, so workers difference them ([`KvStats::delta`]) before
    /// recording here.
    pub fn record_prefix_cache(&self, hits: u64, misses: u64, evictions: u64, cow_copies: u64) {
        if hits == 0 && misses == 0 && evictions == 0 && cow_copies == 0 {
            return;
        }
        let mut m = self.inner.lock().unwrap();
        m.prefix_hits += hits;
        m.prefix_misses += misses;
        m.kv_evictions += evictions;
        m.kv_cow_copies += cow_copies;
    }

    /// Fold one retired request's trace into the pool aggregates: every
    /// span's duration into its phase histogram (per-ITERATION latency
    /// distributions) and the phase wall-time totals, the request's
    /// acceptance rate into its drafter's histogram, and the trace
    /// bookkeeping counters. One lock per request — nothing on the
    /// per-iteration path.
    pub fn record_trace(&self, t: &RequestTrace) {
        let mut m = self.inner.lock().unwrap();
        m.traces_recorded += 1;
        m.trace_spans_dropped += t.dropped_spans;
        if t.completed && !t.theorem2_ok {
            m.theorem2_violations += 1;
        }
        for s in &t.spans {
            let secs = s.dur_us as f64 / 1e6;
            match s.kind {
                SpanKind::Draft => {
                    m.phase_draft_us += s.dur_us;
                    m.phase_draft.record(secs);
                }
                SpanKind::Forward => {
                    m.phase_forward_us += s.dur_us;
                    m.phase_forward.record(secs);
                }
                SpanKind::Verify | SpanKind::Decode => {
                    m.phase_verify_us += s.dur_us;
                    m.phase_verify.record(secs);
                }
                SpanKind::Commit => {
                    m.phase_commit_us += s.dur_us;
                    m.phase_commit.record(secs);
                }
                SpanKind::QueueWait | SpanKind::Admit => {}
            }
        }
        if t.completed && !t.draft_kind.is_empty() && t.proposed > 0 {
            let rate = t.accepted as f64 / t.proposed as f64;
            match m
                .acceptance_by_drafter
                .iter_mut()
                .find(|(k, _)| k == &t.draft_kind)
            {
                Some((_, h)) => h.record(rate),
                None => {
                    let mut h = Histogram::unit();
                    h.record(rate);
                    m.acceptance_by_drafter.push((t.draft_kind.clone(), h));
                }
            }
        }
    }

    pub fn traces_recorded(&self) -> u64 {
        self.inner.lock().unwrap().traces_recorded
    }

    pub fn theorem2_violations(&self) -> u64 {
        self.inner.lock().unwrap().theorem2_violations
    }

    pub fn prefix_hits(&self) -> u64 {
        self.inner.lock().unwrap().prefix_hits
    }

    pub fn prefix_misses(&self) -> u64 {
        self.inner.lock().unwrap().prefix_misses
    }

    pub fn kv_evictions(&self) -> u64 {
        self.inner.lock().unwrap().kv_evictions
    }

    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    pub fn cancelled(&self) -> u64 {
        self.inner.lock().unwrap().cancelled
    }

    pub fn deadline_expired(&self) -> u64 {
        self.inner.lock().unwrap().deadline_expired
    }

    pub fn shed(&self) -> u64 {
        self.inner.lock().unwrap().shed
    }

    /// Engine errors by class: (transient, lane_corrupt, fatal).
    pub fn engine_errors(&self) -> (u64, u64, u64) {
        let m = self.inner.lock().unwrap();
        (
            m.engine_errors_transient,
            m.engine_errors_lane_corrupt,
            m.engine_errors_fatal,
        )
    }

    pub fn forward_retries(&self) -> u64 {
        self.inner.lock().unwrap().forward_retries
    }

    pub fn replica_restarts(&self) -> u64 {
        self.inner.lock().unwrap().replica_restarts
    }

    pub fn requests_failed(&self) -> u64 {
        self.inner.lock().unwrap().requests_failed
    }

    pub fn preemptions(&self) -> u64 {
        self.inner.lock().unwrap().preemptions
    }

    pub fn migrations(&self) -> u64 {
        self.inner.lock().unwrap().migrations
    }

    pub fn drains(&self) -> u64 {
        self.inner.lock().unwrap().drains
    }

    pub fn snapshot_json(&self) -> Json {
        let m = self.inner.lock().unwrap();
        let elapsed = m.started.elapsed().as_secs_f64();
        let accept_rate = if m.proposed > 0 {
            m.accepted as f64 / m.proposed as f64
        } else {
            0.0
        };
        let mean_occ = if m.batch_iterations > 0 {
            m.batch_occupancy_sum as f64 / m.batch_iterations as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("uptime_s", Json::num(elapsed)),
            ("requests", Json::num(m.requests as f64)),
            ("failures", Json::num(m.failures as f64)),
            ("tokens_generated", Json::num(m.tokens_generated as f64)),
            (
                "tokens_per_second",
                Json::num(m.tokens_generated as f64 / elapsed.max(1e-9)),
            ),
            ("model_nfe", Json::num(m.model_nfe as f64)),
            ("aux_nfe", Json::num(m.aux_nfe as f64)),
            ("proposed", Json::num(m.proposed as f64)),
            ("accepted", Json::num(m.accepted as f64)),
            ("acceptance_rate", Json::num(accept_rate)),
            ("latency_p50_s", Json::num(m.latency.quantile(0.5))),
            ("latency_p95_s", Json::num(m.latency.quantile(0.95))),
            ("latency_p99_s", Json::num(m.latency.quantile(0.99))),
            ("latency_mean_s", Json::num(m.latency.mean())),
            ("mean_batch_occupancy", Json::num(mean_occ)),
            ("batch_iterations", Json::num(m.batch_iterations as f64)),
            ("ttft_p50_s", Json::num(m.ttft.quantile(0.5))),
            ("ttft_p95_s", Json::num(m.ttft.quantile(0.95))),
            ("ttft_mean_s", Json::num(m.ttft.mean())),
            ("itl_p50_s", Json::num(m.itl.quantile(0.5))),
            ("itl_p95_s", Json::num(m.itl.quantile(0.95))),
            ("itl_mean_s", Json::num(m.itl.mean())),
            ("cancelled", Json::num(m.cancelled as f64)),
            ("deadline_expired", Json::num(m.deadline_expired as f64)),
            ("shed", Json::num(m.shed as f64)),
            ("prefix_hits", Json::num(m.prefix_hits as f64)),
            ("prefix_misses", Json::num(m.prefix_misses as f64)),
            (
                "prefix_hit_rate",
                Json::num(if m.prefix_hits + m.prefix_misses > 0 {
                    m.prefix_hits as f64 / (m.prefix_hits + m.prefix_misses) as f64
                } else {
                    0.0
                }),
            ),
            ("kv_evictions", Json::num(m.kv_evictions as f64)),
            ("kv_cow_copies", Json::num(m.kv_cow_copies as f64)),
            ("traces_recorded", Json::num(m.traces_recorded as f64)),
            (
                "trace_spans_dropped",
                Json::num(m.trace_spans_dropped as f64),
            ),
            (
                "theorem2_violations",
                Json::num(m.theorem2_violations as f64),
            ),
            ("phase_draft_us", Json::num(m.phase_draft_us as f64)),
            ("phase_forward_us", Json::num(m.phase_forward_us as f64)),
            ("phase_verify_us", Json::num(m.phase_verify_us as f64)),
            ("phase_commit_us", Json::num(m.phase_commit_us as f64)),
            ("phase_draft_p50_s", Json::num(m.phase_draft.quantile(0.5))),
            ("phase_draft_p95_s", Json::num(m.phase_draft.quantile(0.95))),
            (
                "phase_forward_p50_s",
                Json::num(m.phase_forward.quantile(0.5)),
            ),
            (
                "phase_forward_p95_s",
                Json::num(m.phase_forward.quantile(0.95)),
            ),
            ("phase_verify_p50_s", Json::num(m.phase_verify.quantile(0.5))),
            (
                "phase_verify_p95_s",
                Json::num(m.phase_verify.quantile(0.95)),
            ),
            ("phase_commit_p50_s", Json::num(m.phase_commit.quantile(0.5))),
            (
                "phase_commit_p95_s",
                Json::num(m.phase_commit.quantile(0.95)),
            ),
            (
                "engine_errors_transient",
                Json::num(m.engine_errors_transient as f64),
            ),
            (
                "engine_errors_lane_corrupt",
                Json::num(m.engine_errors_lane_corrupt as f64),
            ),
            (
                "engine_errors_fatal",
                Json::num(m.engine_errors_fatal as f64),
            ),
            ("forward_retries", Json::num(m.forward_retries as f64)),
            ("replica_restarts", Json::num(m.replica_restarts as f64)),
            ("requests_failed", Json::num(m.requests_failed as f64)),
            ("preemptions", Json::num(m.preemptions as f64)),
            ("migrations", Json::num(m.migrations as f64)),
            ("drains", Json::num(m.drains as f64)),
            (
                "acceptance_by_drafter",
                Json::obj(
                    m.acceptance_by_drafter
                        .iter()
                        .map(|(k, h)| {
                            (
                                k.as_str(),
                                Json::obj(vec![
                                    ("requests", Json::num(h.count() as f64)),
                                    ("mean", Json::num(h.mean())),
                                    ("p50", Json::num(h.quantile(0.5))),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Render the pool aggregate plus per-replica counters as Prometheus
    /// text exposition (version 0.0.4). Served at GET /metrics under
    /// `Accept: text/plain`; the JSON snapshot stays the default. Family
    /// names carry the `asarm_` prefix and otherwise reuse the snapshot's
    /// snake_case keys (`_total` suffix on counters), so dashboards can
    /// map between the two surfaces mechanically.
    pub fn prometheus(&self, replicas: &[ReplicaStats]) -> String {
        let m = self.inner.lock().unwrap();
        let mut p = PromText::new();
        p.gauge(
            "asarm_uptime_seconds",
            "Seconds since the pool started.",
            m.started.elapsed().as_secs_f64(),
        );
        p.counter(
            "asarm_requests_total",
            "Requests retired (completed or aborted after admission).",
            m.requests as f64,
        );
        p.counter(
            "asarm_failures_total",
            "Requests that retired with an error.",
            m.failures as f64,
        );
        p.counter(
            "asarm_tokens_generated_total",
            "Tokens committed across all requests.",
            m.tokens_generated as f64,
        );
        p.counter(
            "asarm_model_nfe_total",
            "Target-model forward evaluations (Theorem 2 bounds this by tokens generated).",
            m.model_nfe as f64,
        );
        p.counter(
            "asarm_aux_nfe_total",
            "Auxiliary (drafter) forward evaluations.",
            m.aux_nfe as f64,
        );
        p.counter(
            "asarm_proposed_total",
            "Draft tokens proposed for verification.",
            m.proposed as f64,
        );
        p.counter(
            "asarm_accepted_total",
            "Draft tokens accepted by verification.",
            m.accepted as f64,
        );
        p.counter(
            "asarm_batch_iterations_total",
            "Scheduler forward iterations across all workers.",
            m.batch_iterations as f64,
        );
        p.counter(
            "asarm_cancelled_total",
            "Requests retired early by cancel/disconnect.",
            m.cancelled as f64,
        );
        p.counter(
            "asarm_deadline_expired_total",
            "Requests retired early by deadline expiry.",
            m.deadline_expired as f64,
        );
        p.counter(
            "asarm_shed_total",
            "Requests refused at admission (queue full).",
            m.shed as f64,
        );
        p.counter(
            "asarm_prefix_hits_total",
            "Lane initializations served from the prefix cache.",
            m.prefix_hits as f64,
        );
        p.counter(
            "asarm_prefix_misses_total",
            "Lane initializations that prefilled from scratch.",
            m.prefix_misses as f64,
        );
        p.counter(
            "asarm_kv_evictions_total",
            "Sealed prefix-cache entries evicted under block pressure.",
            m.kv_evictions as f64,
        );
        p.counter(
            "asarm_kv_cow_copies_total",
            "Copy-on-write KV block copies.",
            m.kv_cow_copies as f64,
        );
        p.counter(
            "asarm_traces_recorded_total",
            "Request traces published to the span rings.",
            m.traces_recorded as f64,
        );
        p.counter(
            "asarm_trace_spans_dropped_total",
            "Spans discarded past the per-request span cap.",
            m.trace_spans_dropped as f64,
        );
        p.counter(
            "asarm_theorem2_violations_total",
            "Completed requests with model_nfe > tokens committed (must stay 0).",
            m.theorem2_violations as f64,
        );
        p.header(
            "asarm_engine_errors_total",
            "Typed engine errors on the forward surface, by class.",
            "counter",
        );
        p.sample(
            "asarm_engine_errors_total",
            &[("class", ErrorClass::Transient.as_str())],
            m.engine_errors_transient as f64,
        );
        p.sample(
            "asarm_engine_errors_total",
            &[("class", ErrorClass::LaneCorrupt.as_str())],
            m.engine_errors_lane_corrupt as f64,
        );
        p.sample(
            "asarm_engine_errors_total",
            &[("class", ErrorClass::Fatal.as_str())],
            m.engine_errors_fatal as f64,
        );
        p.counter(
            "asarm_forward_retries_total",
            "Per-slot recovery forwards after a failed batched call.",
            m.forward_retries as f64,
        );
        p.counter(
            "asarm_replica_restarts_total",
            "Engine incarnations re-provisioned by the supervisor.",
            m.replica_restarts as f64,
        );
        p.counter(
            "asarm_requests_failed_total",
            "Requests failed by the fault-isolation layer.",
            m.requests_failed as f64,
        );
        p.counter(
            "asarm_preemptions_total",
            "Slots checkpointed and parked to relieve KV pressure.",
            m.preemptions as f64,
        );
        p.counter(
            "asarm_migrations_total",
            "Slots checkpointed off dead engine incarnations and re-queued.",
            m.migrations as f64,
        );
        p.counter(
            "asarm_drains_total",
            "Slots checkpointed and parked by the drain flag.",
            m.drains as f64,
        );
        p.histogram(
            "asarm_request_latency_seconds",
            "End-to-end request latency.",
            &[],
            &m.latency,
        );
        p.histogram(
            "asarm_ttft_seconds",
            "Time to first committed token.",
            &[],
            &m.ttft,
        );
        p.histogram(
            "asarm_itl_seconds",
            "Inter-token latency per committed token.",
            &[],
            &m.itl,
        );
        p.header(
            "asarm_phase_seconds",
            "Per-iteration phase latency (draft/forward/verify/commit spans).",
            "histogram",
        );
        p.histogram_series("asarm_phase_seconds", &[("phase", "draft")], &m.phase_draft);
        p.histogram_series(
            "asarm_phase_seconds",
            &[("phase", "forward")],
            &m.phase_forward,
        );
        p.histogram_series(
            "asarm_phase_seconds",
            &[("phase", "verify")],
            &m.phase_verify,
        );
        p.histogram_series(
            "asarm_phase_seconds",
            &[("phase", "commit")],
            &m.phase_commit,
        );
        if !m.acceptance_by_drafter.is_empty() {
            p.header(
                "asarm_acceptance_rate",
                "Per-request draft acceptance rate, by drafter kind.",
                "histogram",
            );
            for (kind, h) in &m.acceptance_by_drafter {
                p.histogram_series("asarm_acceptance_rate", &[("drafter", kind)], h);
            }
        }
        drop(m);
        if !replicas.is_empty() {
            let rep: Vec<String> = (0..replicas.len()).map(|i| i.to_string()).collect();
            let series = |f: &dyn Fn(&ReplicaStats) -> f64| -> Vec<(Vec<(&str, &str)>, f64)> {
                replicas
                    .iter()
                    .zip(&rep)
                    .map(|(r, id)| (vec![("replica", id.as_str())], f(r)))
                    .collect()
            };
            let emit = |p: &mut PromText, name: &str, help: &str, kind: &str, f: &dyn Fn(&ReplicaStats) -> f64| {
                p.header(name, help, kind);
                for (labels, v) in series(f) {
                    p.sample(name, &labels, v);
                }
            };
            emit(
                &mut p,
                "asarm_replica_requests_total",
                "Requests retired, per replica.",
                "counter",
                &|r| r.requests() as f64,
            );
            emit(
                &mut p,
                "asarm_replica_tokens_generated_total",
                "Tokens committed, per replica.",
                "counter",
                &|r| r.tokens_generated() as f64,
            );
            emit(
                &mut p,
                "asarm_replica_model_nfe_total",
                "Target-model forward evaluations, per replica.",
                "counter",
                &|r| r.model_nfe() as f64,
            );
            emit(
                &mut p,
                "asarm_replica_kv_blocks_free",
                "Free KV blocks in the replica's block pool.",
                "gauge",
                &|r| r.kv_blocks_free() as f64,
            );
            emit(
                &mut p,
                "asarm_replica_kv_blocks_total",
                "Total KV blocks in the replica's block pool.",
                "gauge",
                &|r| r.kv_blocks_total.load(Ordering::Relaxed) as f64,
            );
        }
        p.finish()
    }
}

/// Lifecycle of one scheduler worker / engine replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    /// Worker spawned; engine not yet provisioned (also shown while the
    /// supervisor re-provisions a dead incarnation).
    Starting,
    /// Engine loaded; draining the admission queue.
    Running,
    /// Engine provisioning failed beyond the supervisor's restart
    /// budget; worker exited without (further) serving.
    Failed,
    /// Worker drained its slots and exited cleanly.
    Stopped,
    /// Serving, but its health tracker crossed the degrade threshold
    /// (consecutive forward errors; recovers to Running on success).
    Degraded,
    /// Health tracker crossed the quarantine threshold: the worker
    /// stopped serving on this engine incarnation and handed it to the
    /// supervisor (transient — Starting/Running follow on restart).
    Quarantined,
}

impl ReplicaState {
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaState::Starting => "starting",
            ReplicaState::Running => "running",
            ReplicaState::Failed => "failed",
            ReplicaState::Stopped => "stopped",
            ReplicaState::Degraded => "degraded",
            ReplicaState::Quarantined => "quarantined",
        }
    }

    /// True while the worker loop is (or will again be) serving
    /// requests — the `/healthz` liveness criterion.
    pub fn is_serving(self) -> bool {
        matches!(
            self,
            ReplicaState::Starting
                | ReplicaState::Running
                | ReplicaState::Degraded
                | ReplicaState::Quarantined
        )
    }
}

/// Per-replica serving counters (lock-free; one instance per scheduler
/// worker, shared with every [`super::scheduler::SchedulerHandle`] clone).
pub struct ReplicaStats {
    /// Replica id (= worker index, = factory argument).
    pub id: usize,
    state: AtomicU8,
    requests: AtomicU64,
    failures: AtomicU64,
    tokens_generated: AtomicU64,
    model_nfe: AtomicU64,
    aux_nfe: AtomicU64,
    proposed: AtomicU64,
    accepted: AtomicU64,
    batch_iterations: AtomicU64,
    batch_occupancy_sum: AtomicU64,
    /// Slots this replica retired early (cancel, disconnect, abandoned
    /// handle, or deadline expiry).
    cancelled: AtomicU64,
    // --- paged-KV block pool (gauges + cumulative engine counters,
    //     overwritten wholesale from the replica's [`KvStats`] snapshot
    //     each scheduler iteration; 0 on engines without a native
    //     incremental path). ---
    kv_blocks_total: AtomicU64,
    kv_blocks_free: AtomicU64,
    kv_blocks_cached: AtomicU64,
    kv_blocks_evictable: AtomicU64,
    kv_sealed_entries: AtomicU64,
    kv_prefix_hits: AtomicU64,
    kv_prefix_misses: AtomicU64,
    kv_evictions: AtomicU64,
    kv_cow_copies: AtomicU64,
    // --- request-level tracing (folded once per retired request from its
    //     trace; sums across replicas equal the pool's phase totals). ---
    phase_draft_us: AtomicU64,
    phase_forward_us: AtomicU64,
    phase_verify_us: AtomicU64,
    phase_commit_us: AtomicU64,
    traces_recorded: AtomicU64,
    trace_spans_dropped: AtomicU64,
    // --- fault tolerance (sums across replicas equal the pool
    //     counters). ---
    engine_errors: AtomicU64,
    forward_retries: AtomicU64,
    restarts: AtomicU64,
    requests_failed: AtomicU64,
    // --- decode-state checkpointing (sums across replicas equal the
    //     pool counters; drains are pool-wide, not per-replica). ---
    preemptions: AtomicU64,
    migrations: AtomicU64,
}

impl ReplicaStats {
    pub fn new(id: usize) -> ReplicaStats {
        ReplicaStats {
            id,
            state: AtomicU8::new(ReplicaState::Starting as u8),
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            model_nfe: AtomicU64::new(0),
            aux_nfe: AtomicU64::new(0),
            proposed: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            batch_iterations: AtomicU64::new(0),
            batch_occupancy_sum: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            kv_blocks_total: AtomicU64::new(0),
            kv_blocks_free: AtomicU64::new(0),
            kv_blocks_cached: AtomicU64::new(0),
            kv_blocks_evictable: AtomicU64::new(0),
            kv_sealed_entries: AtomicU64::new(0),
            kv_prefix_hits: AtomicU64::new(0),
            kv_prefix_misses: AtomicU64::new(0),
            kv_evictions: AtomicU64::new(0),
            kv_cow_copies: AtomicU64::new(0),
            phase_draft_us: AtomicU64::new(0),
            phase_forward_us: AtomicU64::new(0),
            phase_verify_us: AtomicU64::new(0),
            phase_commit_us: AtomicU64::new(0),
            traces_recorded: AtomicU64::new(0),
            trace_spans_dropped: AtomicU64::new(0),
            engine_errors: AtomicU64::new(0),
            forward_retries: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            requests_failed: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
        }
    }

    pub fn set_state(&self, s: ReplicaState) {
        self.state.store(s as u8, Ordering::Release);
    }

    pub fn state(&self) -> ReplicaState {
        match self.state.load(Ordering::Acquire) {
            x if x == ReplicaState::Starting as u8 => ReplicaState::Starting,
            x if x == ReplicaState::Running as u8 => ReplicaState::Running,
            x if x == ReplicaState::Failed as u8 => ReplicaState::Failed,
            x if x == ReplicaState::Degraded as u8 => ReplicaState::Degraded,
            x if x == ReplicaState::Quarantined as u8 => ReplicaState::Quarantined,
            _ => ReplicaState::Stopped,
        }
    }

    pub fn record_request(
        &self,
        tokens: u64,
        model_nfe: u64,
        aux_nfe: u64,
        proposed: u64,
        accepted: u64,
    ) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens, Ordering::Relaxed);
        self.model_nfe.fetch_add(model_nfe, Ordering::Relaxed);
        self.aux_nfe.fetch_add(aux_nfe, Ordering::Relaxed);
        self.proposed.fetch_add(proposed, Ordering::Relaxed);
        self.accepted.fetch_add(accepted, Ordering::Relaxed);
    }

    /// Fold one retired request's trace: phase wall-time totals plus the
    /// trace bookkeeping counters. Lock-free, called once per request.
    pub fn record_trace(&self, t: &RequestTrace) {
        self.traces_recorded.fetch_add(1, Ordering::Relaxed);
        self.trace_spans_dropped
            .fetch_add(t.dropped_spans, Ordering::Relaxed);
        self.phase_draft_us
            .fetch_add(t.phase_us(SpanKind::Draft), Ordering::Relaxed);
        self.phase_forward_us
            .fetch_add(t.phase_us(SpanKind::Forward), Ordering::Relaxed);
        self.phase_verify_us.fetch_add(
            t.phase_us(SpanKind::Verify) + t.phase_us(SpanKind::Decode),
            Ordering::Relaxed,
        );
        self.phase_commit_us
            .fetch_add(t.phase_us(SpanKind::Commit), Ordering::Relaxed);
    }

    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_engine_error(&self) {
        self.engine_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_forward_retry(&self) {
        self.forward_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_request_failed(&self) {
        self.requests_failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_migration(&self) {
        self.migrations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn engine_errors(&self) -> u64 {
        self.engine_errors.load(Ordering::Relaxed)
    }

    pub fn forward_retries(&self) -> u64 {
        self.forward_retries.load(Ordering::Relaxed)
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn requests_failed(&self) -> u64 {
        self.requests_failed.load(Ordering::Relaxed)
    }

    pub fn preemptions(&self) -> u64 {
        self.preemptions.load(Ordering::Relaxed)
    }

    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    pub fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch_iteration(&self, occupancy: usize) {
        self.batch_iterations.fetch_add(1, Ordering::Relaxed);
        self.batch_occupancy_sum
            .fetch_add(occupancy as u64, Ordering::Relaxed);
    }

    /// Overwrite the block-pool gauges and cumulative prefix-cache
    /// counters from a fresh engine snapshot (workers push one per
    /// scheduler iteration and at lane retirement).
    pub fn record_kv(&self, s: &KvStats) {
        self.kv_blocks_total
            .store(s.total_blocks as u64, Ordering::Relaxed);
        self.kv_blocks_free
            .store(s.free_blocks as u64, Ordering::Relaxed);
        self.kv_blocks_cached
            .store(s.cached_blocks as u64, Ordering::Relaxed);
        self.kv_blocks_evictable
            .store(s.evictable_blocks as u64, Ordering::Relaxed);
        self.kv_sealed_entries
            .store(s.sealed_entries as u64, Ordering::Relaxed);
        self.kv_prefix_hits.store(s.prefix_hits, Ordering::Relaxed);
        self.kv_prefix_misses
            .store(s.prefix_misses, Ordering::Relaxed);
        self.kv_evictions.store(s.evictions, Ordering::Relaxed);
        self.kv_cow_copies.store(s.cow_copies, Ordering::Relaxed);
    }

    pub fn prefix_hits(&self) -> u64 {
        self.kv_prefix_hits.load(Ordering::Relaxed)
    }

    pub fn prefix_misses(&self) -> u64 {
        self.kv_prefix_misses.load(Ordering::Relaxed)
    }

    pub fn kv_evictions(&self) -> u64 {
        self.kv_evictions.load(Ordering::Relaxed)
    }

    pub fn kv_blocks_free(&self) -> u64 {
        self.kv_blocks_free.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn failures(&self) -> u64 {
        self.failures.load(Ordering::Relaxed)
    }

    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated.load(Ordering::Relaxed)
    }

    pub fn model_nfe(&self) -> u64 {
        self.model_nfe.load(Ordering::Relaxed)
    }

    pub fn aux_nfe(&self) -> u64 {
        self.aux_nfe.load(Ordering::Relaxed)
    }

    pub fn traces_recorded(&self) -> u64 {
        self.traces_recorded.load(Ordering::Relaxed)
    }

    pub fn proposed(&self) -> u64 {
        self.proposed.load(Ordering::Relaxed)
    }

    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    pub fn batch_iterations(&self) -> u64 {
        self.batch_iterations.load(Ordering::Relaxed)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn snapshot_json(&self) -> Json {
        let iters = self.batch_iterations.load(Ordering::Relaxed);
        let occ = if iters > 0 {
            self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / iters as f64
        } else {
            0.0
        };
        let proposed = self.proposed();
        let accept_rate = if proposed > 0 {
            self.accepted() as f64 / proposed as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("replica", Json::num(self.id as f64)),
            ("state", Json::str(self.state().as_str())),
            ("requests", Json::num(self.requests() as f64)),
            ("failures", Json::num(self.failures() as f64)),
            (
                "tokens_generated",
                Json::num(self.tokens_generated() as f64),
            ),
            ("model_nfe", Json::num(self.model_nfe() as f64)),
            ("aux_nfe", Json::num(self.aux_nfe() as f64)),
            ("proposed", Json::num(proposed as f64)),
            ("accepted", Json::num(self.accepted() as f64)),
            ("acceptance_rate", Json::num(accept_rate)),
            ("batch_iterations", Json::num(iters as f64)),
            ("mean_batch_occupancy", Json::num(occ)),
            ("cancelled", Json::num(self.cancelled() as f64)),
            (
                "kv_blocks_total",
                Json::num(self.kv_blocks_total.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_blocks_free",
                Json::num(self.kv_blocks_free.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_blocks_cached",
                Json::num(self.kv_blocks_cached.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_blocks_evictable",
                Json::num(self.kv_blocks_evictable.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_sealed_entries",
                Json::num(self.kv_sealed_entries.load(Ordering::Relaxed) as f64),
            ),
            ("prefix_hits", Json::num(self.prefix_hits() as f64)),
            ("prefix_misses", Json::num(self.prefix_misses() as f64)),
            ("kv_evictions", Json::num(self.kv_evictions() as f64)),
            (
                "kv_cow_copies",
                Json::num(self.kv_cow_copies.load(Ordering::Relaxed) as f64),
            ),
            (
                "phase_draft_us",
                Json::num(self.phase_draft_us.load(Ordering::Relaxed) as f64),
            ),
            (
                "phase_forward_us",
                Json::num(self.phase_forward_us.load(Ordering::Relaxed) as f64),
            ),
            (
                "phase_verify_us",
                Json::num(self.phase_verify_us.load(Ordering::Relaxed) as f64),
            ),
            (
                "phase_commit_us",
                Json::num(self.phase_commit_us.load(Ordering::Relaxed) as f64),
            ),
            ("traces_recorded", Json::num(self.traces_recorded() as f64)),
            (
                "trace_spans_dropped",
                Json::num(self.trace_spans_dropped.load(Ordering::Relaxed) as f64),
            ),
            ("engine_errors", Json::num(self.engine_errors() as f64)),
            ("forward_retries", Json::num(self.forward_retries() as f64)),
            ("restarts", Json::num(self.restarts() as f64)),
            ("requests_failed", Json::num(self.requests_failed() as f64)),
            ("preemptions", Json::num(self.preemptions() as f64)),
            ("migrations", Json::num(self.migrations() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(0.010, 100, 50, 0, 80, 60);
        m.record_request(0.020, 50, 25, 5, 40, 30);
        m.record_batch_iteration(3);
        m.record_batch_iteration(1);
        let j = m.snapshot_json();
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("tokens_generated").unwrap().as_f64(), Some(150.0));
        assert_eq!(j.get("model_nfe").unwrap().as_f64(), Some(75.0));
        assert_eq!(j.get("proposed").unwrap().as_f64(), Some(120.0));
        assert_eq!(j.get("accepted").unwrap().as_f64(), Some(90.0));
        let ar = j.get("acceptance_rate").unwrap().as_f64().unwrap();
        assert!((ar - 0.75).abs() < 1e-9);
        assert_eq!(j.get("mean_batch_occupancy").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn replica_stats_counts_and_states() {
        let r = ReplicaStats::new(2);
        assert_eq!(r.state(), ReplicaState::Starting);
        r.set_state(ReplicaState::Running);
        r.record_request(10, 4, 2, 12, 9);
        r.record_request(6, 3, 1, 8, 6);
        r.record_failure();
        r.record_batch_iteration(3);
        r.record_batch_iteration(1);
        let j = r.snapshot_json();
        assert_eq!(j.get("replica").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("state").unwrap().as_str(), Some("running"));
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("failures").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("tokens_generated").unwrap().as_f64(), Some(16.0));
        assert_eq!(j.get("model_nfe").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("aux_nfe").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("proposed").unwrap().as_f64(), Some(20.0));
        assert_eq!(j.get("accepted").unwrap().as_f64(), Some(15.0));
        assert_eq!(j.get("acceptance_rate").unwrap().as_f64(), Some(0.75));
        assert_eq!(j.get("mean_batch_occupancy").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn lifecycle_counters_and_latency_split() {
        let m = Metrics::new();
        m.record_ttft(0.010);
        m.record_ttft(0.030);
        m.record_itl(0.002);
        m.record_cancelled();
        m.record_deadline_expired();
        m.record_shed();
        m.record_shed();
        let j = m.snapshot_json();
        assert!(j.get("ttft_mean_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("itl_mean_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("cancelled").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("deadline_expired").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("shed").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.cancelled(), 1);
        assert_eq!(m.shed(), 2);
        let r = ReplicaStats::new(0);
        r.record_cancelled();
        assert_eq!(r.cancelled(), 1);
        assert_eq!(
            r.snapshot_json().get("cancelled").unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn kv_counters_and_gauges() {
        let m = Metrics::new();
        m.record_prefix_cache(3, 1, 2, 4);
        m.record_prefix_cache(0, 0, 0, 0); // delta-free push is a no-op
        let j = m.snapshot_json();
        assert_eq!(j.get("prefix_hits").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("prefix_misses").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("kv_evictions").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("kv_cow_copies").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("prefix_hit_rate").unwrap().as_f64(), Some(0.75));
        assert_eq!(m.prefix_hits(), 3);
        assert_eq!(m.prefix_misses(), 1);
        assert_eq!(m.kv_evictions(), 2);

        let r = ReplicaStats::new(0);
        let s = KvStats {
            block_rows: 16,
            total_blocks: 8,
            free_blocks: 5,
            cached_blocks: 2,
            evictable_blocks: 1,
            sealed_entries: 2,
            prefix_hits: 4,
            prefix_misses: 6,
            evictions: 1,
            cow_copies: 3,
        };
        r.record_kv(&s);
        let j = r.snapshot_json();
        assert_eq!(j.get("kv_blocks_total").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.get("kv_blocks_free").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("kv_blocks_cached").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("kv_blocks_evictable").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("kv_sealed_entries").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("prefix_hits").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("prefix_misses").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.get("kv_evictions").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("kv_cow_copies").unwrap().as_f64(), Some(3.0));
        // gauges overwrite, not accumulate
        r.record_kv(&KvStats { free_blocks: 8, ..s });
        assert_eq!(r.kv_blocks_free(), 8);
    }

    fn sample_trace(completed: bool, model_nfe: u64, commits: usize) -> RequestTrace {
        use crate::obs::TraceBuilder;
        let mut b = TraceBuilder::new(7, 0, "spec", Instant::now(), 64);
        b.push_at(SpanKind::QueueWait, 0, 0, 120, 0, 0);
        b.push_at(SpanKind::Draft, 0, 120, 40, 4, 0);
        b.push_at(SpanKind::Forward, 0, 160, 300, 2, 1);
        b.push_at(SpanKind::Verify, 0, 460, 25, 3, 4);
        b.push_at(SpanKind::Commit, 0, 485, 10, commits as u64, 0);
        b.add_commits(commits);
        b.finish(completed, model_nfe, 1, 1, 4, 3, "bigram".into())
    }

    #[test]
    fn trace_fold_updates_phases_and_acceptance() {
        let m = Metrics::new();
        m.record_trace(&sample_trace(true, 2, 4));
        m.record_trace(&sample_trace(true, 2, 4));
        let j = m.snapshot_json();
        assert_eq!(j.get("traces_recorded").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("theorem2_violations").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("phase_draft_us").unwrap().as_f64(), Some(80.0));
        assert_eq!(j.get("phase_forward_us").unwrap().as_f64(), Some(600.0));
        assert_eq!(j.get("phase_verify_us").unwrap().as_f64(), Some(50.0));
        assert_eq!(j.get("phase_commit_us").unwrap().as_f64(), Some(20.0));
        let by = j.get("acceptance_by_drafter").unwrap();
        let bigram = by.get("bigram").unwrap();
        assert_eq!(bigram.get("requests").unwrap().as_f64(), Some(2.0));
        assert!((bigram.get("mean").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-9);
        // A completed request claiming more model NFEs than commits trips
        // the Theorem-2 violation counter; an aborted one does not.
        m.record_trace(&sample_trace(true, 9, 4));
        m.record_trace(&sample_trace(false, 9, 4));
        assert_eq!(m.theorem2_violations(), 1);
        assert_eq!(m.traces_recorded(), 4);
    }

    #[test]
    fn replica_trace_fold_sums_phase_walltime() {
        let r = ReplicaStats::new(0);
        r.record_trace(&sample_trace(true, 2, 4));
        let j = r.snapshot_json();
        assert_eq!(j.get("traces_recorded").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("phase_draft_us").unwrap().as_f64(), Some(40.0));
        assert_eq!(j.get("phase_forward_us").unwrap().as_f64(), Some(300.0));
        assert_eq!(j.get("phase_verify_us").unwrap().as_f64(), Some(25.0));
        assert_eq!(j.get("phase_commit_us").unwrap().as_f64(), Some(10.0));
    }

    #[test]
    fn prometheus_exposition_covers_pool_and_replicas() {
        let m = Metrics::new();
        m.record_request(0.010, 100, 50, 5, 80, 60);
        m.record_trace(&sample_trace(true, 2, 4));
        let r = ReplicaStats::new(0);
        r.record_request(100, 50, 5, 80, 60);
        let text = m.prometheus(std::slice::from_ref(&r));
        assert!(text.contains("# TYPE asarm_requests_total counter"));
        assert!(text.contains("asarm_requests_total 1"));
        assert!(text.contains("asarm_model_nfe_total 50"));
        assert!(text.contains("asarm_aux_nfe_total 5"));
        assert!(text.contains("# TYPE asarm_request_latency_seconds histogram"));
        assert!(text.contains("asarm_request_latency_seconds_count 1"));
        assert!(text.contains("asarm_phase_seconds_bucket{phase=\"forward\",le=\"+Inf\"} 1"));
        assert!(text.contains("asarm_acceptance_rate_bucket{drafter=\"bigram\""));
        assert!(text.contains("asarm_replica_requests_total{replica=\"0\"} 1"));
        assert!(text.contains("asarm_theorem2_violations_total 0"));
        // every line is HELP, TYPE, or a sample — no stray blank lines
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.contains(' '),
                "malformed line: {line:?}"
            );
        }
    }

    #[test]
    fn fault_counters_on_both_surfaces() {
        let m = Metrics::new();
        m.record_engine_error(ErrorClass::Transient);
        m.record_engine_error(ErrorClass::Transient);
        m.record_engine_error(ErrorClass::LaneCorrupt);
        m.record_engine_error(ErrorClass::Fatal);
        m.record_forward_retry();
        m.record_replica_restart();
        m.record_request_failed();
        assert_eq!(m.engine_errors(), (2, 1, 1));
        let j = m.snapshot_json();
        assert_eq!(j.get("engine_errors_transient").unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("engine_errors_lane_corrupt").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(j.get("engine_errors_fatal").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("forward_retries").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("replica_restarts").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("requests_failed").unwrap().as_f64(), Some(1.0));
        let text = m.prometheus(&[]);
        assert!(text.contains("asarm_engine_errors_total{class=\"transient\"} 2"));
        assert!(text.contains("asarm_engine_errors_total{class=\"lane_corrupt\"} 1"));
        assert!(text.contains("asarm_engine_errors_total{class=\"fatal\"} 1"));
        assert!(text.contains("asarm_forward_retries_total 1"));
        assert!(text.contains("asarm_replica_restarts_total 1"));
        assert!(text.contains("asarm_requests_failed_total 1"));

        let r = ReplicaStats::new(0);
        r.record_engine_error();
        r.record_forward_retry();
        r.record_restart();
        r.record_request_failed();
        r.set_state(ReplicaState::Degraded);
        let j = r.snapshot_json();
        assert_eq!(j.get("state").unwrap().as_str(), Some("degraded"));
        assert_eq!(j.get("engine_errors").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("forward_retries").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("restarts").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("requests_failed").unwrap().as_f64(), Some(1.0));
        r.set_state(ReplicaState::Quarantined);
        assert_eq!(r.state(), ReplicaState::Quarantined);
        assert!(r.state().is_serving());
        r.set_state(ReplicaState::Failed);
        assert!(!r.state().is_serving());
    }

    #[test]
    fn thread_safe() {
        let m = Metrics::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record_request(0.001, 1, 1, 0, 1, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.requests(), 800);
    }
}
