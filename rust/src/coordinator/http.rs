//! Minimal HTTP/1.1 server substrate over std::net (no tokio offline).
//!
//! Routes:
//!   POST /v1/infill   — InfillRequest JSON -> InfillResponse JSON
//!   GET  /metrics     — pool-aggregate metrics snapshot JSON
//!   GET  /replicas    — per-replica stats JSON array (id, state, counters)
//!   GET  /healthz     — liveness
//!
//! Connections are handled on the thread pool; each request round-trips
//! through the scheduler handle (the engines themselves stay on their
//! worker threads). Connection: close semantics (one request per
//! connection) keeps the parser simple; the bench client follows suit.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::metrics::Metrics;
use super::request::InfillRequest;
use super::scheduler::SchedulerHandle;

pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    handle: SchedulerHandle,
    metrics: Metrics,
    pool: Arc<ThreadPool>,
}

impl HttpServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(
        addr: &str,
        handle: SchedulerHandle,
        metrics: Metrics,
        workers: usize,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        Ok(HttpServer {
            addr,
            listener,
            handle,
            metrics,
            pool: Arc::new(ThreadPool::new(workers)),
        })
    }

    /// Serve forever (blocks the calling thread).
    pub fn serve(self) -> Result<()> {
        for stream in self.listener.incoming() {
            match stream {
                Ok(s) => {
                    let handle = self.handle.clone();
                    let metrics = self.metrics.clone();
                    self.pool.execute(move || {
                        let _ = handle_conn(s, handle, metrics);
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
        }
        Ok(())
    }

    /// Serve on a background thread; returns the bound address.
    pub fn serve_background(self) -> std::net::SocketAddr {
        let addr = self.addr;
        std::thread::Builder::new()
            .name("http".into())
            .spawn(move || {
                let _ = self.serve();
            })
            .expect("spawn http");
        addr
    }
}

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("no path"))?.to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > 1 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

fn write_response(stream: &mut TcpStream, status: u16, reason: &str, body: &str) -> Result<()> {
    let resp = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn handle_conn(mut stream: TcpStream, handle: SchedulerHandle, metrics: Metrics) -> Result<()> {
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
            return write_response(&mut stream, 400, "Bad Request", &body);
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => write_response(&mut stream, 200, "OK", r#"{"status":"ok"}"#),
        ("GET", "/metrics") => {
            write_response(&mut stream, 200, "OK", &metrics.snapshot_json().to_string())
        }
        ("GET", "/replicas") => {
            write_response(&mut stream, 200, "OK", &handle.replicas_json().to_string())
        }
        ("POST", "/v1/infill") => {
            let run = || -> Result<String> {
                let text = std::str::from_utf8(&req.body).context("body not utf-8")?;
                let j = Json::parse(text).map_err(|e| anyhow!("bad json: {e}"))?;
                let infill = InfillRequest::from_json(&j)?;
                let resp = handle.infill(infill)?;
                Ok(resp.to_json().to_string())
            };
            match run() {
                Ok(body) => write_response(&mut stream, 200, "OK", &body),
                Err(e) => {
                    let body =
                        Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
                    write_response(&mut stream, 400, "Bad Request", &body)
                }
            }
        }
        _ => write_response(&mut stream, 404, "Not Found", r#"{"error":"not found"}"#),
    }
}

/// A tiny blocking HTTP client (bench load generator / tests).
pub fn http_post(addr: &std::net::SocketAddr, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    read_http_response(stream)
}

pub fn http_get(addr: &std::net::SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    read_http_response(stream)
}

fn read_http_response(stream: TcpStream) -> Result<(u16, String)> {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line: {status_line}"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}
