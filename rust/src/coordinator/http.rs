//! Minimal HTTP/1.1 server substrate over std::net (no tokio offline).
//!
//! Routes:
//!   POST /v1/infill        — InfillRequest JSON -> InfillResponse JSON
//!                            (blocks until the decode finishes)
//!   POST /infill/stream    — same request JSON, but the response is a
//!                            chunked `text/event-stream` (SSE): one
//!     (alias /v1/infill/stream)  `commit` event per accepted chunk with
//!                            positions, tokens, and the incrementally
//!                            decodable text, then a terminal
//!                            `done`/`error` event
//!   GET  /metrics          — pool-aggregate metrics snapshot JSON
//!                            (incl. TTFT / inter-token latency /
//!                            cancelled / shed); with
//!                            `Accept: text/plain` the same counters
//!                            in Prometheus text exposition instead
//!   GET  /replicas         — per-replica stats JSON array (each object
//!                            carries the pool's `retry_budget`)
//!   POST /drain            — checkpoint active slots and refuse new
//!                            admissions (drain-free restart prep);
//!                            `?resume=1` lifts the drain and re-admits
//!                            the parked slots; returns the drain state
//!   GET  /drain            — drain state JSON (`draining`, `parked`,
//!                            `preemptions`, `migrations`, `drains`)
//!   GET  /trace/recent     — index of recently retired request
//!                            traces (one summary object per trace,
//!                            newest first; `[]` when tracing is off);
//!                            `?limit=N` bounds the response, clamped
//!                            to the trace-ring capacity
//!   GET  /trace/{id}       — full trace for one request as Chrome
//!                            trace-event JSON (load into
//!                            chrome://tracing or Perfetto)
//!   GET  /debug/vars       — JSON snapshot of the rolling per-second
//!                            time-series (merged across replicas) plus
//!                            the speculation heatmap/curve aggregates;
//!                            `?window=N` selects the trailing seconds
//!   GET  /debug/flight/{id}— one sampled request's speculation flight
//!                            record (windows, per-position outcomes,
//!                            entropies, adaptive-window trajectory)
//!   GET  /debug/dashboard  — self-contained HTML dashboard polling
//!                            /debug/vars (no external assets)
//!   GET  /healthz          — pool liveness: 200 while any replica is
//!                            serving (or restarting under supervision),
//!                            503 once every replica is Stopped/Failed;
//!                            body carries per-replica states
//!
//! Connections are handled on the thread pool; each request round-trips
//! through the scheduler handle (the engines themselves stay on their
//! worker threads). Connection: close semantics (one request per
//! connection) keeps the parser simple; the bench client follows suit.
//!
//! Backpressure: when the scheduler's bounded admission queue is full,
//! BOTH infill endpoints shed with `429 Too Many Requests` +
//! `Retry-After` instead of queueing without bound. On the streaming
//! path a failed socket write (client went away) flips the request's
//! cancel token so the scheduler frees the batch slot within one
//! iteration; between commits, keepalive comments are written on an idle
//! timeout so a silent disconnect is still noticed.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::lifecycle::{Event, TextAssembler};
use super::metrics::Metrics;
use super::request::InfillRequest;
use super::scheduler::{SchedulerHandle, SubmitError};

/// How long the SSE writer waits for the next event before emitting a
/// keepalive comment (which doubles as disconnect detection).
const SSE_KEEPALIVE: Duration = Duration::from_millis(500);

/// How many trace summaries GET /trace/recent returns (newest first)
/// when the client passes no `?limit=`. The full per-replica rings
/// usually hold more; this bounds the response body, not the retention.
const TRACE_RECENT_LIMIT: usize = 64;

/// Default trailing window (seconds) for GET /debug/vars when the client
/// passes no `?window=`.
const DEBUG_VARS_WINDOW: usize = 60;

/// First value of `key` in a raw query string (`a=1&b=2`). No percent
/// decoding: every parameter this server accepts is numeric.
fn query_param<'q>(query: &'q str, key: &str) -> Option<&'q str> {
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v)
}

pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    handle: SchedulerHandle,
    metrics: Metrics,
    pool: Arc<ThreadPool>,
}

impl HttpServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(
        addr: &str,
        handle: SchedulerHandle,
        metrics: Metrics,
        workers: usize,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        Ok(HttpServer {
            addr,
            listener,
            handle,
            metrics,
            pool: Arc::new(ThreadPool::new(workers)),
        })
    }

    /// Serve forever (blocks the calling thread).
    pub fn serve(self) -> Result<()> {
        for stream in self.listener.incoming() {
            match stream {
                Ok(s) => {
                    let handle = self.handle.clone();
                    let metrics = self.metrics.clone();
                    self.pool.execute(move || {
                        let _ = handle_conn(s, handle, metrics);
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
        }
        Ok(())
    }

    /// Serve on a background thread; returns the bound address.
    pub fn serve_background(self) -> std::net::SocketAddr {
        let addr = self.addr;
        std::thread::Builder::new()
            .name("http".into())
            .spawn(move || {
                let _ = self.serve();
            })
            .expect("spawn http");
        addr
    }
}

struct Request {
    method: String,
    path: String,
    /// Raw `Accept` header value (empty when absent). Only consulted
    /// for content negotiation on GET /metrics.
    accept: String,
    body: Vec<u8>,
}

impl Request {
    /// Does the client prefer a plain-text body? Deliberately loose
    /// matching (`text/plain` anywhere in the Accept list) — Prometheus
    /// scrapers send long q-weighted lists and we only distinguish
    /// "wants text exposition" from the JSON default.
    fn wants_text(&self) -> bool {
        self.accept.to_ascii_lowercase().contains("text/plain")
    }
}

/// The GET /debug/dashboard payload: one self-contained page (inline
/// CSS/JS, no external assets — it must render from an air-gapped box)
/// that polls /debug/vars and draws the rolling time-series plus the
/// positional-acceptance heatmap and entropy acceptance curves.
const DASHBOARD_HTML: &str = r##"<!doctype html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>asarm dashboard</title>
<style>
 body{font:13px/1.4 system-ui,sans-serif;margin:16px;background:#111;color:#ddd}
 h1{font-size:16px;margin:0 0 4px}
 h2{font-size:13px;margin:14px 0 4px;color:#9cf}
 .meta{color:#888}
 .grid{display:flex;flex-wrap:wrap;gap:16px}
 canvas{background:#1a1a1a;border:1px solid #333}
 table{border-collapse:collapse;margin-top:4px}
 td,th{border:1px solid #333;padding:2px 6px;text-align:right;font-variant-numeric:tabular-nums}
 th{color:#9cf;font-weight:normal}
 td.hm{color:#111;min-width:34px}
 #err{color:#f66}
</style>
</head>
<body>
<h1>asarm — speculation &amp; serving dashboard</h1>
<div class="meta">polls <code>/debug/vars?window=120</code> every 2s
 &middot; uptime <span id="up">?</span>s
 &middot; queue depth <span id="qd">?</span>
 &middot; flight records <span id="fr">?</span> (dropped <span id="fd">?</span>, rate <span id="fs">?</span>)
 <span id="err"></span></div>
<div class="grid">
 <div><h2>tokens/s &amp; model NFE/s</h2><canvas id="tps" width="460" height="140"></canvas></div>
 <div><h2>accept rate (per second)</h2><canvas id="acc" width="460" height="140"></canvas></div>
 <div><h2>queue depth &amp; batch occupancy</h2><canvas id="load" width="460" height="140"></canvas></div>
 <div><h2>KV blocks free &amp; engine errors</h2><canvas id="kv" width="460" height="140"></canvas></div>
</div>
<h2>positional acceptance heatmap (accept rate &times; window position &times; drafter)</h2>
<div id="heat"></div>
<h2>entropy-bucketed acceptance (accept rate by target-entropy bucket, nats)</h2>
<div id="curves"></div>
<script>
"use strict";
function line(id, rows, series, colors, ymaxHint) {
  const c = document.getElementById(id), g = c.getContext("2d");
  g.clearRect(0, 0, c.width, c.height);
  if (!rows.length) return;
  let ymax = ymaxHint || 0;
  for (const s of series) for (const r of rows) ymax = Math.max(ymax, s.get(r));
  ymax = ymax || 1;
  g.strokeStyle = "#333";
  g.strokeRect(0.5, 0.5, c.width - 1, c.height - 1);
  series.forEach((s, si) => {
    g.strokeStyle = colors[si];
    g.beginPath();
    rows.forEach((r, i) => {
      const x = rows.length < 2 ? c.width / 2 : i * (c.width - 8) / (rows.length - 1) + 4;
      const y = c.height - 4 - (s.get(r) / ymax) * (c.height - 8);
      i ? g.lineTo(x, y) : g.moveTo(x, y);
    });
    g.stroke();
    g.fillStyle = colors[si];
    g.fillText(s.name + " (max " + ymax.toFixed(ymax < 2 ? 2 : 0) + ")", 6, 12 + 12 * si);
  });
}
function shade(rate) {
  const t = Math.max(0, Math.min(1, rate));
  return "rgb(" + Math.round(230 - 160 * t) + "," + Math.round(70 + 160 * t) + ",80)";
}
function heatTable(heat) {
  let maxPos = 0;
  for (const h of heat) maxPos = Math.max(maxPos, ...h.positions.map(p => p.pos + 1));
  if (!heat.length || !maxPos) return "<div class=meta>no speculation windows recorded yet</div>";
  let html = "<table><tr><th>drafter</th><th>windows</th>";
  for (let p = 0; p < maxPos; p++) html += "<th>p" + p + "</th>";
  html += "</tr>";
  for (const h of heat) {
    html += "<tr><th>" + h.drafter + "</th><td>" + h.windows + "</td>";
    for (let p = 0; p < maxPos; p++) {
      const cell = h.positions.find(x => x.pos === p);
      html += cell
        ? "<td class=hm style='background:" + shade(cell.accept_rate) + "' title='" +
          cell.accepted + "/" + cell.proposed + "'>" + cell.accept_rate.toFixed(2) + "</td>"
        : "<td></td>";
    }
    html += "</tr>";
  }
  return html + "</table>";
}
function curveTable(heat) {
  if (!heat.length) return "<div class=meta>no data</div>";
  const les = heat[0].entropy_curve.map(b => b.le);
  let html = "<table><tr><th>drafter</th>";
  for (const le of les) html += "<th>&le;" + le + "</th>";
  html += "</tr>";
  for (const h of heat) {
    html += "<tr><th>" + h.drafter + "</th>";
    for (const b of h.entropy_curve) {
      html += b.proposed > 0
        ? "<td class=hm style='background:" + shade(b.accept_rate) + "' title='" +
          b.accepted + "/" + b.proposed + "'>" + b.accept_rate.toFixed(2) + "</td>"
        : "<td></td>";
    }
    html += "</tr>";
  }
  return html + "</table>";
}
async function tick() {
  try {
    const v = await (await fetch("/debug/vars?window=120")).json();
    document.getElementById("err").textContent = "";
    document.getElementById("up").textContent = v.uptime_sec;
    document.getElementById("qd").textContent = v.queue_depth;
    document.getElementById("fr").textContent = v.flight.recorded;
    document.getElementById("fd").textContent = v.flight.dropped;
    document.getElementById("fs").textContent = v.flight.sample_rate;
    const rows = v.series;
    line("tps", rows, [
      { name: "tokens/s", get: r => r.tokens },
      { name: "model NFE/s", get: r => r.model_nfe },
    ], ["#6cf", "#fc6"]);
    line("acc", rows, [{ name: "accept rate", get: r => r.accept_rate }], ["#6f6"], 1);
    line("load", rows, [
      { name: "queue depth", get: r => r.queue_depth },
      { name: "batch occupancy", get: r => r.batch_occupancy },
    ], ["#f96", "#96f"]);
    line("kv", rows, [
      { name: "kv blocks free", get: r => r.kv_blocks_free },
      { name: "engine errors/s", get: r => r.errors_transient + r.errors_lane_corrupt + r.errors_fatal },
    ], ["#9cf", "#f66"]);
    document.getElementById("heat").innerHTML = heatTable(v.heatmap);
    document.getElementById("curves").innerHTML = curveTable(v.heatmap);
  } catch (e) {
    document.getElementById("err").textContent = " — fetch failed: " + e;
  }
}
tick();
setInterval(tick, 2000);
</script>
</body>
</html>
"##;

fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("no path"))?.to_string();
    let mut content_length = 0usize;
    let mut accept = String::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
            if k.eq_ignore_ascii_case("accept") {
                accept = v.trim().to_string();
            }
        }
    }
    if content_length > 1 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request {
        method,
        path,
        accept,
        body,
    })
}

fn write_response(stream: &mut TcpStream, status: u16, reason: &str, body: &str) -> Result<()> {
    write_response_typed(stream, status, reason, "application/json", &[], body)
}

fn write_response_headers(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
) -> Result<()> {
    write_response_typed(stream, status, reason, "application/json", extra_headers, body)
}

fn write_response_typed(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
) -> Result<()> {
    let mut resp = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        resp.push_str(&format!("{k}: {v}\r\n"));
    }
    resp.push_str("\r\n");
    resp.push_str(body);
    stream.write_all(resp.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn shed_response(stream: &mut TcpStream) -> Result<()> {
    write_response_headers(
        stream,
        429,
        "Too Many Requests",
        &[("Retry-After", "1")],
        r#"{"error":"admission queue full; retry later"}"#,
    )
}

/// The scheduler pool is gone (every replica failed or shut down): a
/// server-side condition, so 503 — not a 400 that would stop clients
/// and alerting from treating it as retryable/page-worthy.
fn unavailable_response(stream: &mut TcpStream) -> Result<()> {
    write_response_headers(
        stream,
        503,
        "Service Unavailable",
        &[("Retry-After", "5")],
        r#"{"error":"scheduler shut down"}"#,
    )
}

/// Every replica died beyond the supervisor's restart budget: the same
/// 503 + Retry-After as an orderly shutdown (load balancers treat both
/// as "stop routing here"), but the body names the fault for operators.
fn replicas_lost_response(stream: &mut TcpStream) -> Result<()> {
    write_response_headers(
        stream,
        503,
        "Service Unavailable",
        &[("Retry-After", "5")],
        r#"{"error":"all replicas lost; request cannot be served"}"#,
    )
}

/// The pool is draining (POST /drain): admissions are refused but the
/// replicas are healthy and will serve again once the drain lifts —
/// 503 + Retry-After, distinguishable from the 429 shed (queue full)
/// because the client should NOT retry against this instance until its
/// operator finishes the restart.
fn draining_response(stream: &mut TcpStream) -> Result<()> {
    write_response_headers(
        stream,
        503,
        "Service Unavailable",
        &[("Retry-After", "5")],
        r#"{"error":"pool draining; new admissions refused until drain is lifted"}"#,
    )
}

/// One HTTP chunk (`Transfer-Encoding: chunked`), flushed immediately so
/// SSE events reach the client as they happen.
fn write_chunk(stream: &mut TcpStream, payload: &str) -> Result<()> {
    stream.write_all(format!("{:x}\r\n", payload.len()).as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.write_all(b"\r\n")?;
    stream.flush()?;
    Ok(())
}

/// One SSE frame as one HTTP chunk. `data` must be single-line (the JSON
/// serializer never emits raw newlines).
fn write_sse_event(stream: &mut TcpStream, event: &str, data: &str) -> Result<()> {
    write_chunk(stream, &format!("event: {event}\ndata: {data}\n\n"))
}

fn handle_conn(mut stream: TcpStream, handle: SchedulerHandle, metrics: Metrics) -> Result<()> {
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
            return write_response(&mut stream, 400, "Bad Request", &body);
        }
    };
    // The request target may carry a query string; routing matches on
    // the bare path and each route parses its own parameters.
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            // Liveness is pool-level: 200 while any replica serves or
            // will serve again (Starting/Running/Degraded/Quarantined-
            // pending-restart), 503 once every replica is permanently
            // Stopped or Failed. Load balancers key on the status code;
            // the JSON body carries the per-replica detail.
            let body = handle.healthz_json().to_string();
            if handle.healthy() {
                write_response(&mut stream, 200, "OK", &body)
            } else {
                write_response_headers(
                    &mut stream,
                    503,
                    "Service Unavailable",
                    &[("Retry-After", "5")],
                    &body,
                )
            }
        }
        ("GET", "/metrics") => {
            // Content negotiation: Prometheus scrapers ask for
            // text/plain and get the text exposition (which folds in
            // per-replica series); everyone else keeps the JSON
            // snapshot that PR 1-6 clients already parse.
            if req.wants_text() {
                write_response_typed(
                    &mut stream,
                    200,
                    "OK",
                    crate::obs::prometheus::CONTENT_TYPE,
                    &[],
                    &handle.prometheus_text(),
                )
            } else {
                write_response(&mut stream, 200, "OK", &metrics.snapshot_json().to_string())
            }
        }
        ("GET", "/replicas") => {
            write_response(&mut stream, 200, "OK", &handle.replicas_json().to_string())
        }
        ("GET", "/drain") => {
            write_response(&mut stream, 200, "OK", &handle.drain_json().to_string())
        }
        ("POST", "/drain") => {
            // Admin surface for drain-free restarts: flip the drain flag
            // so workers checkpoint their active slots onto the resume
            // deque and submit() refuses admissions; `?resume=1` lifts
            // it and the parked slots re-admit with warm-prefix restore.
            let lift = query_param(query, "resume").is_some_and(|v| v != "0");
            handle.set_draining(!lift);
            write_response(&mut stream, 200, "OK", &handle.drain_json().to_string())
        }
        ("GET", "/trace/recent") => {
            // `?limit=N` bounds the response body; clamped to the ring
            // capacity because a larger limit cannot return more than
            // the per-replica rings retain anyway.
            let limit = match query_param(query, "limit") {
                None => TRACE_RECENT_LIMIT,
                Some(raw) => match raw.parse::<usize>() {
                    Ok(n) => n.min(handle.trace_capacity()),
                    Err(_) => {
                        let body = r#"{"error":"limit must be a non-negative integer"}"#;
                        return write_response(&mut stream, 400, "Bad Request", body);
                    }
                },
            };
            write_response(
                &mut stream,
                200,
                "OK",
                &handle.trace_recent_json(limit).to_string(),
            )
        }
        ("GET", p) if p.starts_with("/trace/") => {
            match p["/trace/".len()..].parse::<u64>() {
                Err(_) => {
                    let body = r#"{"error":"trace id must be a decimal request id"}"#;
                    write_response(&mut stream, 400, "Bad Request", body)
                }
                Ok(id) => match handle.trace_chrome_json(id) {
                    Some(j) => write_response(&mut stream, 200, "OK", &j.to_string()),
                    // Distinguishable from the route-miss 404 by body:
                    // either tracing is off, the id never existed, or
                    // the ring already evicted it.
                    None => write_response(
                        &mut stream,
                        404,
                        "Not Found",
                        r#"{"error":"no trace for that request id (tracing off, or evicted from the ring)"}"#,
                    ),
                },
            }
        }
        ("GET", "/debug/vars") => {
            // `?window=N` selects the trailing seconds of time-series
            // history; the ring snapshot clamps it to its capacity.
            let window = match query_param(query, "window") {
                None => DEBUG_VARS_WINDOW,
                Some(raw) => match raw.parse::<usize>() {
                    Ok(n) => n.max(1),
                    Err(_) => {
                        let body = r#"{"error":"window must be a positive integer (seconds)"}"#;
                        return write_response(&mut stream, 400, "Bad Request", body);
                    }
                },
            };
            write_response(&mut stream, 200, "OK", &handle.debug_vars_json(window).to_string())
        }
        ("GET", p) if p.starts_with("/debug/flight/") => {
            match p["/debug/flight/".len()..].parse::<u64>() {
                Err(_) => {
                    let body = r#"{"error":"flight id must be a decimal request id"}"#;
                    write_response(&mut stream, 400, "Bad Request", body)
                }
                Ok(id) => match handle.flight_json(id) {
                    Some(j) => write_response(&mut stream, 200, "OK", &j.to_string()),
                    None => write_response(
                        &mut stream,
                        404,
                        "Not Found",
                        r#"{"error":"no flight record for that request id (not sampled, or evicted from the ring)"}"#,
                    ),
                },
            }
        }
        ("GET", "/debug/dashboard") => write_response_typed(
            &mut stream,
            200,
            "OK",
            "text/html; charset=utf-8",
            &[],
            DASHBOARD_HTML,
        ),
        ("POST", "/v1/infill") => {
            let infill = match parse_infill(&req.body) {
                Ok(r) => r,
                Err(e) => return bad_request(&mut stream, &e),
            };
            match handle.submit(infill) {
                Err(SubmitError::QueueFull(_)) => shed_response(&mut stream),
                Err(SubmitError::ShutDown) => unavailable_response(&mut stream),
                Err(SubmitError::ReplicaLost) => replicas_lost_response(&mut stream),
                Err(SubmitError::Draining) => draining_response(&mut stream),
                Ok(rh) => match wait_watching_socket(rh, &stream) {
                    Some(Ok(resp)) => {
                        write_response(&mut stream, 200, "OK", &resp.to_json().to_string())
                    }
                    Some(Err(e)) => bad_request(&mut stream, &e),
                    // client vanished mid-request: nothing to answer
                    None => Ok(()),
                },
            }
        }
        ("POST", "/infill/stream") | ("POST", "/v1/infill/stream") => {
            handle_stream(stream, handle, &req.body)
        }
        _ => write_response(&mut stream, 404, "Not Found", r#"{"error":"not found"}"#),
    }
}

/// Has the peer closed its end? A non-blocking `peek`: EOF (`Ok(0)`) or
/// a hard error means gone; `WouldBlock` means an open, idle socket.
/// Pipelined bytes (`Ok(_)`) count as alive — Connection: close clients
/// never send them, and we must not consume anything here.
///
/// POLICY: a half-close (client `shutdown(WR)` after the request while
/// still reading) is indistinguishable from a full close on the read
/// side, so it too counts as gone and cancels the decode. That is the
/// usual serving-stack interpretation of client EOF mid-request; the
/// deliberate alternative — ignoring EOF — would resurrect the
/// dead-client-holds-a-slot problem this subsystem exists to fix.
/// Half-closing clients should keep the socket fully open (standard
/// HTTP/1.1 practice) or use the SSE endpoint.
fn client_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 1];
    let gone = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

/// Blocking-path wait that still notices a dead client: between events,
/// probe the socket; on disconnect flip the cancel token (freeing the
/// batch slot within one iteration — the same contract as the SSE path)
/// and return None since there is nobody left to answer.
fn wait_watching_socket(
    rh: super::lifecycle::RequestHandle,
    stream: &TcpStream,
) -> Option<Result<crate::coordinator::InfillResponse>> {
    use crate::util::mpmc::RecvTimeoutError;
    loop {
        match rh.next_event_timeout(SSE_KEEPALIVE) {
            // Probe on every commit too: while a decode is active the
            // channel never idles long enough for the Timeout arm, and
            // commits arrive at iteration cadence so the non-blocking
            // peek stays cheap.
            Ok(Event::Committed { .. }) | Err(RecvTimeoutError::Timeout) => {
                if client_gone(stream) {
                    rh.cancel();
                    return None;
                }
                if rh.deadline_overdue() {
                    rh.cancel();
                    return Some(Err(anyhow!("deadline exceeded awaiting scheduler")));
                }
            }
            Ok(Event::Done(resp)) => return Some(Ok(resp)),
            Ok(Event::Error(e)) => return Some(Err(anyhow!(e))),
            Err(RecvTimeoutError::Disconnected) => {
                return Some(Err(anyhow!("scheduler dropped request")))
            }
        }
    }
}

fn parse_infill(body: &[u8]) -> Result<InfillRequest> {
    let text = std::str::from_utf8(body).context("body not utf-8")?;
    let j = Json::parse(text).map_err(|e| anyhow!("bad json: {e}"))?;
    InfillRequest::from_json(&j)
}

fn bad_request(stream: &mut TcpStream, e: &anyhow::Error) -> Result<()> {
    let body = Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
    write_response(stream, 400, "Bad Request", &body)
}

/// The SSE surface: serve one request's event channel as a chunked
/// `text/event-stream`. Any failed write (the client hung up) flips the
/// cancel token so the scheduler frees the slot within one iteration.
fn handle_stream(mut stream: TcpStream, handle: SchedulerHandle, body: &[u8]) -> Result<()> {
    let infill = match parse_infill(body) {
        Ok(r) => r,
        Err(e) => return bad_request(&mut stream, &e),
    };
    // The assembler mirrors the blocking path's text reconstruction
    // incrementally (complete UTF-8 only; lossy like the tokenizer).
    let mut assembler = TextAssembler::new(&infill.text, infill.mask_char);
    let rh = match handle.submit(infill) {
        Err(SubmitError::QueueFull(_)) => return shed_response(&mut stream),
        Err(SubmitError::ShutDown) => return unavailable_response(&mut stream),
        Err(SubmitError::ReplicaLost) => return replicas_lost_response(&mut stream),
        Err(SubmitError::Draining) => return draining_response(&mut stream),
        Ok(rh) => rh,
    };
    let cancel = rh.cancel_token();
    let header = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(header.as_bytes()).is_err() {
        cancel.cancel();
        return Ok(());
    }
    loop {
        use crate::util::mpmc::RecvTimeoutError;
        let event = match rh.next_event_timeout(SSE_KEEPALIVE) {
            Ok(ev) => ev,
            Err(RecvTimeoutError::Timeout) => {
                // Client-side deadline backstop: a request that expired
                // without any worker observing it (deep in a saturated
                // queue) must not stream keepalives forever.
                if rh.deadline_overdue() {
                    rh.cancel();
                    let _ = write_sse_event(
                        &mut stream,
                        "error",
                        &Json::obj(vec![(
                            "error",
                            Json::str("deadline exceeded awaiting scheduler"),
                        )])
                        .to_string(),
                    );
                    break;
                }
                // Idle: keepalive comment doubles as disconnect probe.
                if write_chunk(&mut stream, ": keepalive\n\n").is_err() {
                    cancel.cancel();
                    return Ok(());
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => {
                let _ = write_sse_event(
                    &mut stream,
                    "error",
                    &Json::obj(vec![("error", Json::str("scheduler dropped request"))])
                        .to_string(),
                );
                break;
            }
        };
        let ok = match event {
            Event::Committed { positions, tokens } => {
                let delta = assembler.apply(&positions, &tokens);
                let data = Json::obj(vec![
                    (
                        "positions",
                        Json::Arr(positions.iter().map(|&p| Json::num(p as f64)).collect()),
                    ),
                    (
                        "tokens",
                        Json::Arr(tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                    ),
                    ("text_delta", Json::str(delta)),
                ])
                .to_string();
                write_sse_event(&mut stream, "commit", &data).is_ok()
            }
            Event::Done(resp) => {
                // Flush any bytes held back for UTF-8 completeness so the
                // concatenated deltas equal the final text exactly.
                let tail = assembler.finish();
                if !tail.is_empty() {
                    let data = Json::obj(vec![
                        ("positions", Json::Arr(vec![])),
                        ("tokens", Json::Arr(vec![])),
                        ("text_delta", Json::str(tail)),
                    ])
                    .to_string();
                    if write_sse_event(&mut stream, "commit", &data).is_err() {
                        cancel.cancel();
                        return Ok(());
                    }
                }
                let _ = write_sse_event(&mut stream, "done", &resp.to_json().to_string());
                break;
            }
            Event::Error(e) => {
                let _ = write_sse_event(
                    &mut stream,
                    "error",
                    &Json::obj(vec![("error", Json::str(e))]).to_string(),
                );
                break;
            }
        };
        if !ok {
            // Client went away mid-stream: free the batch slot.
            cancel.cancel();
            return Ok(());
        }
    }
    // Terminal chunk of the chunked encoding.
    let _ = stream.write_all(b"0\r\n\r\n");
    let _ = stream.flush();
    Ok(())
}

/// A tiny blocking HTTP client (bench load generator / tests).
pub fn http_post(addr: &std::net::SocketAddr, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    read_http_response(stream)
}

pub fn http_get(addr: &std::net::SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    read_http_response(stream)
}

/// GET with an explicit `Accept` header (exercises the /metrics content
/// negotiation the way a Prometheus scraper would).
pub fn http_get_accept(
    addr: &std::net::SocketAddr,
    path: &str,
    accept: &str,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nAccept: {accept}\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(req.as_bytes())?;
    read_http_response(stream)
}

/// One parsed server-sent event.
#[derive(Clone, Debug)]
pub struct SseEvent {
    pub event: String,
    pub data: String,
}

/// A streaming response, fully drained: status + headers, and either the
/// parsed SSE events (chunked streams) or the plain body (errors/sheds).
#[derive(Debug, Default)]
pub struct StreamResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
    pub events: Vec<SseEvent>,
}

impl StreamResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// POST and drain a streaming endpoint over a real socket (tests and the
/// serve_e2e example). Chunked bodies are decoded and parsed into SSE
/// events; non-chunked responses (400/429) land in `body`.
pub fn http_post_stream(
    addr: &std::net::SocketAddr,
    path: &str,
    body: &str,
) -> Result<StreamResponse> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nAccept: text/event-stream\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line: {status_line}"))?;
    let mut resp = StreamResponse {
        status,
        ..Default::default()
    };
    let mut content_length = 0usize;
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let (k, v) = (k.trim(), v.trim());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse().unwrap_or(0);
            }
            if k.eq_ignore_ascii_case("transfer-encoding") && v.eq_ignore_ascii_case("chunked") {
                chunked = true;
            }
            resp.headers.push((k.to_string(), v.to_string()));
        }
    }
    if !chunked {
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        resp.body = String::from_utf8_lossy(&body).into_owned();
        return Ok(resp);
    }
    // Decode the chunked stream, then split the SSE frames.
    let mut raw = Vec::new();
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line)?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| anyhow!("bad chunk size: {size_line:?}"))?;
        if size == 0 {
            let mut crlf = String::new();
            let _ = reader.read_line(&mut crlf);
            break;
        }
        let mut chunk = vec![0u8; size + 2]; // payload + trailing CRLF
        reader.read_exact(&mut chunk)?;
        chunk.truncate(size);
        raw.extend_from_slice(&chunk);
    }
    let text = String::from_utf8_lossy(&raw);
    for frame in text.split("\n\n") {
        let mut event = String::new();
        let mut data = String::new();
        for line in frame.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                event = v.to_string();
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v.to_string();
            }
            // comment lines (": keepalive") are dropped
        }
        if !event.is_empty() {
            resp.events.push(SseEvent { event, data });
        }
    }
    Ok(resp)
}

fn read_http_response(stream: TcpStream) -> Result<(u16, String)> {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line: {status_line}"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}
