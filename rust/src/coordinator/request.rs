//! The serving protocol: infill requests/responses and their JSON codec.

use anyhow::{bail, Result};

use crate::draft::{DraftKind, DraftOptions};
use crate::util::json::Json;

/// Which decoder serves the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// ASSD (Algorithm 1) over the configured draft source — the paper's
    /// headline. The `draft` request field picks the drafter.
    Assd,
    /// Legacy alias: ASSD with the context bigram drafter (Algorithm 2).
    /// Equivalent to `assd` with `"draft": {"kind": "bigram"}`.
    AssdNgram,
    /// Sequential factorized decoding (baseline).
    Sequential,
    /// Masked-diffusion baseline (conditional-independence unmasking).
    Diffusion,
}

impl SamplerKind {
    pub const ALL: [SamplerKind; 4] = [
        SamplerKind::Assd,
        SamplerKind::AssdNgram,
        SamplerKind::Sequential,
        SamplerKind::Diffusion,
    ];

    /// Case-insensitive parse; the error lists the valid kinds.
    pub fn parse(s: &str) -> Result<SamplerKind> {
        let lower = s.to_ascii_lowercase();
        for k in SamplerKind::ALL {
            if k.name() == lower {
                return Ok(k);
            }
        }
        bail!(
            "unknown sampler '{s}' (valid kinds: {})",
            SamplerKind::ALL.map(|k| k.name()).join(", ")
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Assd => "assd",
            SamplerKind::AssdNgram => "assd_ngram",
            SamplerKind::Sequential => "sequential",
            SamplerKind::Diffusion => "diffusion",
        }
    }

    /// Resolve the effective draft configuration for this sampler: the
    /// `assd_ngram` legacy alias forces the Algorithm-2 bigram drafter.
    /// Shared by the scheduler's admission path and the eval harness so
    /// serving and bench behavior cannot diverge.
    pub fn effective_draft(&self, draft: DraftOptions) -> DraftOptions {
        match self {
            SamplerKind::AssdNgram => DraftOptions {
                kind: DraftKind::Bigram,
                ..draft
            },
            _ => draft,
        }
    }
}

/// Partially-specified draft configuration, as it arrives on the wire:
/// every field a request leaves out inherits the scheduler's
/// [`super::scheduler::SchedulerConfig::default_draft`] at admission
/// (so `asarm serve --draft bigram --adaptive` applies to legacy
/// `{"k": 5}` requests and partial `{"draft": {...}}` objects alike).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DraftSpec {
    pub kind: Option<DraftKind>,
    pub max_len: Option<usize>,
    pub adaptive: Option<bool>,
}

impl DraftSpec {
    /// Fully-specified spec (CLI / programmatic callers that want no
    /// inheritance).
    pub fn from_options(opts: DraftOptions) -> DraftSpec {
        DraftSpec {
            kind: Some(opts.kind),
            max_len: Some(opts.max_len),
            adaptive: Some(opts.adaptive),
        }
    }

    /// Overlay this spec onto the pool default.
    pub fn resolve(&self, base: DraftOptions) -> DraftOptions {
        DraftOptions {
            kind: self.kind.unwrap_or(base.kind),
            max_len: self.max_len.unwrap_or(base.max_len),
            adaptive: self.adaptive.unwrap_or(base.adaptive),
        }
    }
}

/// An infilling request: text whose `mask_char` runs are to be generated.
#[derive(Clone, Debug)]
pub struct InfillRequest {
    pub text: String,
    pub mask_char: char,
    pub sampler: SamplerKind,
    /// Draft configuration for the ASSD samplers; unspecified fields
    /// inherit the scheduler's default at admission.
    pub draft: DraftSpec,
    /// diffusion steps (Diffusion sampler only)
    pub steps: usize,
    pub temperature: f32,
    pub seed: u64,
    /// Optional deadline, measured from SUBMISSION (queue wait counts):
    /// past it the scheduler retires the request with a partial-progress
    /// error instead of finishing the decode. Wire field `timeout_ms`.
    pub timeout_ms: Option<u64>,
}

impl Default for InfillRequest {
    fn default() -> Self {
        InfillRequest {
            text: String::new(),
            mask_char: '_',
            sampler: SamplerKind::Assd,
            draft: DraftSpec::default(),
            steps: 32,
            temperature: 1.0,
            seed: 0,
            timeout_ms: None,
        }
    }
}

impl InfillRequest {
    pub fn from_json(j: &Json) -> Result<InfillRequest> {
        let mut r = InfillRequest::default();
        match j.get("text").and_then(|t| t.as_str()) {
            Some(t) => r.text = t.to_string(),
            None => bail!("missing 'text'"),
        }
        if let Some(mc) = j.get("mask_char").and_then(|t| t.as_str()) {
            let mut chars = mc.chars();
            r.mask_char = chars.next().unwrap_or('_');
            if chars.next().is_some() {
                bail!("mask_char must be a single character");
            }
        }
        if let Some(s) = j.get("sampler").and_then(|t| t.as_str()) {
            r.sampler = SamplerKind::parse(s)?;
        }
        // Legacy scalar speculation window: "k" sets the draft length only
        // (kind/adaptivity still inherit the pool default).
        if let Some(k) = j.get("k").and_then(|t| t.as_usize()) {
            if k == 0 {
                bail!("k must be >= 1");
            }
            r.draft.max_len = Some(k);
        }
        // Draft configuration: {"kind": "self|bigram|lookup",
        // "max_len": N, "adaptive": bool}. Fields present override "k"
        // and the pool default; absent fields stay inherited.
        if let Some(dj) = j.get("draft") {
            if !matches!(dj, Json::Obj(_)) {
                bail!("'draft' must be an object");
            }
            if let Some(kind) = dj.get("kind").and_then(|t| t.as_str()) {
                r.draft.kind = Some(DraftKind::parse(kind)?);
            }
            if let Some(ml) = dj.get("max_len").and_then(|t| t.as_usize()) {
                if ml == 0 {
                    bail!("draft.max_len must be >= 1");
                }
                r.draft.max_len = Some(ml);
            }
            if let Some(a) = dj.get("adaptive").and_then(|t| t.as_bool()) {
                r.draft.adaptive = Some(a);
            }
        }
        if let Some(s) = j.get("steps").and_then(|t| t.as_usize()) {
            r.steps = s.max(1);
        }
        if let Some(t) = j.get("temperature").and_then(|t| t.as_f64()) {
            if t <= 0.0 {
                bail!("temperature must be > 0");
            }
            r.temperature = t as f32;
        }
        if let Some(s) = j.get("seed").and_then(|t| t.as_f64()) {
            r.seed = s as u64;
        }
        if let Some(t) = j.get("timeout_ms").and_then(|t| t.as_f64()) {
            // strictly >= 1: a fractional value in (0,1) would truncate
            // to an instantly-expired 0ms deadline
            if t < 1.0 {
                bail!("timeout_ms must be >= 1");
            }
            r.timeout_ms = Some(t as u64);
        }
        Ok(r)
    }
}

/// The response: completed text plus the accounting the paper reports and
/// the per-request speculation telemetry.
#[derive(Clone, Debug)]
pub struct InfillResponse {
    /// Pool-unique id assigned at submission; keys the request's trace
    /// (GET /trace/{request_id}). 0 only in hand-built test fixtures.
    pub request_id: u64,
    pub text: String,
    pub model_nfe: u64,
    pub aux_nfe: u64,
    pub iterations: u64,
    /// speculative tokens examined / kept by verification
    pub proposed: u64,
    pub accepted: u64,
    pub acceptance_rate: f64,
    /// drafter that served the request ("" for non-speculative samplers)
    pub draft_kind: String,
    /// draft window length when the decode finished
    pub draft_len: usize,
    pub latency_s: f64,
    pub n_generated: usize,
}

impl InfillResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("request_id", Json::num(self.request_id as f64)),
            ("text", Json::str(self.text.clone())),
            ("model_nfe", Json::num(self.model_nfe as f64)),
            ("aux_nfe", Json::num(self.aux_nfe as f64)),
            ("iterations", Json::num(self.iterations as f64)),
            ("proposed", Json::num(self.proposed as f64)),
            ("accepted", Json::num(self.accepted as f64)),
            ("acceptance_rate", Json::num(self.acceptance_rate)),
            ("draft", Json::str(self.draft_kind.clone())),
            ("draft_len", Json::num(self.draft_len as f64)),
            ("latency_s", Json::num(self.latency_s)),
            ("n_generated", Json::num(self.n_generated as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let j = Json::parse(r#"{"text": "Tom went to ___."}"#).unwrap();
        let r = InfillRequest::from_json(&j).unwrap();
        assert_eq!(r.text, "Tom went to ___.");
        assert_eq!(r.sampler, SamplerKind::Assd);
        assert_eq!(
            r.draft,
            DraftSpec::default(),
            "unspecified draft inherits the scheduler default"
        );
    }

    #[test]
    fn parse_full() {
        let j = Json::parse(
            r#"{"text":"a?b","mask_char":"?","sampler":"assd_ngram","k":8,
               "steps":16,"temperature":0.8,"seed":42}"#,
        )
        .unwrap();
        let r = InfillRequest::from_json(&j).unwrap();
        assert_eq!(r.mask_char, '?');
        assert_eq!(r.sampler, SamplerKind::AssdNgram);
        assert_eq!(r.draft.max_len, Some(8));
        assert_eq!(r.draft.kind, None, "legacy k leaves the kind inherited");
        assert_eq!(r.steps, 16);
        assert!((r.temperature - 0.8).abs() < 1e-6);
        assert_eq!(r.seed, 42);
    }

    #[test]
    fn parse_draft_object() {
        let j = Json::parse(
            r#"{"text":"a__b","draft":{"kind":"lookup","max_len":12,"adaptive":true}}"#,
        )
        .unwrap();
        let d = InfillRequest::from_json(&j).unwrap().draft;
        assert_eq!(d.kind, Some(DraftKind::Lookup));
        assert_eq!(d.max_len, Some(12));
        assert_eq!(d.adaptive, Some(true));
        // partial object: unspecified fields stay inherited
        let j = Json::parse(r#"{"text":"a__b","draft":{"kind":"BIGRAM"}}"#).unwrap();
        let d = InfillRequest::from_json(&j).unwrap().draft;
        assert_eq!(
            d.kind,
            Some(DraftKind::Bigram),
            "draft kind parse is case-insensitive"
        );
        assert_eq!(d.max_len, None);
        assert_eq!(d.adaptive, None);
    }

    #[test]
    fn draft_object_overrides_legacy_k() {
        let j = Json::parse(r#"{"text":"a__b","k":3,"draft":{"max_len":9}}"#).unwrap();
        assert_eq!(InfillRequest::from_json(&j).unwrap().draft.max_len, Some(9));
        // "k" alone still works
        let j = Json::parse(r#"{"text":"a__b","k":3}"#).unwrap();
        assert_eq!(InfillRequest::from_json(&j).unwrap().draft.max_len, Some(3));
    }

    #[test]
    fn draft_spec_resolves_over_base() {
        let base = DraftOptions {
            kind: DraftKind::Bigram,
            max_len: 7,
            adaptive: true,
        };
        assert_eq!(DraftSpec::default().resolve(base), base);
        let partial = DraftSpec {
            max_len: Some(3),
            ..Default::default()
        };
        assert_eq!(
            partial.resolve(base),
            DraftOptions {
                kind: DraftKind::Bigram,
                max_len: 3,
                adaptive: true,
            }
        );
        assert_eq!(
            DraftSpec::from_options(DraftOptions::default()).resolve(base),
            DraftOptions::default()
        );
    }

    #[test]
    fn parse_timeout_ms() {
        let j = Json::parse(r#"{"text":"a__b","timeout_ms":250}"#).unwrap();
        assert_eq!(InfillRequest::from_json(&j).unwrap().timeout_ms, Some(250));
        let j = Json::parse(r#"{"text":"a__b"}"#).unwrap();
        assert_eq!(InfillRequest::from_json(&j).unwrap().timeout_ms, None);
    }

    #[test]
    fn rejects_bad_requests() {
        for bad in [
            r#"{}"#,
            r#"{"text":"x","sampler":"bogus"}"#,
            r#"{"text":"x","k":0}"#,
            r#"{"text":"x","temperature":0}"#,
            r#"{"text":"x","mask_char":"ab"}"#,
            r#"{"text":"x","draft":"self"}"#,
            r#"{"text":"x","draft":{"kind":"nope"}}"#,
            r#"{"text":"x","draft":{"max_len":0}}"#,
            r#"{"text":"x","timeout_ms":0}"#,
            r#"{"text":"x","timeout_ms":0.5}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(InfillRequest::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn sampler_parse_is_case_insensitive_and_lists_kinds() {
        assert_eq!(SamplerKind::parse("ASSD").unwrap(), SamplerKind::Assd);
        assert_eq!(
            SamplerKind::parse("Assd_Ngram").unwrap(),
            SamplerKind::AssdNgram
        );
        let err = SamplerKind::parse("bogus").unwrap_err().to_string();
        for k in SamplerKind::ALL {
            assert!(err.contains(k.name()), "missing {} in: {err}", k.name());
        }
    }

    #[test]
    fn response_roundtrips_json() {
        let r = InfillResponse {
            request_id: 31,
            text: "done".into(),
            model_nfe: 10,
            aux_nfe: 2,
            iterations: 5,
            proposed: 50,
            accepted: 40,
            acceptance_rate: 0.8,
            draft_kind: "lookup".into(),
            draft_len: 7,
            latency_s: 0.25,
            n_generated: 40,
        };
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("request_id").unwrap().as_f64(), Some(31.0));
        assert_eq!(parsed.get("model_nfe").unwrap().as_f64(), Some(10.0));
        assert_eq!(parsed.get("text").unwrap().as_str(), Some("done"));
        assert_eq!(parsed.get("proposed").unwrap().as_f64(), Some(50.0));
        assert_eq!(parsed.get("accepted").unwrap().as_f64(), Some(40.0));
        assert_eq!(parsed.get("draft").unwrap().as_str(), Some("lookup"));
        assert_eq!(parsed.get("draft_len").unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn sampler_kind_names_roundtrip() {
        for k in SamplerKind::ALL {
            assert_eq!(SamplerKind::parse(k.name()).unwrap(), k);
        }
    }
}
