//! The serving protocol: infill requests/responses and their JSON codec.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Which decoder serves the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// ASSD with self-drafting (Algorithm 1) — the paper's headline.
    Assd,
    /// ASSD with context n-gram drafting (Algorithm 2).
    AssdNgram,
    /// Sequential factorized decoding (baseline).
    Sequential,
    /// Masked-diffusion baseline (conditional-independence unmasking).
    Diffusion,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<SamplerKind> {
        Ok(match s {
            "assd" => SamplerKind::Assd,
            "assd_ngram" => SamplerKind::AssdNgram,
            "sequential" => SamplerKind::Sequential,
            "diffusion" => SamplerKind::Diffusion,
            other => bail!("unknown sampler '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Assd => "assd",
            SamplerKind::AssdNgram => "assd_ngram",
            SamplerKind::Sequential => "sequential",
            SamplerKind::Diffusion => "diffusion",
        }
    }
}

/// An infilling request: text whose `mask_char` runs are to be generated.
#[derive(Clone, Debug)]
pub struct InfillRequest {
    pub text: String,
    pub mask_char: char,
    pub sampler: SamplerKind,
    /// speculation window (Alg. 1's k)
    pub k: usize,
    /// diffusion steps (Diffusion sampler only)
    pub steps: usize,
    pub temperature: f32,
    pub seed: u64,
}

impl Default for InfillRequest {
    fn default() -> Self {
        InfillRequest {
            text: String::new(),
            mask_char: '_',
            sampler: SamplerKind::Assd,
            k: 5,
            steps: 32,
            temperature: 1.0,
            seed: 0,
        }
    }
}

impl InfillRequest {
    pub fn from_json(j: &Json) -> Result<InfillRequest> {
        let mut r = InfillRequest::default();
        match j.get("text").and_then(|t| t.as_str()) {
            Some(t) => r.text = t.to_string(),
            None => bail!("missing 'text'"),
        }
        if let Some(mc) = j.get("mask_char").and_then(|t| t.as_str()) {
            let mut chars = mc.chars();
            r.mask_char = chars.next().unwrap_or('_');
            if chars.next().is_some() {
                bail!("mask_char must be a single character");
            }
        }
        if let Some(s) = j.get("sampler").and_then(|t| t.as_str()) {
            r.sampler = SamplerKind::parse(s)?;
        }
        if let Some(k) = j.get("k").and_then(|t| t.as_usize()) {
            if k == 0 {
                bail!("k must be >= 1");
            }
            r.k = k;
        }
        if let Some(s) = j.get("steps").and_then(|t| t.as_usize()) {
            r.steps = s.max(1);
        }
        if let Some(t) = j.get("temperature").and_then(|t| t.as_f64()) {
            if t <= 0.0 {
                bail!("temperature must be > 0");
            }
            r.temperature = t as f32;
        }
        if let Some(s) = j.get("seed").and_then(|t| t.as_f64()) {
            r.seed = s as u64;
        }
        Ok(r)
    }
}

/// The response: completed text plus the accounting the paper reports.
#[derive(Clone, Debug)]
pub struct InfillResponse {
    pub text: String,
    pub model_nfe: u64,
    pub aux_nfe: u64,
    pub iterations: u64,
    pub acceptance_rate: f64,
    pub latency_s: f64,
    pub n_generated: usize,
}

impl InfillResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("text", Json::str(self.text.clone())),
            ("model_nfe", Json::num(self.model_nfe as f64)),
            ("aux_nfe", Json::num(self.aux_nfe as f64)),
            ("iterations", Json::num(self.iterations as f64)),
            ("acceptance_rate", Json::num(self.acceptance_rate)),
            ("latency_s", Json::num(self.latency_s)),
            ("n_generated", Json::num(self.n_generated as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let j = Json::parse(r#"{"text": "Tom went to ___."}"#).unwrap();
        let r = InfillRequest::from_json(&j).unwrap();
        assert_eq!(r.text, "Tom went to ___.");
        assert_eq!(r.sampler, SamplerKind::Assd);
        assert_eq!(r.k, 5);
    }

    #[test]
    fn parse_full() {
        let j = Json::parse(
            r#"{"text":"a?b","mask_char":"?","sampler":"assd_ngram","k":8,
               "steps":16,"temperature":0.8,"seed":42}"#,
        )
        .unwrap();
        let r = InfillRequest::from_json(&j).unwrap();
        assert_eq!(r.mask_char, '?');
        assert_eq!(r.sampler, SamplerKind::AssdNgram);
        assert_eq!(r.k, 8);
        assert_eq!(r.steps, 16);
        assert!((r.temperature - 0.8).abs() < 1e-6);
        assert_eq!(r.seed, 42);
    }

    #[test]
    fn rejects_bad_requests() {
        for bad in [
            r#"{}"#,
            r#"{"text":"x","sampler":"bogus"}"#,
            r#"{"text":"x","k":0}"#,
            r#"{"text":"x","temperature":0}"#,
            r#"{"text":"x","mask_char":"ab"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(InfillRequest::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn response_roundtrips_json() {
        let r = InfillResponse {
            text: "done".into(),
            model_nfe: 10,
            aux_nfe: 2,
            iterations: 5,
            acceptance_rate: 0.8,
            latency_s: 0.25,
            n_generated: 40,
        };
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("model_nfe").unwrap().as_f64(), Some(10.0));
        assert_eq!(parsed.get("text").unwrap().as_str(), Some("done"));
    }

    #[test]
    fn sampler_kind_names_roundtrip() {
        for k in [
            SamplerKind::Assd,
            SamplerKind::AssdNgram,
            SamplerKind::Sequential,
            SamplerKind::Diffusion,
        ] {
            assert_eq!(SamplerKind::parse(k.name()).unwrap(), k);
        }
    }
}
