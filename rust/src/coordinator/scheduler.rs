//! The scheduler: continuous (iteration-level) batching of decode state
//! machines over a POOL of engine worker threads.
//!
//! The PJRT client is single-threaded, so each engine is OWNED by one
//! dedicated scheduler worker (constructed on that thread via
//! [`EnginePool`]). Requests arrive on one shared BOUNDED MPMC admission
//! queue ([`crate::util::mpmc`]) drained by all workers: whichever worker
//! has a free batch slot first picks up the next job, so a slow or dead
//! replica never stalls admission; when the queue is full, submission is
//! refused outright (load shedding — the HTTP layer renders it as a 429).
//! On paged-KV engines the worker's slot count is additionally capped by
//! the BLOCK BUDGET — only as many lanes as the K/V block pool can back
//! at their worst case are admitted, cached prefixes stay LRU-evictable
//! under pressure, and active lanes are never evicted (docs/
//! ARCHITECTURE.md §Paged KV & prefix cache).
//! Within a worker the loop is vLLM-style continuous batching with
//! LANE-PINNED slots: each request becomes a decode state machine that is
//! pinned to one batch slot — its engine CACHE LANE — for its whole
//! lifetime. Every iteration the worker first retires slots whose
//! lifecycle ended early (cancel token flipped, deadline passed, or the
//! client's event channel closed — see [`super::lifecycle`]), then
//! gathers each active machine's pending COMPACT forward request
//! (ordering + decode state + wanted rows — no materialized masks, see
//! docs/ARCHITECTURE.md §Compact forward ABI), executes ONE batched
//! forward on its own replica — `forward_inc` for machines that vouch for
//! a fixed ordering (their lane carries the persistent K/V cache of their
//! committed prefix; docs/ARCHITECTURE.md §Incremental forward & KV
//! cache), `forward_ord` for the rest (diffusion) — scatters the gathered
//! rows back, STREAMS each machine's freshly accepted tokens over its
//! event channel, and retires finished machines. A lane frees the moment
//! its request completes (or dies) — the worker resets the engine-side
//! lane cache at every handoff, so a newly admitted slot can never
//! observe a retired request's cache — and a queued request joins
//! mid-flight. Because every machine owns its private RNG, the engines
//! evaluate sequences independently, and retiring a slot touches only its
//! own lane (no re-indexing of survivors, unlike the old `swap_remove`
//! composition), retirement never perturbs batch-mates' outputs or caches
//! (enforced by tests below). Draft-phase and verify-phase ASSD sequences
//! still share a batch (both phases use the same executable and differ
//! only in their per-slot `(known, want, committed)` state), and each
//! machine's OWN model-NFE accounting (the Theorem-1 bound) is untouched
//! by routing. Engine-side launch counts are a different matter: a MIXED
//! batch on a native-incremental engine costs two launches per iteration
//! (one `forward_inc`, one `forward_ord`), and XlaEngine books extra
//! launches for per-lane prefill/catch-up — "one iteration = one engine
//! launch" holds only for unmixed batches on a single path, exactly as it
//! already did for the compact path's oversized-want and chunked-batch
//! routing.
//!
//! Aggregate serving metrics ([`Metrics`]) are shared by all workers;
//! per-replica counters ([`ReplicaStats`]) are exported per worker (GET
//! /replicas). Shutdown: dropping every [`SchedulerHandle`] closes the
//! queue and workers drain their remaining slots; conversely, if every
//! worker dies (e.g. all replicas fail to provision), the LAST one out
//! closes the queue and fails any still-queued jobs so clients get an
//! error instead of a hang.
//!
//! FAULT TOLERANCE (docs/ARCHITECTURE.md §Fault tolerance & supervision):
//! the forward surface returns typed [`EngineError`]s, and a failed
//! batched call no longer unwinds the worker. Transient and lane-corrupt
//! failures put each slot the call was carrying through a per-slot retry
//! ladder — lane reset + single-spec COMPACT relaunch of the same
//! idempotent forward request, bit-identical because the failed call
//! never reached the machine — spending a per-request retry budget whose
//! exhaustion retires just that request with a typed error while
//! batch-mates proceed; per-slot decode panics are contained the same
//! way. A worker-local [`HealthTracker`] escalates consecutive failed
//! batched calls Healthy → Degraded → Quarantined; a fatal error or a
//! quarantine ends the engine INCARNATION — active slots get typed
//! errors, queued requests stay queued — and the supervisor loop in
//! [`spawn_pool`] re-provisions the replica through the pool factory (up
//! to [`SupervisorPolicy::max_restarts`]) before declaring it Failed.
//! Once every replica is lost, submission reports
//! [`SubmitError::ReplicaLost`] and reclaims queued jobs with typed
//! errors instead of stranding them. Deterministic fault injection for
//! all of the above lives in [`crate::runtime::ChaosEngine`]
//! (`--chaos-seed`/`--chaos-rate`).
//!
//! CHECKPOINTING (docs/ARCHITECTURE.md §Checkpointing, preemption &
//! migration): every decode machine can freeze into a
//! [`crate::decode::snapshot::DecodeSnapshot`] whose restore replays the
//! uninterrupted run bit-for-bit. The pool keeps a shared RESUME deque of
//! checkpointed slots ([`PoolShared`]) that every worker drains ahead of
//! the admission queue, and restructures "this request must die" into
//! "checkpoint unless truly failed" at three seams: (1) PREEMPTION — a
//! `forward_inc` that fails with [`EngineError::KvPressure`] parks the
//! least-progressed checkpointable slot (seal + release its lane) instead
//! of spinning the retry ladder against a full block pool; the survivor
//! batch allocates, and the victim resumes later with a warm-prefix
//! restore. (2) MIGRATION — when an engine incarnation dies, active slots
//! that can checkpoint are re-queued instead of failed: replica death
//! costs latency, not requests, and open SSE streams continue without
//! re-emitting a token. (3) DRAIN — [`SchedulerHandle::set_draining`]
//! (POST /drain) refuses new admissions with [`SubmitError::Draining`]
//! and parks every checkpointable active slot; lifting the flag resumes
//! them in place — a restart window with zero failed requests.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::data::masking::lattice_sigma;
use crate::decode::assd::AssdMachine;
use crate::decode::diffusion::DiffusionMachine;
use crate::decode::sequential::SequentialMachine;
use crate::decode::snapshot::DecodeSnapshot;
use crate::decode::{DecodeMachine, DecodeOutcome, IterPhase, IterStats};
use crate::draft::DraftOptions;
use crate::model::mask::Ordering;
use crate::obs::flight::{self, FlightBuilder, FlightRecorder};
use crate::obs::timeseries::{self, Bucket, CounterFold, TsRing};
use crate::obs::{chrome, tap, Rung, SpanKind, SpanRecorder, TraceBuilder, DEFAULT_SPAN_CAP};
use crate::runtime::{
    ChaosConfig, ChaosEngine, Engine, EngineError, EnginePool, ErrorClass, ForwardSpec, Health,
    HealthPolicy, HealthTracker, IncSpec, KvStats, PoolConfig, SupervisorPolicy,
};
use crate::tokenizer::{ByteTokenizer, MASK};
use crate::util::json::Json;
use crate::util::mpmc;
use crate::util::rng::Rng;

use super::lifecycle::{self, Abort, LifecycleEmitter, RequestHandle};
use super::metrics::{Metrics, ReplicaState, ReplicaStats};
use super::request::{InfillRequest, InfillResponse, SamplerKind};

/// Per-worker batching knobs (each pool worker runs its own copy).
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max sequences decoded concurrently PER WORKER (batch slots). The
    /// pool's total in-flight capacity is `replicas * max_batch`.
    pub max_batch: usize,
    /// How long an idle worker blocks on the admission queue before
    /// re-polling (bounds shutdown latency, not throughput).
    pub idle_poll: Duration,
    /// Draft configuration applied to ASSD requests that do not carry
    /// their own `draft` field (`asarm serve --draft/--draft-max-len/
    /// --adaptive`).
    pub default_draft: DraftOptions,
    /// Admission-queue capacity, POOL-WIDE: beyond this many queued (not
    /// yet admitted) requests, [`SchedulerHandle::submit`] sheds with
    /// [`SubmitError::QueueFull`] instead of letting the backlog grow
    /// without bound (`--queue-depth`).
    pub queue_depth: usize,
    /// Per-request event-channel capacity. Sized so a full decode's
    /// commit events fit comfortably; a client that still falls this far
    /// behind is cancelled rather than allowed to stall its batch
    /// (`--event-buffer`; docs/ARCHITECTURE.md §Request lifecycle &
    /// streaming).
    pub event_capacity: usize,
    /// Record a per-request trace (spans + NFE accounting) for every
    /// served request (`--trace`; docs/ARCHITECTURE.md §Observability &
    /// tracing). Off, requests carry no [`TraceBuilder`] and the only
    /// residual cost is the engines' thread-local rung/probe notes.
    pub trace: bool,
    /// Completed traces retained PER REPLICA in its drop-oldest
    /// [`SpanRecorder`] ring (`--trace-capacity`).
    pub trace_capacity: usize,
    /// Fraction of requests whose speculation flight is recorded
    /// (`--flight-sample-rate`; docs/ARCHITECTURE.md §Speculation
    /// analytics & time-series). The decision is a deterministic hash of
    /// the request id — never the decode RNG — so sampled and unsampled
    /// runs stay bit-identical. 0 disables the recorder entirely.
    pub flight_sample_rate: f64,
    /// Retired flight records retained PER REPLICA in its drop-oldest
    /// [`FlightRecorder`] ring (`--flight-capacity`). Heatmap aggregates
    /// fold at record time and survive ring eviction.
    pub flight_capacity: usize,
    /// Deterministic fault injection wrapped around every replica's
    /// engine at provision time (`--chaos-seed`/`--chaos-rate`; docs/
    /// ARCHITECTURE.md §Fault tolerance & supervision). The default zero
    /// rate skips the wrapper entirely — no proxy on the hot path.
    pub chaos: ChaosConfig,
    /// Single-spec retry launches a request may spend over its lifetime
    /// recovering from failed batched forwards; exhaustion retires the
    /// request with a typed error while batch-mates proceed.
    pub retry_budget: u32,
    /// Consecutive-failure thresholds for the per-incarnation replica
    /// health state machine (Healthy → Degraded → Quarantined).
    pub health: HealthPolicy,
    /// Re-provisioning budget and backoff for dead engine incarnations
    /// (fatal errors, quarantines, worker panics, failed provisions).
    pub supervisor: SupervisorPolicy,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 4,
            idle_poll: Duration::from_millis(50),
            default_draft: DraftOptions::default(),
            queue_depth: 1024,
            event_capacity: 256,
            trace: true,
            trace_capacity: 256,
            flight_sample_rate: 0.05,
            flight_capacity: 64,
            chaos: ChaosConfig::default(),
            retry_budget: 8,
            health: HealthPolicy::default(),
            supervisor: SupervisorPolicy::default(),
        }
    }
}

/// Pool-unique request ids, assigned at submission. Process-global so ids
/// stay unique across schedulers within one process (tests spawn many);
/// starts at 1 — 0 is reserved for hand-built fixtures.
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

struct Job {
    request: InfillRequest,
    life: LifecycleEmitter,
    request_id: u64,
}

/// A checkpointed in-flight request awaiting re-admission: everything a
/// [`Slot`] carried that is not reconstructible from the snapshot. The
/// lifecycle emitter rides along UNFINISHED — the client's stream stays
/// open across the park, its deadline clock keeps running (submission
/// epoch), and no committed token is ever re-emitted (the restored
/// machine's commit buffer resumes exactly where it froze).
struct ResumeJob {
    life: LifecycleEmitter,
    snapshot: DecodeSnapshot,
    /// Tokens already streamed to the client (progress messages + the
    /// TTFT-vs-ITL branch on the next commit).
    committed: usize,
    text_len: usize,
    n_targets: usize,
    trace: Option<TraceBuilder>,
    flight: Option<FlightBuilder>,
    /// Remaining retry budget — parking is not a free refill.
    retries: u32,
}

/// Pool-wide shared state beyond the admission queue: the resume deque
/// of checkpointed slots and the drain flag. Plain `Arc` held by the
/// handle AND every worker (not extra queue senders, which would keep
/// the admission queue open after the last handle drops).
struct PoolShared {
    resume: Mutex<VecDeque<ResumeJob>>,
    draining: AtomicBool,
}

/// Submission failure: distinguishes backpressure (the caller should
/// retry later — HTTP 429) from shutdown.
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    /// The bounded admission queue is at capacity (load shedding).
    #[error("admission queue full ({0} requests queued); retry later")]
    QueueFull(usize),
    /// The pool is gone; no request will ever be served again.
    #[error("scheduler shut down")]
    ShutDown,
    /// Every replica DIED (provisioning and restart budgets exhausted)
    /// rather than draining after an orderly shutdown; queued requests
    /// were reclaimed and failed with typed errors instead of being
    /// silently stranded. A server fault, unlike
    /// [`SubmitError::ShutDown`] — but equally terminal for this pool.
    #[error("all replicas lost; request cannot be served")]
    ReplicaLost,
    /// The pool is draining (POST /drain): active requests are being
    /// checkpointed and parked; new admissions are refused until the
    /// drain is lifted (HTTP 503 + Retry-After, unlike the 429 of
    /// [`SubmitError::QueueFull`] — the client should come back, not
    /// back off).
    #[error("pool draining; new admissions refused until drain is lifted")]
    Draining,
}

/// Cloneable handle for submitting requests to the worker pool.
#[derive(Clone)]
pub struct SchedulerHandle {
    tx: mpmc::Sender<Job>,
    shared: Arc<PoolShared>,
    replicas: Arc<Vec<ReplicaStats>>,
    recorders: Arc<Vec<SpanRecorder>>,
    flights: Arc<Vec<FlightRecorder>>,
    /// Per-replica per-second activity rings plus one pool-level ring
    /// for queue depth (the admission queue is shared, so folding it
    /// per replica would overcount under the sum-merge).
    rings: Arc<Vec<TsRing>>,
    pool_ring: Arc<TsRing>,
    /// Shared epoch for the time-series clock (bucket seconds are
    /// `origin.elapsed().as_secs()` on every worker).
    origin: Instant,
    metrics: Metrics,
    queue_depth: usize,
    event_capacity: usize,
    trace_capacity: usize,
    flight_sample_rate: f64,
    retry_budget: u32,
}

impl SchedulerHandle {
    /// Blocking round-trip: submit and await the terminal event.
    pub fn infill(&self, request: InfillRequest) -> Result<InfillResponse> {
        self.submit(request).map_err(anyhow::Error::new)?.wait()
    }

    /// Async submit: returns the request's lifecycle handle immediately
    /// (event stream + cancellation; load generators and the SSE
    /// surface). Sheds with [`SubmitError::QueueFull`] when the bounded
    /// admission queue is at capacity.
    pub fn submit(&self, request: InfillRequest) -> Result<RequestHandle, SubmitError> {
        if self.shared.draining.load(AtomicOrdering::Relaxed) {
            return Err(SubmitError::Draining);
        }
        let timeout = request.timeout_ms.map(Duration::from_millis);
        let request_id = NEXT_REQUEST_ID.fetch_add(1, AtomicOrdering::Relaxed);
        let (life, handle) = lifecycle::channel(timeout, self.event_capacity, request_id);
        match self.tx.try_send(Job {
            request,
            life,
            request_id,
        }) {
            Ok(()) => Ok(handle),
            Err(mpmc::TrySendError::Full(_)) => {
                self.metrics.record_shed();
                Err(SubmitError::QueueFull(self.queue_depth))
            }
            Err(mpmc::TrySendError::Closed(_)) => {
                if self.tx.is_lost() {
                    // The last receiver was DROPPED (every worker died)
                    // rather than explicitly closed and drained: reclaim
                    // whatever the dead pool left queued and fail each
                    // job typed, so no client blocks on a reply that can
                    // never come.
                    for job in self.tx.reclaim() {
                        self.metrics.record_request_failed();
                        job.life
                            .finish(Err(anyhow::Error::new(SubmitError::ReplicaLost)));
                    }
                    Err(SubmitError::ReplicaLost)
                } else {
                    Err(SubmitError::ShutDown)
                }
            }
        }
    }

    /// Per-replica serving counters, indexed by replica id.
    pub fn replica_stats(&self) -> &[ReplicaStats] {
        &self.replicas
    }

    /// JSON array of per-replica snapshots (the GET /replicas payload).
    /// Each object carries the pool's effective `retry_budget`
    /// (`--retry-budget`) so operators can read the recovery policy off
    /// the same surface as the counters it explains.
    pub fn replicas_json(&self) -> Json {
        Json::Arr(
            self.replicas
                .iter()
                .map(|r| {
                    let mut j = r.snapshot_json();
                    if let Json::Obj(m) = &mut j {
                        m.insert(
                            "retry_budget".to_string(),
                            Json::num(self.retry_budget as f64),
                        );
                    }
                    j
                })
                .collect(),
        )
    }

    /// Flip the pool-wide drain flag (POST /drain). On: new submissions
    /// are refused with [`SubmitError::Draining`] and every worker parks
    /// its checkpointable active slots on the resume deque (sealing their
    /// committed rows into the prefix cache). Off: parked checkpoints
    /// re-admit with warm-prefix restores. Client streams stay open and
    /// deadlines keep running throughout.
    pub fn set_draining(&self, on: bool) {
        self.shared.draining.store(on, AtomicOrdering::Relaxed);
    }

    /// True while the pool refuses admissions (see
    /// [`SchedulerHandle::set_draining`]).
    pub fn draining(&self) -> bool {
        self.shared.draining.load(AtomicOrdering::Relaxed)
    }

    /// Checkpointed requests currently parked on the resume deque
    /// (waiting for a free lane, or for the drain flag to lift).
    pub fn parked(&self) -> usize {
        self.shared.resume.lock().unwrap().len()
    }

    /// The GET /drain payload: the flag, the park depth, and the live
    /// checkpoint/preemption/migration counters that explain them.
    pub fn drain_json(&self) -> Json {
        Json::obj(vec![
            ("draining", Json::Bool(self.draining())),
            ("parked", Json::num(self.parked() as f64)),
            ("preemptions", Json::num(self.metrics.preemptions() as f64)),
            ("migrations", Json::num(self.metrics.migrations() as f64)),
            ("drains", Json::num(self.metrics.drains() as f64)),
        ])
    }

    /// Look up a retired request's trace across every replica's ring.
    pub fn trace(&self, request_id: u64) -> Option<Arc<crate::obs::RequestTrace>> {
        self.recorders.iter().find_map(|r| r.get(request_id))
    }

    /// Chrome trace-event JSON for one request (the GET /trace/{id}
    /// payload; load it in chrome://tracing or Perfetto).
    pub fn trace_chrome_json(&self, request_id: u64) -> Option<Json> {
        self.trace(request_id).map(|t| chrome::trace_json(&t))
    }

    /// Newest-first index of retained traces, merged across replicas (the
    /// GET /trace/recent payload): one summary object per trace.
    pub fn trace_recent_json(&self, limit: usize) -> Json {
        let mut all: Vec<Arc<crate::obs::RequestTrace>> = self
            .recorders
            .iter()
            .flat_map(|r| r.recent(limit))
            .collect();
        // request ids are assigned monotonically at submission, so they
        // order the merged view by recency
        all.sort_by(|a, b| b.request_id.cmp(&a.request_id));
        all.truncate(limit);
        Json::Arr(all.iter().map(|t| t.summary_json()).collect())
    }

    /// Ring capacity of the per-replica trace recorders — the clamp for
    /// `/trace/recent?limit=` (a larger limit cannot return more than
    /// the rings retain).
    pub fn trace_capacity(&self) -> usize {
        self.trace_capacity
    }

    /// Look up a retired request's flight record across every replica's
    /// ring (the GET /debug/flight/{id} payload).
    pub fn flight_json(&self, request_id: u64) -> Option<Json> {
        self.flights
            .iter()
            .find_map(|f| f.get(request_id))
            .map(|r| r.to_json())
    }

    /// Pool-merged positional-acceptance heatmap + entropy curves.
    fn merged_heat(&self) -> Vec<flight::DrafterHeat> {
        flight::merge_heat(self.flights.iter().map(|f| f.heat()).collect())
    }

    /// The GET /debug/vars payload: windowed pool time-series (replica
    /// rings merged field-wise, plus the shared-queue ring), the flight
    /// heatmap/curve aggregates, and recorder accounting.
    pub fn debug_vars_json(&self, window: usize) -> Json {
        let mut snaps: Vec<Vec<Bucket>> =
            self.rings.iter().map(|r| r.snapshot(window)).collect();
        snaps.push(self.pool_ring.snapshot(window));
        let series = timeseries::merge(&snaps);
        let recorded: u64 = self.flights.iter().map(|f| f.recorded()).sum();
        let dropped: u64 = self.flights.iter().map(|f| f.dropped()).sum();
        Json::obj(vec![
            ("uptime_sec", Json::num(self.origin.elapsed().as_secs() as f64)),
            ("window", Json::num(window as f64)),
            ("queue_depth", Json::num(self.tx.len() as f64)),
            ("series", timeseries::series_json(&series)),
            ("heatmap", flight::heat_json(&self.merged_heat())),
            (
                "flight",
                Json::obj(vec![
                    ("sample_rate", Json::num(self.flight_sample_rate)),
                    ("recorded", Json::num(recorded as f64)),
                    ("dropped", Json::num(dropped as f64)),
                ]),
            ),
        ])
    }

    /// Prometheus text exposition of the pool aggregate plus per-replica
    /// counters (the GET /metrics payload under `Accept: text/plain`),
    /// with the flight-recorder heatmap/curve families appended.
    pub fn prometheus_text(&self) -> String {
        let mut out = self.metrics.prometheus(&self.replicas);
        out.push_str(&self.prometheus_flight_text());
        out
    }

    /// Flight-recorder families: positional acceptance heatmap and
    /// entropy-bucketed acceptance curves as per-drafter labeled
    /// counters, plus a per-drafter target-entropy histogram.
    fn prometheus_flight_text(&self) -> String {
        use crate::obs::prometheus::PromText;
        let heat = self.merged_heat();
        let recorded: u64 = self.flights.iter().map(|f| f.recorded()).sum();
        let dropped: u64 = self.flights.iter().map(|f| f.dropped()).sum();
        let mut w = PromText::new();
        w.counter(
            "asarm_flight_records_total",
            "Flight records captured (sampled requests retired).",
            recorded as f64,
        );
        w.counter(
            "asarm_flight_records_dropped_total",
            "Flight records evicted from the per-replica rings.",
            dropped as f64,
        );
        w.header(
            "asarm_flight_windows_total",
            "Speculation windows recorded, by drafter.",
            "counter",
        );
        for h in &heat {
            w.sample("asarm_flight_windows_total", &[("drafter", &h.drafter)], h.windows as f64);
        }
        w.header(
            "asarm_flight_position_proposed_total",
            "Window positions verified, by drafter and window position.",
            "counter",
        );
        for h in &heat {
            for (i, &(p, _)) in h.pos.iter().enumerate().filter(|(_, c)| c.0 > 0) {
                let pos = i.to_string();
                w.sample(
                    "asarm_flight_position_proposed_total",
                    &[("drafter", &h.drafter), ("pos", &pos)],
                    p as f64,
                );
            }
        }
        w.header(
            "asarm_flight_position_accepted_total",
            "Window positions accepted, by drafter and window position.",
            "counter",
        );
        for h in &heat {
            for (i, &(p, a)) in h.pos.iter().enumerate().filter(|(_, c)| c.0 > 0) {
                let _ = p;
                let pos = i.to_string();
                w.sample(
                    "asarm_flight_position_accepted_total",
                    &[("drafter", &h.drafter), ("pos", &pos)],
                    a as f64,
                );
            }
        }
        w.header(
            "asarm_flight_entropy_proposed_total",
            "Window positions verified, by drafter and target-entropy bucket (le = nats).",
            "counter",
        );
        for h in &heat {
            for (i, &(p, _)) in h.entropy.iter().enumerate() {
                let le = flight::ENTROPY_BOUNDS
                    .get(i)
                    .map(|b| b.to_string())
                    .unwrap_or_else(|| "+Inf".to_string());
                w.sample(
                    "asarm_flight_entropy_proposed_total",
                    &[("drafter", &h.drafter), ("le", &le)],
                    p as f64,
                );
            }
        }
        w.header(
            "asarm_flight_entropy_accepted_total",
            "Window positions accepted, by drafter and target-entropy bucket (le = nats).",
            "counter",
        );
        for h in &heat {
            for (i, &(_, a)) in h.entropy.iter().enumerate() {
                let le = flight::ENTROPY_BOUNDS
                    .get(i)
                    .map(|b| b.to_string())
                    .unwrap_or_else(|| "+Inf".to_string());
                w.sample(
                    "asarm_flight_entropy_accepted_total",
                    &[("drafter", &h.drafter), ("le", &le)],
                    a as f64,
                );
            }
        }
        if !heat.is_empty() {
            w.header(
                "asarm_flight_target_entropy_nats",
                "Target-distribution entropy of verified rows, by drafter.",
                "histogram",
            );
            for h in &heat {
                w.histogram_series(
                    "asarm_flight_target_entropy_nats",
                    &[("drafter", &h.drafter)],
                    &h.target_entropy,
                );
            }
        }
        w.finish()
    }

    /// Pool liveness — the GET /healthz criterion: true while at least
    /// one replica is serving or will serve again (Starting, Running,
    /// Degraded, or Quarantined-pending-restart); false once every
    /// replica is Stopped or Failed for good.
    pub fn healthy(&self) -> bool {
        self.replicas.iter().any(|r| r.state().is_serving())
    }

    /// The GET /healthz payload: overall status plus per-replica states
    /// (the detail behind the 200/503 status code).
    pub fn healthz_json(&self) -> Json {
        let serving = self
            .replicas
            .iter()
            .filter(|r| r.state().is_serving())
            .count();
        Json::obj(vec![
            (
                "status",
                Json::str(if serving > 0 { "ok" } else { "unavailable" }),
            ),
            ("replicas_serving", Json::num(serving as f64)),
            ("replicas_total", Json::num(self.replicas.len() as f64)),
            (
                "replicas",
                Json::Arr(
                    self.replicas
                        .iter()
                        .map(|r| Json::str(r.state().as_str()))
                        .collect(),
                ),
            ),
        ])
    }
}

struct Slot {
    machine: Box<dyn DecodeMachine>,
    life: LifecycleEmitter,
    t0: Instant,
    /// When the previous commit chunk was streamed (ITL bookkeeping).
    last_commit: Instant,
    /// Tokens committed so far (partial-progress error messages).
    committed: usize,
    text_len: usize,
    n_targets: usize,
    /// Per-request span/counter accumulator; `None` with tracing off.
    trace: Option<TraceBuilder>,
    /// Speculation flight accumulator; `Some` only for requests chosen
    /// by the deterministic id-hash sampler. Its presence is what arms
    /// the machine-side flight tap around this slot's absorbs.
    flight: Option<FlightBuilder>,
    /// Remaining single-spec retry launches for fault recovery
    /// ([`SchedulerConfig::retry_budget`]); decremented per attempt,
    /// never replenished.
    retries: u32,
}

/// Spawn a single-replica scheduler. `factory` constructs the engine ON
/// the worker thread (the XLA engine is not Send). Kept as the simple API
/// for tests and one-shot CLI use; [`spawn_pool`] is the general form.
pub fn spawn<F>(factory: F, cfg: SchedulerConfig, metrics: Metrics) -> SchedulerHandle
where
    F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
{
    let cell = Mutex::new(Some(factory));
    spawn_pool(
        EnginePool::from_fn(PoolConfig { replicas: 1 }, move |_| {
            // A second provision means the sole incarnation died; a
            // FnOnce factory cannot rebuild it, so report an ordinary
            // provisioning failure and let the supervisor retire the
            // replica (panicking here would kill the worker thread
            // mid-supervision and strand the queue).
            match cell.lock().unwrap().take() {
                Some(f) => f(),
                None => bail!("single-replica factory already consumed"),
            }
        }),
        cfg,
        metrics,
    )
}

/// Spawn one scheduler worker per pool replica, all draining one shared
/// admission queue. Each worker provisions its engine on its own thread
/// and runs the continuous-batching loop against that replica alone.
pub fn spawn_pool(pool: EnginePool, cfg: SchedulerConfig, metrics: Metrics) -> SchedulerHandle {
    let n_workers = pool.replicas();
    let (tx, rx) = mpmc::bounded::<Job>(cfg.queue_depth);
    let replicas: Arc<Vec<ReplicaStats>> =
        Arc::new((0..n_workers).map(ReplicaStats::new).collect());
    let recorders: Arc<Vec<SpanRecorder>> = Arc::new(
        (0..n_workers)
            .map(|_| SpanRecorder::new(cfg.trace_capacity))
            .collect(),
    );
    let flights: Arc<Vec<FlightRecorder>> = Arc::new(
        (0..n_workers)
            .map(|_| FlightRecorder::new(cfg.flight_capacity))
            .collect(),
    );
    let rings: Arc<Vec<TsRing>> = Arc::new(
        (0..n_workers)
            .map(|_| TsRing::new(TS_RING_CAPACITY))
            .collect(),
    );
    let pool_ring = Arc::new(TsRing::new(TS_RING_CAPACITY));
    let origin = Instant::now();
    let live = Arc::new(AtomicUsize::new(n_workers));
    let pool = Arc::new(pool);
    let shared = Arc::new(PoolShared {
        resume: Mutex::new(VecDeque::new()),
        draining: AtomicBool::new(false),
    });
    for id in 0..n_workers {
        let rx = rx.clone();
        let metrics = metrics.clone();
        let replicas = Arc::clone(&replicas);
        let recorders = Arc::clone(&recorders);
        let flights = Arc::clone(&flights);
        let rings = Arc::clone(&rings);
        let pool_ring = Arc::clone(&pool_ring);
        let live = Arc::clone(&live);
        let pool = Arc::clone(&pool);
        let shared = Arc::clone(&shared);
        thread::Builder::new()
            .name(format!("scheduler-{id}"))
            .spawn(move || {
                // The guard must cover panics too (a panicking worker that
                // skipped the last-one-out bookkeeping would leave queued
                // clients blocked forever), hence Drop rather than a
                // trailing call.
                let _exit = WorkerExitGuard {
                    live,
                    rx: rx.clone(),
                    shared: Arc::clone(&shared),
                };
                let stats = &replicas[id];
                let recorder = &recorders[id];
                let obs = WorkerObs {
                    flight: &flights[id],
                    ring: &rings[id],
                    pool_ring: &pool_ring,
                    origin,
                };
                // SUPERVISION: each pass provisions one engine
                // INCARNATION and serves on it until the queue closes
                // (orderly exit) or the incarnation dies — a fatal engine
                // error, a health quarantine, a worker panic, or a failed
                // provision. Dead incarnations are re-provisioned through
                // the pool factory up to the restart budget; the
                // admission queue survives every death, so queued
                // requests simply wait for the next incarnation (or get
                // picked up by a pool-mate).
                let mut restarts_left = cfg.supervisor.max_restarts;
                loop {
                    let died = match pool.provision(id) {
                        Ok(engine) => {
                            let engine = ChaosEngine::wrap(engine, cfg.chaos);
                            stats.set_state(ReplicaState::Running);
                            match catch_unwind(AssertUnwindSafe(|| {
                                run_worker(
                                    engine.as_ref(),
                                    &rx,
                                    &shared,
                                    cfg,
                                    &metrics,
                                    stats,
                                    recorder,
                                    &obs,
                                )
                            })) {
                                Ok(WorkerExit::Drained) => {
                                    stats.set_state(ReplicaState::Stopped);
                                    return;
                                }
                                Ok(WorkerExit::EngineDead) => "engine incarnation died",
                                Err(_) => "worker panicked",
                            }
                        }
                        Err(e) => {
                            eprintln!("scheduler-{id}: engine init failed: {e:#}");
                            "engine provisioning failed"
                        }
                    };
                    if restarts_left == 0 {
                        eprintln!(
                            "scheduler-{id}: {died}; restart budget exhausted, replica failed"
                        );
                        stats.set_state(ReplicaState::Failed);
                        return;
                    }
                    restarts_left -= 1;
                    eprintln!(
                        "scheduler-{id}: {died}; re-provisioning ({restarts_left} restarts left)"
                    );
                    metrics.record_replica_restart();
                    stats.record_restart();
                    stats.set_state(ReplicaState::Starting);
                    thread::sleep(cfg.supervisor.restart_backoff);
                }
            })
            .expect("spawn scheduler worker");
    }
    SchedulerHandle {
        tx,
        shared,
        replicas,
        recorders,
        flights,
        rings,
        pool_ring,
        origin,
        metrics,
        queue_depth: cfg.queue_depth,
        event_capacity: cfg.event_capacity,
        trace_capacity: cfg.trace_capacity,
        flight_sample_rate: cfg.flight_sample_rate,
        retry_budget: cfg.retry_budget,
    }
}

/// Per-second buckets retained per ring: ten minutes of history — enough
/// for the dashboard's widest window while keeping a ring at a few tens
/// of KiB.
const TS_RING_CAPACITY: usize = 600;

/// The per-worker observability surfaces threaded into [`run_worker`]
/// alongside the replica's trace recorder (grouped so incarnation
/// restarts keep reusing the same rings and flight ring).
struct WorkerObs<'a> {
    flight: &'a FlightRecorder,
    ring: &'a TsRing,
    pool_ring: &'a TsRing,
    origin: Instant,
}

/// Cumulative-to-delta folds for the per-second bucket ring, plus the
/// engine-error deltas that have no cumulative per-class source on
/// [`ReplicaStats`] (its error counter is classless) and are therefore
/// counted directly at the `e.class()` match sites.
#[derive(Default)]
struct TsFolds {
    tokens: CounterFold,
    model_nfe: CounterFold,
    aux_nfe: CounterFold,
    proposed: CounterFold,
    accepted: CounterFold,
    requests: CounterFold,
    err_transient: u64,
    err_lane_corrupt: u64,
    err_fatal: u64,
}

impl TsFolds {
    fn note_engine_error(&mut self, class: ErrorClass) {
        match class {
            ErrorClass::Transient => self.err_transient += 1,
            ErrorClass::LaneCorrupt => self.err_lane_corrupt += 1,
            ErrorClass::Fatal => self.err_fatal += 1,
        }
    }

    /// Fold this replica's cumulative counters into the current
    /// one-second bucket and overwrite its gauges. Queue depth is a
    /// POOL-level gauge (the admission queue is shared), so it lands in
    /// the pool ring only — writing it per-replica would overcount it
    /// N-fold under the field-wise sum that merges replica rings.
    fn tick(
        &mut self,
        obs: &WorkerObs<'_>,
        stats: &ReplicaStats,
        engine: &dyn Engine,
        queue_depth: usize,
        occupancy: usize,
    ) {
        let at = obs.origin.elapsed().as_secs();
        let tokens = self.tokens.fold(stats.tokens_generated());
        let model_nfe = self.model_nfe.fold(stats.model_nfe());
        let aux_nfe = self.aux_nfe.fold(stats.aux_nfe());
        let proposed = self.proposed.fold(stats.proposed());
        let accepted = self.accepted.fold(stats.accepted());
        let requests = self.requests.fold(stats.requests());
        let (et, el, ef) = (self.err_transient, self.err_lane_corrupt, self.err_fatal);
        self.err_transient = 0;
        self.err_lane_corrupt = 0;
        self.err_fatal = 0;
        let kv = engine.kv_stats();
        let serving = stats.state().is_serving() as u64;
        obs.ring.record_at(at, |b| {
            b.tokens += tokens;
            b.model_nfe += model_nfe;
            b.aux_nfe += aux_nfe;
            b.proposed += proposed;
            b.accepted += accepted;
            b.requests += requests;
            b.errors_transient += et;
            b.errors_lane_corrupt += el;
            b.errors_fatal += ef;
            b.batch_occupancy = occupancy as u64;
            if let Some(kv) = &kv {
                b.kv_blocks_free = kv.free_blocks as u64;
                b.kv_blocks_total = kv.total_blocks as u64;
            }
            b.serving = serving;
        });
        obs.pool_ring.record_at(at, |b| {
            b.queue_depth = queue_depth as u64;
        });
    }
}

/// Last-worker-out bookkeeping, panic-safe via Drop: when the final worker
/// exits (cleanly or by unwinding), close the admission queue and fail
/// whatever is still queued — otherwise those clients would block forever
/// on replies that can never come.
struct WorkerExitGuard {
    live: Arc<AtomicUsize>,
    rx: mpmc::Receiver<Job>,
    shared: Arc<PoolShared>,
}

impl Drop for WorkerExitGuard {
    fn drop(&mut self) {
        if self.live.fetch_sub(1, AtomicOrdering::AcqRel) == 1 {
            self.rx.close();
            while let Ok(job) = self.rx.try_recv() {
                job.life.finish(Err(anyhow!("engine pool shut down")));
            }
            // Parked checkpoints can never resume once every worker is
            // gone: fail them typed (with progress context) rather than
            // stranding their clients on open streams.
            let mut parked = self.shared.resume.lock().unwrap();
            while let Some(rj) = parked.pop_front() {
                rj.life.finish(Err(anyhow!(
                    "engine pool shut down after {}/{} tokens",
                    rj.committed,
                    rj.n_targets
                )));
            }
        }
    }
}

/// Book the right counters for a lifecycle that ended early — shared by
/// the in-slot retire check and the admission-time queued-job check, so
/// a new [`Abort`] variant cannot silently diverge between the paths.
fn record_abort(reason: Abort, metrics: &Metrics, stats: &ReplicaStats) -> &'static str {
    match reason {
        Abort::DeadlineExpired => metrics.record_deadline_expired(),
        Abort::Cancelled | Abort::Abandoned => metrics.record_cancelled(),
    }
    stats.record_cancelled();
    match reason {
        Abort::Cancelled => "cancelled",
        Abort::DeadlineExpired => "deadline exceeded",
        Abort::Abandoned => "abandoned by client",
    }
}

/// Close and publish a slot's trace (if tracing is on). `completed` is
/// false on every abort path: an aborted request may legitimately sit one
/// draft NFE ahead of its commits mid-iteration, so the Theorem-2 flag is
/// only asserted on completed requests.
fn finish_trace(
    trace: Option<TraceBuilder>,
    completed: bool,
    s: IterStats,
    draft_kind: String,
    metrics: &Metrics,
    stats: &ReplicaStats,
    recorder: &SpanRecorder,
) {
    if let Some(b) = trace {
        let t = b.finish(
            completed,
            s.model_nfe,
            s.aux_nfe,
            s.iterations,
            s.proposed,
            s.accepted,
            draft_kind,
        );
        metrics.record_trace(&t);
        stats.record_trace(&t);
        recorder.record(t);
    }
}

/// Close and publish a slot's flight record (if this request was
/// sampled). The trace-path twin of [`finish_trace`].
fn finish_flight(
    flight: Option<FlightBuilder>,
    completed: bool,
    draft_kind: String,
    flight_rec: &FlightRecorder,
) {
    if let Some(b) = flight {
        flight_rec.record(b.finish(completed, draft_kind));
    }
}

/// Retire a slot whose lifecycle ended before the decode finished: book
/// the right counter and send the terminal error (with partial progress).
fn abort_slot(
    mut slot: Slot,
    reason: Abort,
    metrics: &Metrics,
    stats: &ReplicaStats,
    recorder: &SpanRecorder,
    flight_rec: &FlightRecorder,
) {
    let what = record_abort(reason, metrics, stats);
    let s = slot.machine.iter_stats();
    finish_trace(
        slot.trace.take(),
        false,
        s,
        String::new(),
        metrics,
        stats,
        recorder,
    );
    finish_flight(slot.flight.take(), false, String::new(), flight_rec);
    slot.life.finish(Err(anyhow!(
        "{what} after {}/{} tokens",
        slot.committed,
        slot.n_targets
    )));
}

/// Checkpoint a live slot into a [`ResumeJob`] on the shared resume
/// deque: freeze the machine, seal the lane's committed rows into the
/// prefix cache and release its blocks (`reset_lane` = seal-then-release
/// on paged engines), and carry the lifecycle/trace/flight/retry state
/// across the park. Returns false — leaving the slot untouched — when
/// the machine is not checkpointable; the caller falls back to whatever
/// it would have done without this layer. Must be called between
/// absorbs (every call site is), so the restored machine re-issues the
/// exact same forward the parked one would have.
fn park_slot(
    shared: &PoolShared,
    engine: &dyn Engine,
    lanes: &mut [Option<Slot>],
    lane: usize,
) -> bool {
    let Some(snapshot) = lanes[lane]
        .as_ref()
        .and_then(|slot| slot.machine.checkpoint())
    else {
        return false;
    };
    let Some(slot) = lanes[lane].take() else {
        return false;
    };
    engine.reset_lane(lane);
    shared.resume.lock().unwrap().push_back(ResumeJob {
        snapshot,
        committed: slot.committed,
        text_len: slot.text_len,
        n_targets: slot.n_targets,
        trace: slot.trace,
        flight: slot.flight,
        retries: slot.retries,
        life: slot.life,
    });
    true
}

/// KV-pressure preemption: park the least-progressed checkpointable slot
/// (it has the least sunk cost and the smallest sealed prefix; ties break
/// toward the higher lane, LIFO by admission order within a batch) so its
/// released blocks let the surviving batch allocate. Returns false when
/// at most one slot is active — parking the sole occupant frees blocks
/// nobody else is waiting for and risks a park/resume livelock — or when
/// nothing checkpointable is found; the caller falls back to the retry
/// ladder, whose compact relaunch needs no KV allocation at all.
fn preempt_victim(
    shared: &PoolShared,
    engine: &dyn Engine,
    lanes: &mut [Option<Slot>],
    metrics: &Metrics,
    stats: &ReplicaStats,
) -> bool {
    if lanes.iter().filter(|s| s.is_some()).count() <= 1 {
        return false;
    }
    let victim = lanes
        .iter()
        .enumerate()
        .filter_map(|(lane, s)| s.as_ref().map(|s| (lane, s)))
        .filter(|(_, s)| s.machine.checkpoint().is_some())
        .min_by_key(|&(lane, s)| (s.committed, std::cmp::Reverse(lane)))
        .map(|(lane, _)| lane);
    let Some(lane) = victim else {
        return false;
    };
    if park_slot(shared, engine, lanes, lane) {
        metrics.record_preemption();
        stats.record_preemption();
        true
    } else {
        false
    }
}

/// Difference this replica's cumulative engine counters against the
/// previous push, fold the deltas into the pool aggregate, and overwrite
/// the per-replica gauges. No-op on engines without a paged KV pool.
fn push_kv_stats(
    engine: &dyn Engine,
    metrics: &Metrics,
    stats: &ReplicaStats,
    last: &mut KvStats,
) {
    if let Some(s) = engine.kv_stats() {
        stats.record_kv(&s);
        let d = s.delta(last);
        metrics.record_prefix_cache(d.prefix_hits, d.prefix_misses, d.evictions, d.cow_copies);
        *last = s;
    }
}

/// Absorb one slot's forward rows, recording the iteration's spans: the
/// shared batched-forward span (the measured engine-call duration, tagged
/// with the rung the engine actually executed), then the machine-local
/// phase span — Draft/Verify for ASSD, Decode for the baselines — labeled
/// from the counter DELTAS around the absorb. The machine's own state is
/// read through the read-only [`DecodeMachine::phase`]/
/// [`DecodeMachine::iter_stats`] hooks, so tracing cannot perturb decode
/// outputs (enforced by the bit-identity tests below).
fn absorb_traced(
    slot: &mut Slot,
    rows: &[f32],
    fwd_dur_us: u64,
    rung: Option<Rung>,
    batch: usize,
) {
    let pre = slot.machine.iter_stats();
    let phase = slot.machine.phase();
    if let Some(tb) = slot.trace.as_mut() {
        let now = tb.now_us();
        tb.push_at(
            SpanKind::Forward,
            pre.iterations as u32,
            now.saturating_sub(fwd_dur_us),
            fwd_dur_us,
            rung.map(|r| r as u64).unwrap_or(Rung::Dense as u64),
            batch as u64,
        );
        if let Some(r) = rung {
            tb.note_rung(r);
        }
    }
    // Arm the flight tap for exactly this absorb (the arm also clears
    // any residue a panicking batch-mate could have left in the
    // thread-local buffer), and drain it right after. Machines only
    // *read* sampling buffers under the tap — the RNG stream is
    // untouched — so recording cannot perturb decode outputs.
    flight::begin(slot.flight.is_some());
    let t = Instant::now();
    slot.machine.absorb(rows);
    let dur = t.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
    if let Some(fb) = slot.flight.as_mut() {
        fb.drain_tap();
    }
    let post = slot.machine.iter_stats();
    if let Some(tb) = slot.trace.as_mut() {
        let (kind, a, b) = match phase {
            IterPhase::Draft => (
                SpanKind::Draft,
                post.draft_len as u64,
                post.aux_nfe.saturating_sub(pre.aux_nfe),
            ),
            IterPhase::Verify => (
                SpanKind::Verify,
                post.accepted.saturating_sub(pre.accepted),
                post.proposed.saturating_sub(pre.proposed),
            ),
            IterPhase::Decode => (
                SpanKind::Decode,
                post.model_nfe.saturating_sub(pre.model_nfe),
                0,
            ),
        };
        let now = tb.now_us();
        tb.push_at(kind, pre.iterations as u32, now.saturating_sub(dur), dur, a, b);
        if post.draft_len > 0 {
            tb.note_window(post.draft_len);
        }
    }
}

/// Why [`run_worker`] returned control to the supervisor.
enum WorkerExit {
    /// The admission queue closed and every slot drained: orderly exit,
    /// the replica is done for good.
    Drained,
    /// The engine incarnation died (fatal forward error or health
    /// quarantine); active slots were failed typed, queued requests are
    /// untouched, and the supervisor decides whether to re-provision.
    EngineDead,
}

/// Retire a slot that failed SERVER-SIDE (retry exhaustion, wedged
/// machine, contained decode panic, incarnation death): book the failure
/// on both metric surfaces, publish the partial trace, and deliver the
/// typed error with progress context. The `EngineError` root (when there
/// is one) stays downcastable through the added context.
fn retire_failed(
    mut slot: Slot,
    err: anyhow::Error,
    metrics: &Metrics,
    stats: &ReplicaStats,
    recorder: &SpanRecorder,
    flight_rec: &FlightRecorder,
) {
    metrics.record_failure();
    stats.record_failure();
    metrics.record_request_failed();
    stats.record_request_failed();
    let s = slot.machine.iter_stats();
    finish_trace(
        slot.trace.take(),
        false,
        s,
        String::new(),
        metrics,
        stats,
        recorder,
    );
    finish_flight(slot.flight.take(), false, String::new(), flight_rec);
    let (committed, targets) = (slot.committed, slot.n_targets);
    slot.life.finish(Err(err.context(format!(
        "request failed after {committed}/{targets} tokens"
    ))));
}

/// How one slot's retry ladder ended.
enum SlotRecovery {
    /// A retry launch delivered rows and the machine absorbed them; the
    /// slot continues exactly as if the batched call had served it.
    Recovered,
    /// The retry budget ran out (or the machine wedged mid-recovery);
    /// retire the slot with this typed error.
    Exhausted(EngineError),
    /// A retry surfaced a fatal error: the incarnation is dead.
    Fatal(EngineError),
}

/// Retry one slot after its batched forward failed, down the ladder:
/// reset the (possibly corrupt) lane, re-issue the SAME forward request
/// as a single-spec COMPACT launch, absorb on success. The failed batched
/// call never reached the machine (faults are injected/raised before any
/// absorb), and `DecodeMachine::forward_request` is idempotent between
/// absorbs, so a successful retry yields exactly the rows the batched
/// call would have — recovery is bit-identical, and Theorem-2 NFE
/// accounting is untouched (machine NFE counts absorbs, not launches).
/// The lane reset is safe mid-request: the incremental path rebuilds the
/// lane by catch-up on its next iteration, and sealed prefixes are
/// bit-equivalent to recompute (docs/ARCHITECTURE.md §Paged KV & prefix
/// cache).
fn recover_slot(
    engine: &dyn Engine,
    lane: usize,
    slot: &mut Slot,
    cause: &EngineError,
    metrics: &Metrics,
    stats: &ReplicaStats,
    ts: &mut TsFolds,
) -> SlotRecovery {
    let mut last = cause.clone();
    loop {
        if slot.retries == 0 {
            return SlotRecovery::Exhausted(last);
        }
        slot.retries -= 1;
        metrics.record_forward_retry();
        stats.record_forward_retry();
        // Drop whatever the failed call (or the fault itself) left in
        // this lane's cache; the compact retry reads no lane state.
        engine.reset_lane(lane);
        let (result, dur_us) = {
            let Some(spec) = slot.machine.forward_request() else {
                // An active machine stopped requesting work mid-recovery:
                // its state machine is wedged — retire it, not the worker.
                return SlotRecovery::Exhausted(EngineError::lane_corrupt(
                    lane,
                    "machine stopped requesting forwards during recovery",
                ));
            };
            let t = Instant::now();
            let rows = engine.forward_ord(std::slice::from_ref(&spec));
            (
                rows,
                t.elapsed().as_micros().min(u128::from(u64::MAX)) as u64,
            )
        };
        match result {
            Ok(rows) => match rows.into_iter().next() {
                Some(seq_rows) => {
                    let rung = tap::take_rung();
                    absorb_traced(slot, &seq_rows, dur_us, rung, 1);
                    return SlotRecovery::Recovered;
                }
                None => {
                    tap::reset();
                    last = EngineError::transient("retry launch returned no rows");
                }
            },
            Err(e) => {
                tap::reset();
                metrics.record_engine_error(e.class());
                stats.record_engine_error();
                ts.note_engine_error(e.class());
                if e.class() == ErrorClass::Fatal {
                    return SlotRecovery::Fatal(e);
                }
                last = e;
            }
        }
    }
}

/// Put every slot a failed batched call was carrying through the retry
/// ladder. Recovered slots continue in place; exhausted ones retire with
/// the typed error; a fatal retry error aborts the sweep and marks the
/// incarnation dead (remaining slots are failed by the teardown path).
#[allow(clippy::too_many_arguments)]
fn recover_lanes(
    engine: &dyn Engine,
    lanes: &mut [Option<Slot>],
    idx: &[usize],
    cause: &EngineError,
    metrics: &Metrics,
    stats: &ReplicaStats,
    recorder: &SpanRecorder,
    flight_rec: &FlightRecorder,
    ts: &mut TsFolds,
    engine_dead: &mut Option<EngineError>,
) {
    for &lane in idx {
        if engine_dead.is_some() {
            return;
        }
        let outcome = match lanes[lane].as_mut() {
            Some(slot) => recover_slot(engine, lane, slot, cause, metrics, stats, ts),
            None => continue,
        };
        match outcome {
            SlotRecovery::Recovered => {}
            SlotRecovery::Exhausted(e) => {
                if let Some(slot) = lanes[lane].take() {
                    engine.reset_lane(lane);
                    retire_failed(
                        slot,
                        anyhow::Error::new(e).context("retry budget exhausted"),
                        metrics,
                        stats,
                        recorder,
                        flight_rec,
                    );
                }
            }
            SlotRecovery::Fatal(e) => *engine_dead = Some(e),
        }
    }
}

/// Absorb one slot's rows with PANIC CONTAINMENT: a decode-machine panic
/// is a bug in that request's state machine, not in its batch-mates — the
/// slot is retired with a typed error and the worker (and every other
/// lane) keeps serving. `AssertUnwindSafe` is sound because the panicking
/// slot is retired immediately: its possibly-inconsistent machine state
/// is never observed again.
#[allow(clippy::too_many_arguments)]
fn absorb_contained(
    engine: &dyn Engine,
    lanes: &mut [Option<Slot>],
    lane: usize,
    rows: &[f32],
    dur_us: u64,
    rung: Option<Rung>,
    batch: usize,
    metrics: &Metrics,
    stats: &ReplicaStats,
    recorder: &SpanRecorder,
    flight_rec: &FlightRecorder,
) {
    let Some(slot) = lanes[lane].as_mut() else {
        return;
    };
    let absorbed = catch_unwind(AssertUnwindSafe(|| {
        absorb_traced(slot, rows, dur_us, rung, batch)
    }));
    if absorbed.is_err() {
        if let Some(slot) = lanes[lane].take() {
            engine.reset_lane(lane);
            retire_failed(
                slot,
                anyhow::Error::new(EngineError::lane_corrupt(lane, "decode step panicked")),
                metrics,
                stats,
                recorder,
                flight_rec,
            );
        }
    }
}

/// One worker's continuous-batching loop over its private engine replica.
#[allow(clippy::too_many_arguments)]
fn run_worker(
    engine: &dyn Engine,
    rx: &mpmc::Receiver<Job>,
    shared: &PoolShared,
    cfg: SchedulerConfig,
    metrics: &Metrics,
    stats: &ReplicaStats,
    recorder: &SpanRecorder,
    obs: &WorkerObs<'_>,
) -> WorkerExit {
    let tok = ByteTokenizer::new();
    // Health is per-incarnation: a fresh tracker each time the supervisor
    // provisions an engine, so a past incarnation's error streak cannot
    // poison its replacement.
    let mut health = HealthTracker::new(cfg.health);
    // Engines record rung/prefix-probe notes into thread-locals (each
    // engine is owned by exactly this thread); start from a clean slate
    // so a prior occupant of the thread cannot leak notes into our first
    // iteration.
    tap::reset();
    flight::reset();
    // Per-worker time-series folds: the replica's cumulative counters are
    // turned into per-second deltas for the bucket ring (counters fold,
    // gauges overwrite). Per-incarnation is fine: the cumulative sources
    // (ReplicaStats) outlive incarnations, and CounterFold's reset rule
    // only fires when a cumulative actually goes backwards.
    let mut ts = TsFolds::default();
    // BLOCK-BUDGET ADMISSION: on a paged-KV engine, concurrency is capped
    // by memory, not just `max_batch` — admit only as many lanes as the
    // block pool can back at their worst case (every lane growing to the
    // full window). Cached prefixes do NOT count against the budget: their
    // blocks are evictable (LRU) the moment an active lane needs them,
    // whereas active lanes are never evicted — so admission under this cap
    // can never deadlock on pool exhaustion. Engines without a pool
    // (compact/dense paths) keep the plain `max_batch` cap.
    let lane_budget = engine
        .kv_stats()
        .map(|s| s.lane_budget(engine.seq_len()))
        .unwrap_or(usize::MAX);
    let mut last_kv = KvStats::default();
    // Batch slots double as engine CACHE LANES: a request is pinned to
    // its slot index for its whole lifetime, so the engine can key the
    // sequence's persistent K/V cache by lane and retiring one slot never
    // re-indexes (or touches the cache of) a batch-mate — the reason this
    // is a fixed Vec<Option<Slot>> rather than the old swap_remove Vec.
    let mut lanes: Vec<Option<Slot>> = (0..cfg.max_batch.max(1).min(lane_budget))
        .map(|_| None)
        .collect();
    let mut queue_open = true;
    fn active(lanes: &[Option<Slot>]) -> usize {
        lanes.iter().filter(|s| s.is_some()).count()
    }

    while queue_open || active(&lanes) > 0 {
        // --- time-series tick: fold this replica's cumulative counters
        //     into the current one-second bucket. Idle iterations tick
        //     too (the admission loop blocks at most `idle_poll`), so
        //     seconds keep advancing and gauges stay fresh while the
        //     replica waits for work. ---
        ts.tick(obs, stats, engine, rx.len(), active(&lanes));
        let draining = shared.draining.load(AtomicOrdering::Relaxed);
        if draining {
            // --- drain sweep (POST /drain): park every checkpointable
            //     active slot (aborted ones retire as usual); machines
            //     that cannot checkpoint keep decoding to completion —
            //     the drain waits them out rather than failing them. ---
            for lane in 0..lanes.len() {
                let aborted = lanes[lane].as_ref().and_then(|s| s.life.abort_reason());
                if let Some(reason) = aborted {
                    let Some(slot) = lanes[lane].take() else { continue };
                    engine.reset_lane(lane);
                    abort_slot(slot, reason, metrics, stats, recorder, obs.flight);
                    continue;
                }
                if lanes[lane].is_some() && park_slot(shared, engine, &mut lanes, lane) {
                    metrics.record_drain();
                }
            }
            if active(&lanes) == 0 {
                // Parked: nothing to decode and admission is refused.
                // Exit only on pool shutdown (the last worker's guard
                // fails whatever stayed parked); otherwise idle-poll so
                // lifting the flag is noticed promptly.
                if rx.is_closed() && rx.is_empty() {
                    queue_open = false;
                    continue;
                }
                thread::sleep(cfg.idle_poll);
                continue;
            }
        }
        // --- admission: resume parked checkpoints first, then top up
        //     free lanes from the shared queue. A draining worker admits
        //     nothing — new work queues behind the drain and parked
        //     checkpoints wait for the flag to lift. ---
        while !draining && active(&lanes) < lanes.len() && queue_open {
            // Parked checkpoints outrank the queue: they already spent
            // their queue wait once and their clients hold open streams.
            let resumed = shared.resume.lock().unwrap().pop_front();
            if let Some(rj) = resumed {
                // Abort beats resume — and the deadline clock kept
                // running while parked (same submission epoch), so a
                // request that expired in the park books
                // deadline_expired, never cancelled.
                if let Some(reason) = rj.life.abort_reason() {
                    let what = record_abort(reason, metrics, stats);
                    finish_trace(
                        rj.trace,
                        false,
                        IterStats::default(),
                        String::new(),
                        metrics,
                        stats,
                        recorder,
                    );
                    finish_flight(rj.flight, false, String::new(), obs.flight);
                    rj.life.finish(Err(anyhow!(
                        "{what} while queued after {}/{} tokens",
                        rj.committed,
                        rj.n_targets
                    )));
                    continue;
                }
                let Some(lane) = lanes.iter().position(|s| s.is_none()) else {
                    // The loop guard said a lane was free; if the
                    // invariant broke, re-park rather than fail.
                    shared.resume.lock().unwrap().push_front(rj);
                    break;
                };
                // Lane handoff as at first admission. The restored
                // machine's next forward re-seeds the lane — warm via
                // the prefix cache when its sealed rows are still
                // resident, cold (catch-up recompute, bit-identical)
                // otherwise.
                engine.reset_lane(lane);
                let machine = crate::decode::snapshot::restore(rj.snapshot);
                let mut trace = rj.trace;
                if let Some(b) = trace.as_mut() {
                    b.push(
                        SpanKind::Admit,
                        machine.iter_stats().iterations as u32,
                        0,
                        rj.n_targets as u64,
                        lane as u64,
                    );
                }
                lanes[lane] = Some(Slot {
                    machine,
                    t0: rj.life.submitted_at(),
                    last_commit: Instant::now(),
                    committed: rj.committed,
                    text_len: rj.text_len,
                    n_targets: rj.n_targets,
                    trace,
                    flight: rj.flight,
                    retries: rj.retries,
                    life: rj.life,
                });
                continue;
            }
            let job = if active(&lanes) == 0 {
                match rx.recv_timeout(cfg.idle_poll) {
                    Ok(j) => j,
                    Err(mpmc::RecvTimeoutError::Timeout) => break,
                    Err(mpmc::RecvTimeoutError::Disconnected) => {
                        queue_open = false;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(mpmc::TryRecvError::Empty) => break,
                    Err(mpmc::TryRecvError::Disconnected) => {
                        queue_open = false;
                        break;
                    }
                }
            };
            // A request can die while still queued (client cancelled or
            // vanished, deadline burned up waiting): never give it a slot.
            if let Some(reason) = job.life.abort_reason() {
                let what = record_abort(reason, metrics, stats);
                job.life.finish(Err(anyhow!("{what} while queued")));
                continue;
            }
            // Trace epoch = submission (matches the TTFT/deadline clock),
            // so queue wait is span [0, now) and every later span's ts is
            // monotone µs-since-submit.
            let sampler = job.request.sampler.name();
            let submitted = job.life.submitted_at();
            let t_admit = Instant::now();
            let queue_us = (t_admit - submitted).as_micros().min(u128::from(u64::MAX)) as u64;
            let mut trace = cfg.trace.then(|| {
                let mut b = TraceBuilder::new(
                    job.request_id,
                    stats.id,
                    sampler,
                    submitted,
                    DEFAULT_SPAN_CAP,
                );
                b.push_at(SpanKind::QueueWait, 0, 0, queue_us, 0, 0);
                b
            });
            // Flight sampling is deterministic in the request id, so a
            // request is either recorded everywhere or nowhere — replays
            // and cross-replica comparisons see the same sample set.
            let flight = flight::sampled(job.request_id, cfg.flight_sample_rate)
                .then(|| FlightBuilder::new(job.request_id, stats.id, sampler));
            match admit(engine, &tok, job.request, cfg.default_draft) {
                Ok(AdmitResult::Slot(machine, text_len, n_targets)) => {
                    // The admission loop's guard guarantees a free lane;
                    // if that invariant ever breaks, it must cost this
                    // one request a typed error, not the worker its life
                    // (the old `.expect` here unwound the whole replica).
                    let Some(lane) = lanes.iter().position(|s| s.is_none()) else {
                        metrics.record_failure();
                        stats.record_failure();
                        metrics.record_request_failed();
                        stats.record_request_failed();
                        finish_trace(
                            trace,
                            false,
                            IterStats::default(),
                            String::new(),
                            metrics,
                            stats,
                            recorder,
                        );
                        finish_flight(flight, false, String::new(), obs.flight);
                        job.life
                            .finish(Err(anyhow!("internal: no free lane at admission")));
                        continue;
                    };
                    // Lane handoff: whatever the previous occupant left in
                    // the engine-side cache is dropped BEFORE the new
                    // request can issue a forward from this lane.
                    engine.reset_lane(lane);
                    if let Some(b) = trace.as_mut() {
                        b.push(SpanKind::Admit, 0, queue_us, n_targets as u64, lane as u64);
                    }
                    // TTFT and latency_s run from SUBMISSION, the same
                    // clock the deadline uses — queue wait counts.
                    let t0 = job.life.submitted_at();
                    lanes[lane] = Some(Slot {
                        machine,
                        life: job.life,
                        t0,
                        last_commit: t0,
                        committed: 0,
                        text_len,
                        n_targets,
                        trace,
                        flight,
                        retries: cfg.retry_budget,
                    });
                }
                Ok(AdmitResult::Immediate(mut resp)) => {
                    resp.request_id = job.request_id;
                    if let Some(b) = trace.as_mut() {
                        b.push(SpanKind::Admit, 0, queue_us, 0, 0);
                    }
                    finish_trace(
                        trace,
                        true,
                        IterStats::default(),
                        String::new(),
                        metrics,
                        stats,
                        recorder,
                    );
                    finish_flight(flight, true, String::new(), obs.flight);
                    job.life.finish(Ok(resp));
                }
                Err(e) => {
                    metrics.record_failure();
                    stats.record_failure();
                    finish_trace(
                        trace,
                        false,
                        IterStats::default(),
                        String::new(),
                        metrics,
                        stats,
                        recorder,
                    );
                    finish_flight(flight, false, String::new(), obs.flight);
                    job.life.finish(Err(e));
                }
            }
        }

        // --- lifecycle check: retire dead slots BEFORE spending compute
        //     on them (cancel token, deadline, abandoned event channel).
        //     Machines own their RNG, the engine evaluates sequences
        //     independently, and retirement only clears this slot's own
        //     lane, so removal never disturbs batch-mates. ---
        for lane in 0..lanes.len() {
            let aborted = lanes[lane].as_ref().and_then(|s| s.life.abort_reason());
            if let Some(reason) = aborted {
                let Some(slot) = lanes[lane].take() else { continue };
                engine.reset_lane(lane);
                abort_slot(slot, reason, metrics, stats, recorder, obs.flight);
            }
        }
        let b = active(&lanes);
        if b == 0 {
            continue;
        }

        // --- one batched forward over all active machines ---
        // Each machine's request borrows its own state (tokens, ordering,
        // wanted rows); no per-slot mask or token buffers are copied.
        // Machines that vouch for a fixed ordering route through the
        // lane-pinned INCREMENTAL path (the engine appends their newly
        // committed rows to the lane cache and computes only the active
        // rows); the rest (diffusion) stay on the compact path. On
        // engines without a native incremental path everything takes one
        // compact call, exactly as before.
        metrics.record_batch_iteration(b);
        stats.record_batch_iteration(b);
        let native_inc = engine.inc_lanes() > 0;
        // Forward durations and actual execution rungs, per batched call
        // (the engines note the weakest rung they actually took into a
        // thread-local tap; exact because each engine is thread-pinned).
        let mut inc_dur_us = 0u64;
        let mut ord_dur_us = 0u64;
        let mut inc_rung = None;
        let mut ord_rung = None;
        let mut probes: Vec<(usize, bool)> = Vec::new();
        let mut batch_errors = 0u32;
        let (inc_idx, ord_idx, wedged, inc_result, ord_result) = {
            let mut inc_specs: Vec<IncSpec<'_>> = Vec::new();
            let mut inc_idx: Vec<usize> = Vec::new();
            let mut ord_specs: Vec<ForwardSpec<'_>> = Vec::new();
            let mut ord_idx: Vec<usize> = Vec::new();
            let mut wedged: Vec<usize> = Vec::new();
            for (lane, slot) in lanes.iter_mut().enumerate() {
                let Some(slot) = slot.as_mut() else { continue };
                // Read the commit level BEFORE the request borrows the
                // machine (it describes the state the request is from).
                let committed = slot.machine.incremental();
                // An active, un-done machine that requests no forward is
                // WEDGED (a DecodeMachine contract violation): retire
                // just that slot below — the old `.expect` here took the
                // whole worker, and every batch-mate, down with it.
                let Some(spec) = slot.machine.forward_request() else {
                    wedged.push(lane);
                    continue;
                };
                match committed {
                    Some(committed) if native_inc => {
                        inc_idx.push(lane);
                        inc_specs.push(IncSpec {
                            spec,
                            committed,
                            lane,
                        });
                    }
                    _ => {
                        ord_idx.push(lane);
                        ord_specs.push(spec);
                    }
                }
            }
            // The two batched calls run — and fail — INDEPENDENTLY: a
            // fault on the incremental path must not cost the compact
            // path its launch (or vice versa). Fault isolation starts at
            // the call boundary.
            let inc_result = if inc_specs.is_empty() {
                Ok(Vec::new())
            } else {
                let t = Instant::now();
                let rows = engine.forward_inc(&inc_specs);
                inc_dur_us = t.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
                match &rows {
                    Ok(_) => {
                        inc_rung = tap::take_rung();
                        tap::take_prefix_probes(&mut probes);
                    }
                    // A half-executed call may have left rung/probe
                    // notes; drop them so they cannot attach to the next
                    // launch's spans.
                    Err(_) => tap::reset(),
                }
                rows
            };
            let ord_result = if ord_specs.is_empty() {
                Ok(Vec::new())
            } else {
                let t = Instant::now();
                let rows = engine.forward_ord(&ord_specs);
                ord_dur_us = t.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
                match &rows {
                    Ok(_) => ord_rung = tap::take_rung(),
                    Err(_) => tap::reset(),
                }
                rows
            };
            (inc_idx, ord_idx, wedged, inc_result, ord_result)
        };
        // Wedged machines retire alone; their batch-mates proceed.
        for lane in wedged {
            if let Some(slot) = lanes[lane].take() {
                engine.reset_lane(lane);
                retire_failed(
                    slot,
                    anyhow::Error::new(EngineError::lane_corrupt(
                        lane,
                        "active machine is neither done nor requesting a forward",
                    )),
                    metrics,
                    stats,
                    recorder,
                    obs.flight,
                );
            }
        }
        // --- fault isolation: a failed batched call no longer unwinds
        //     the worker (or its batch-mates). Transient and lane-corrupt
        //     failures put every slot the call carried through the
        //     per-slot retry ladder; a fatal failure (or a quarantine,
        //     below) ends the incarnation and hands the replica to the
        //     supervisor. ---
        let mut engine_dead: Option<EngineError> = None;
        let inc_rows = match inc_result {
            Ok(rows) => rows,
            Err(e) => {
                metrics.record_engine_error(e.class());
                stats.record_engine_error();
                ts.note_engine_error(e.class());
                if e.class() == ErrorClass::Fatal {
                    batch_errors += 1;
                    engine_dead = Some(e);
                } else if e.is_kv_pressure()
                    && preempt_victim(shared, engine, &mut lanes, metrics, stats)
                {
                    // KV PRESSURE, RELIEVED BY PREEMPTION: a victim slot
                    // checkpointed, sealed its committed rows, and
                    // released its lane's blocks. The failed call never
                    // reached any machine, so every survivor simply
                    // re-issues the same idempotent forward next
                    // iteration — bit-identical, no retry budget spent,
                    // and not a health event (the engine is sound; the
                    // pool was merely full).
                } else {
                    batch_errors += 1;
                    recover_lanes(
                        engine,
                        &mut lanes,
                        &inc_idx,
                        &e,
                        metrics,
                        stats,
                        recorder,
                        obs.flight,
                        &mut ts,
                        &mut engine_dead,
                    );
                }
                Vec::new()
            }
        };
        let ord_rows = match ord_result {
            Ok(rows) => {
                if engine_dead.is_some() {
                    // A fatal error on the other path killed the
                    // incarnation; these rows die with it (their slots
                    // are failed typed by the teardown below).
                    Vec::new()
                } else {
                    rows
                }
            }
            Err(e) => {
                metrics.record_engine_error(e.class());
                stats.record_engine_error();
                ts.note_engine_error(e.class());
                if engine_dead.is_none() {
                    if e.class() == ErrorClass::Fatal {
                        batch_errors += 1;
                        engine_dead = Some(e);
                    } else if e.is_kv_pressure()
                        && preempt_victim(shared, engine, &mut lanes, metrics, stats)
                    {
                        // see the incremental arm above: preemption, not
                        // a retry and not a health event
                    } else {
                        batch_errors += 1;
                        recover_lanes(
                            engine,
                            &mut lanes,
                            &ord_idx,
                            &e,
                            metrics,
                            stats,
                            recorder,
                            obs.flight,
                            &mut ts,
                            &mut engine_dead,
                        );
                    }
                } else {
                    batch_errors += 1;
                }
                Vec::new()
            }
        };
        // --- health: consecutive failed batched calls escalate
        //     Healthy → Degraded → Quarantined; any clean iteration
        //     recovers the streak. Mirrored into the shared replica
        //     state for GET /healthz and GET /replicas. ---
        if batch_errors == 0 {
            health.record_success();
        } else {
            for _ in 0..batch_errors {
                health.record_error();
            }
        }
        match health.health() {
            Health::Healthy => stats.set_state(ReplicaState::Running),
            Health::Degraded => stats.set_state(ReplicaState::Degraded),
            Health::Quarantined => {
                if engine_dead.is_none() {
                    engine_dead = Some(EngineError::fatal(
                        "replica quarantined: consecutive batched-forward failures \
                         crossed the health policy's quarantine threshold",
                    ));
                }
            }
        }
        if let Some(cause) = engine_dead {
            // The incarnation is gone: MIGRATE the slots it was carrying
            // — checkpoint unless truly failed. The failed call never
            // reached any machine, so every slot sits cleanly between
            // absorbs and its checkpoint resumes bit-identically on the
            // next incarnation (or a pool-mate); replica death costs
            // latency, not requests, and the clients' streams stay open
            // with no token re-emitted. Only aborted lifecycles and
            // non-checkpointable machines still fail. Queued requests
            // are untouched as before.
            tap::reset();
            flight::reset();
            stats.set_state(ReplicaState::Quarantined);
            for lane in 0..lanes.len() {
                let aborted = lanes[lane].as_ref().and_then(|s| s.life.abort_reason());
                if let Some(reason) = aborted {
                    let Some(slot) = lanes[lane].take() else { continue };
                    engine.reset_lane(lane);
                    abort_slot(slot, reason, metrics, stats, recorder, obs.flight);
                    continue;
                }
                if lanes[lane].is_some() && park_slot(shared, engine, &mut lanes, lane) {
                    metrics.record_migration();
                    stats.record_migration();
                    continue;
                }
                if let Some(slot) = lanes[lane].take() {
                    engine.reset_lane(lane);
                    retire_failed(
                        slot,
                        anyhow::Error::new(cause.clone()).context("engine incarnation lost"),
                        metrics,
                        stats,
                        recorder,
                        obs.flight,
                    );
                }
            }
            // Final tick so the fatal-error delta and the incarnation's
            // last gauges land in the ring before the thread exits.
            ts.tick(obs, stats, engine, rx.len(), 0);
            return WorkerExit::EngineDead;
        }
        // Prefix-probe attribution: the engine noted (lane, hit) at every
        // prefix-cache lookup this batch; fold each into its slot's trace.
        for (lane, hit) in probes.drain(..) {
            if let Some(slot) = lanes.get_mut(lane).and_then(|s| s.as_mut()) {
                if let Some(tb) = slot.trace.as_mut() {
                    tb.note_prefix_probe(hit);
                }
                if let Some(fb) = slot.flight.as_mut() {
                    fb.note_prefix_probe(hit);
                }
            }
        }
        for (seq_rows, &lane) in inc_rows.iter().zip(&inc_idx) {
            absorb_contained(
                engine,
                &mut lanes,
                lane,
                seq_rows,
                inc_dur_us,
                inc_rung,
                inc_idx.len(),
                metrics,
                stats,
                recorder,
                obs.flight,
            );
        }
        for (seq_rows, &lane) in ord_rows.iter().zip(&ord_idx) {
            absorb_contained(
                engine,
                &mut lanes,
                lane,
                seq_rows,
                ord_dur_us,
                ord_rung,
                ord_idx.len(),
                metrics,
                stats,
                recorder,
                obs.flight,
            );
        }

        // --- stream freshly accepted tokens (TTFT/ITL bookkeeping) ---
        for slot in lanes.iter_mut().flatten() {
            let t_commit = Instant::now();
            let commits = slot.machine.drain_commits();
            if commits.is_empty() {
                continue;
            }
            if let Some(tb) = slot.trace.as_mut() {
                let dur = t_commit.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
                let iter = slot.machine.iter_stats().iterations as u32;
                let start = tb.now_us().saturating_sub(dur);
                tb.push_at(SpanKind::Commit, iter, start, dur, commits.len() as u64, 0);
                tb.add_commits(commits.len());
            }
            let now = Instant::now();
            if slot.committed == 0 {
                metrics.record_ttft((now - slot.t0).as_secs_f64());
            } else {
                metrics.record_itl((now - slot.last_commit).as_secs_f64() / commits.len() as f64);
            }
            slot.committed += commits.len();
            slot.last_commit = now;
            let (positions, tokens): (Vec<usize>, Vec<u32>) = commits.into_iter().unzip();
            // A false return means the client lags or vanished; the
            // emitter flipped the cancel token, so the lifecycle check
            // above retires this slot at the next iteration.
            slot.life.commit(positions, tokens);
        }

        // --- retire finished machines ---
        for lane in 0..lanes.len() {
            let done = lanes[lane].as_ref().is_some_and(|s| s.machine.done());
            if !done {
                continue;
            }
            let Some(mut slot) = lanes[lane].take() else {
                continue;
            };
            engine.reset_lane(lane);
            // A machine can finish on the very iteration its client
            // lagged (final commit dropped, cancel flipped) or
            // vanished: delivering Done then would end the stream as
            // a SUCCESS with tokens silently missing. Deadline
            // expiry alone is different — the work is complete and
            // the stream intact, so the result is still delivered
            // (stream_broken ignores the deadline, unlike
            // abort_reason, so an expired deadline cannot mask a
            // broken stream here).
            if let Some(reason) = slot.life.stream_broken() {
                abort_slot(slot, reason, metrics, stats, recorder, obs.flight);
                continue;
            }
            let latency = slot.t0.elapsed().as_secs_f64();
            let trace = slot.trace.take();
            let flight = slot.flight.take();
            let outcome = slot.machine.outcome();
            let mut resp =
                outcome_to_response(&tok, outcome, latency, slot.text_len, slot.n_targets);
            resp.request_id = slot.life.request_id();
            finish_trace(
                trace,
                true,
                IterStats {
                    model_nfe: resp.model_nfe,
                    aux_nfe: resp.aux_nfe,
                    iterations: resp.iterations,
                    proposed: resp.proposed,
                    accepted: resp.accepted,
                    draft_len: resp.draft_len,
                },
                resp.draft_kind.clone(),
                metrics,
                stats,
                recorder,
            );
            finish_flight(flight, true, resp.draft_kind.clone(), obs.flight);
            metrics.record_request(
                latency,
                resp.n_generated as u64,
                resp.model_nfe,
                resp.aux_nfe,
                resp.proposed,
                resp.accepted,
            );
            stats.record_request(
                resp.n_generated as u64,
                resp.model_nfe,
                resp.aux_nfe,
                resp.proposed,
                resp.accepted,
            );
            slot.life.finish(Ok(resp));
        }

        // --- export this iteration's block-pool state: gauges overwrite
        //     the replica snapshot; hit/miss/eviction deltas fold into
        //     the pool aggregate. Runs AFTER retirement so a lane's
        //     closing seal (prefix-cache insert) is visible immediately.
        push_kv_stats(engine, metrics, stats, &mut last_kv);
    }
    WorkerExit::Drained
}

enum AdmitResult {
    Slot(Box<dyn DecodeMachine>, usize, usize),
    Immediate(InfillResponse),
}

/// Turn a request into a decode machine (or an immediate response when
/// there is nothing to infill).
fn admit(
    engine: &dyn Engine,
    tok: &ByteTokenizer,
    req: InfillRequest,
    default_draft: DraftOptions,
) -> Result<AdmitResult> {
    let n = engine.seq_len();
    let v = engine.vocab();
    if req.text.is_empty() {
        bail!("empty text");
    }
    let bytes = req.text.as_bytes();
    if bytes.len() > n {
        bail!("text longer than model window ({} > {n})", bytes.len());
    }
    // Token buffer: visible bytes, MASK at mask_char, PAD tail (visible).
    let mask_byte = {
        let mut buf = [0u8; 4];
        let s = req.mask_char.encode_utf8(&mut buf);
        if s.len() != 1 {
            bail!("mask_char must be a single byte");
        }
        buf[0]
    };
    let mut tokens = tok.encode_fixed(&req.text, n);
    let mut visible: Vec<usize> = Vec::with_capacity(n);
    let mut n_targets = 0;
    for (i, t) in tokens.iter_mut().enumerate() {
        if i < bytes.len() && bytes[i] == mask_byte {
            *t = MASK;
            n_targets += 1;
        } else {
            visible.push(i);
        }
    }
    if n_targets == 0 {
        return Ok(AdmitResult::Immediate(InfillResponse {
            request_id: 0, // stamped by the worker from the job
            text: req.text,
            model_nfe: 0,
            aux_nfe: 0,
            iterations: 0,
            proposed: 0,
            accepted: 0,
            acceptance_rate: 0.0,
            draft_kind: String::new(),
            draft_len: 0,
            latency_s: 0.0,
            n_generated: 0,
        }));
    }
    let m = visible.len();
    let ord = Ordering::new(lattice_sigma(&visible, n), m);
    let rng = Rng::new(req.seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1));
    let machine: Box<dyn DecodeMachine> = match req.sampler {
        SamplerKind::Assd | SamplerKind::AssdNgram => {
            let opts = req.sampler.effective_draft(req.draft.resolve(default_draft));
            // Window cap: the artifact sequence length AND the compact
            // path's row-gather width, so speculation never forces the
            // engine off its fwd_ord artifacts mid-request.
            let cap = n.min(engine.max_gather_rows());
            Box::new(AssdMachine::from_options(
                ord,
                tokens,
                v,
                opts,
                cap,
                req.temperature,
                rng,
            ))
        }
        SamplerKind::Sequential => Box::new(SequentialMachine::new(
            ord,
            tokens,
            v,
            req.temperature,
            rng,
        )),
        SamplerKind::Diffusion => Box::new(DiffusionMachine::new(
            tokens,
            v,
            req.steps,
            req.temperature,
            rng,
        )),
    };
    Ok(AdmitResult::Slot(machine, bytes.len(), n_targets))
}

fn outcome_to_response(
    tok: &ByteTokenizer,
    outcome: DecodeOutcome,
    latency_s: f64,
    text_len: usize,
    n_targets: usize,
) -> InfillResponse {
    // The original text occupied the first `text_len` byte positions; the
    // rest is PAD. Truncate at the token level (byte-level truncation of
    // the decoded string could split a multi-byte char).
    let text = tok.decode(&outcome.tokens[..text_len.min(outcome.tokens.len())]);
    InfillResponse {
        request_id: 0, // stamped by the worker from the slot's lifecycle
        text,
        model_nfe: outcome.model_nfe,
        aux_nfe: outcome.aux_nfe,
        iterations: outcome.iterations,
        proposed: outcome.proposed,
        accepted: outcome.accepted,
        acceptance_rate: outcome.acceptance_rate(),
        draft_kind: outcome.draft_kind,
        draft_len: outcome.final_draft_len,
        latency_s,
        n_generated: n_targets,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lifecycle::Event;
    use crate::coordinator::DraftSpec;
    use crate::draft::DraftKind;
    use crate::runtime::mock::{MockEngine, SlowEngine};
    use crate::runtime::PagedKvConfig;

    fn mock_handle(max_batch: usize) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        let m2 = metrics.clone();
        let h = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch,
                idle_poll: Duration::from_millis(5),
                ..Default::default()
            },
            m2,
        );
        (h, metrics)
    }

    /// A pool whose forwards take `delay` each: slow enough to observe
    /// cancellation, deadlines, and shedding deterministically.
    fn slow_handle(
        max_batch: usize,
        queue_depth: usize,
        delay_ms: u64,
    ) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        let m2 = metrics.clone();
        let h = spawn(
            move || {
                Ok(Box::new(SlowEngine::new(
                    MockEngine::new(3, 16, 258, 1.0),
                    Duration::from_millis(delay_ms),
                )) as Box<dyn Engine>)
            },
            SchedulerConfig {
                max_batch,
                queue_depth,
                idle_poll: Duration::from_millis(2),
                ..Default::default()
            },
            m2,
        );
        (h, metrics)
    }

    fn mock_pool_handle(replicas: usize, max_batch: usize) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        // Every replica gets the SAME seed: replicas are share-nothing
        // copies of one model, so outputs must not depend on which worker
        // serves a request.
        let pool = EnginePool::from_fn(PoolConfig { replicas }, |_id| {
            Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>)
        });
        let h = spawn_pool(
            pool,
            SchedulerConfig {
                max_batch,
                idle_poll: Duration::from_millis(5),
                ..Default::default()
            },
            metrics.clone(),
        );
        (h, metrics)
    }

    #[test]
    fn serves_single_request() {
        let (h, metrics) = mock_handle(2);
        let resp = h
            .infill(InfillRequest {
                text: "ab__cd__".into(),
                seed: 7,
                ..Default::default()
            })
            .unwrap();
        // The mock engine emits arbitrary bytes, so the lossy UTF-8 decode
        // may change byte lengths; assert structure, not exact bytes.
        assert!(resp.text.starts_with("ab"), "{:?}", resp.text);
        assert!(!resp.text.contains('_'));
        assert_eq!(resp.n_generated, 4);
        assert!(resp.model_nfe >= 1 && resp.model_nfe <= 4);
        assert_eq!(metrics.requests(), 1);
    }

    #[test]
    fn no_mask_is_immediate() {
        let (h, _) = mock_handle(2);
        let resp = h
            .infill(InfillRequest {
                text: "hello".into(),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.text, "hello");
        assert_eq!(resp.model_nfe, 0);
    }

    #[test]
    fn rejects_oversized_and_empty() {
        let (h, _) = mock_handle(2);
        assert!(h
            .infill(InfillRequest {
                text: "x".repeat(100),
                ..Default::default()
            })
            .is_err());
        assert!(h
            .infill(InfillRequest {
                text: "".into(),
                ..Default::default()
            })
            .is_err());
    }

    #[test]
    fn all_samplers_complete() {
        let (h, _) = mock_handle(4);
        for sampler in SamplerKind::ALL {
            let resp = h
                .infill(InfillRequest {
                    text: "ab____cd".into(),
                    sampler,
                    seed: 11,
                    ..Default::default()
                })
                .unwrap();
            assert!(!resp.text.contains('_'), "{}: {}", sampler.name(), resp.text);
        }
    }

    /// Every drafter kind (fixed and adaptive) serves requests end to end,
    /// reports its identity and telemetry in the response, and feeds the
    /// aggregate speculation counters.
    #[test]
    fn all_drafters_serve_with_telemetry() {
        let (h, metrics) = mock_handle(2);
        for kind in DraftKind::ALL {
            for adaptive in [false, true] {
                let resp = h
                    .infill(InfillRequest {
                        text: "ab______cd".into(),
                        draft: DraftSpec::from_options(DraftOptions {
                            kind,
                            max_len: 4,
                            adaptive,
                        }),
                        seed: 21,
                        ..Default::default()
                    })
                    .unwrap();
                assert!(!resp.text.contains('_'), "{}: {}", kind.name(), resp.text);
                assert_eq!(resp.draft_kind, kind.name());
                assert!(resp.proposed > 0, "{}: no speculation", kind.name());
                assert!(resp.accepted <= resp.proposed);
                assert!(resp.draft_len >= 1);
                if kind == DraftKind::SelfModel {
                    assert!(resp.model_nfe <= 8, "Theorem 1: {}", resp.model_nfe);
                } else {
                    assert!(resp.aux_nfe > 0, "external drafter books aux NFE");
                }
            }
        }
        let j = metrics.snapshot_json();
        assert!(j.get("proposed").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("acceptance_rate").unwrap().as_f64().unwrap() > 0.0);
    }

    /// The scheduler's default draft config applies when a request carries
    /// no draft field (and per-request draft fields override it).
    #[test]
    fn default_draft_config_applies() {
        let metrics = Metrics::new();
        let h = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(5),
                default_draft: DraftOptions {
                    kind: DraftKind::Lookup,
                    max_len: 3,
                    adaptive: false,
                },
                ..Default::default()
            },
            metrics,
        );
        let resp = h
            .infill(InfillRequest {
                text: "ab____cd".into(),
                seed: 5,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.draft_kind, "lookup");
        let resp = h
            .infill(InfillRequest {
                text: "ab____cd".into(),
                draft: DraftSpec::from_options(DraftOptions::default()),
                seed: 5,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.draft_kind, "self", "per-request draft overrides default");
        // partial spec: only the specified field overrides, the rest
        // (kind = lookup) still inherits the pool default
        let resp = h
            .infill(InfillRequest {
                text: "ab____cd".into(),
                draft: DraftSpec {
                    max_len: Some(2),
                    ..Default::default()
                },
                seed: 5,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.draft_kind, "lookup", "partial spec must inherit kind");
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let (h, metrics) = mock_handle(4);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                h.submit(InfillRequest {
                    text: "ab______".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rh in handles {
            let resp = rh.wait().unwrap();
            assert_eq!(resp.n_generated, 6);
        }
        let j = metrics.snapshot_json();
        let occ = j.get("mean_batch_occupancy").unwrap().as_f64().unwrap();
        assert!(occ > 1.0, "continuous batching never batched (occ={occ})");
    }

    #[test]
    fn deterministic_given_seed() {
        let (h, _) = mock_handle(1);
        let get = |seed| {
            h.infill(InfillRequest {
                text: "xy____zw".into(),
                seed,
                ..Default::default()
            })
            .unwrap()
            .text
        };
        assert_eq!(get(5), get(5));
    }

    #[test]
    fn pool_output_matches_single_replica_given_seed() {
        // Replicas are share-nothing copies of the same model, so WHICH
        // worker serves a request must not change the sampled text.
        let (single, _) = mock_pool_handle(1, 1);
        let (pooled, _) = mock_pool_handle(3, 1);
        let req = |seed| InfillRequest {
            text: "xy____zw".into(),
            seed,
            ..Default::default()
        };
        for seed in [1u64, 9, 42] {
            assert_eq!(
                single.infill(req(seed)).unwrap().text,
                pooled.infill(req(seed)).unwrap().text
            );
        }
    }

    #[test]
    fn pool_serves_concurrent_load() {
        let (h, metrics) = mock_pool_handle(2, 2);
        let handles: Vec<_> = (0..16)
            .map(|i| {
                h.submit(InfillRequest {
                    text: "ab______".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rh in handles {
            let resp = rh.wait().unwrap();
            assert_eq!(resp.n_generated, 6);
        }
        assert_eq!(metrics.requests(), 16);
        assert_eq!(h.replica_stats().len(), 2);
        let by_replica: u64 = h.replica_stats().iter().map(|r| r.requests()).sum();
        assert_eq!(by_replica, 16);
    }

    #[test]
    fn all_replicas_failing_errors_instead_of_hanging() {
        let metrics = Metrics::new();
        let pool = EnginePool::from_fn(PoolConfig { replicas: 2 }, |id| {
            bail!("replica {id} down")
        });
        let h = spawn_pool(pool, SchedulerConfig::default(), metrics);
        // Regardless of whether the workers have already exited (submit
        // fails) or exit after we queue (drain-and-fail), we get an error.
        assert!(h
            .infill(InfillRequest {
                text: "ab__".into(),
                ..Default::default()
            })
            .is_err());
    }

    // --- lane allocator ---------------------------------------------------

    /// Lane reuse across admission/retire interleavings never crosses
    /// caches: a staggered stream of requests (different lengths, so
    /// lanes free and refill mid-flight) must produce, for every seed,
    /// exactly the text an isolated single-lane scheduler produces. The
    /// mock engine reads committed columns from its lane cache (not the
    /// live buffer), so a lane-crossing or skipped reset would change
    /// sampled tokens — and trips its debug asserts first.
    #[test]
    fn lane_reuse_across_churn_keeps_outputs_bit_identical() {
        let texts = |i: usize| -> String {
            // staggered target counts: 2..12 blanks
            format!("ab{}cd", "_".repeat(2 + (i * 3) % 11))
        };
        let (isolated, _) = mock_handle(1);
        let reference: Vec<String> = (0..12)
            .map(|i| {
                isolated
                    .infill(InfillRequest {
                        text: texts(i),
                        seed: 100 + i as u64,
                        ..Default::default()
                    })
                    .unwrap()
                    .text
            })
            .collect();
        let (churny, metrics) = mock_handle(3);
        let handles: Vec<_> = (0..12)
            .map(|i| {
                churny
                    .submit(InfillRequest {
                        text: texts(i),
                        seed: 100 + i as u64,
                        ..Default::default()
                    })
                    .unwrap()
            })
            .collect();
        for (i, rh) in handles.into_iter().enumerate() {
            assert_eq!(rh.wait().unwrap().text, reference[i], "request {i}");
        }
        assert_eq!(metrics.requests(), 12);
    }

    /// Retiring a lane frees it for new admissions without touching
    /// batch-mates: more requests than lanes all complete, and occupancy
    /// shows lanes were actually shared over time.
    #[test]
    fn lanes_recycle_through_more_requests_than_slots() {
        let (h, metrics) = mock_handle(2);
        let handles: Vec<_> = (0..10)
            .map(|i| {
                h.submit(InfillRequest {
                    text: "ab____".into(),
                    seed: i,
                    sampler: SamplerKind::Sequential,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rh in handles {
            assert_eq!(rh.wait().unwrap().n_generated, 4);
        }
        assert_eq!(metrics.requests(), 10);
    }

    /// Mixed batches route per slot: incremental-capable machines (ASSD,
    /// sequential) and non-incremental ones (diffusion) coexist in one
    /// scheduler batch and all complete correctly.
    #[test]
    fn mixed_incremental_and_compact_slots_batch_together() {
        let (h, metrics) = mock_handle(3);
        let reqs = [
            (SamplerKind::Assd, 1u64),
            (SamplerKind::Diffusion, 2),
            (SamplerKind::Sequential, 3),
        ];
        let handles: Vec<_> = reqs
            .iter()
            .map(|&(sampler, seed)| {
                h.submit(InfillRequest {
                    text: "ab______cd".into(),
                    sampler,
                    seed,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rh in handles {
            let resp = rh.wait().unwrap();
            assert!(!resp.text.contains('_'));
            assert_eq!(resp.n_generated, 6);
        }
        assert_eq!(metrics.requests(), 3);
    }

    // --- request lifecycle: streaming, cancellation, deadlines ----------

    /// Commit events stream DURING the decode and reassemble to exactly
    /// the terminal response: every target position exactly once, token
    /// values matching the final text's bytes.
    #[test]
    fn commit_events_reassemble_to_final_response() {
        let (h, _) = mock_handle(1);
        let rh = h
            .submit(InfillRequest {
                text: "ab________cd".into(),
                seed: 13,
                ..Default::default()
            })
            .unwrap();
        let mut commits: Vec<(usize, u32)> = vec![];
        let resp = loop {
            match rh.next_event().expect("stream ended without terminal") {
                Event::Committed { positions, tokens } => {
                    commits.extend(positions.into_iter().zip(tokens));
                }
                Event::Done(resp) => break resp,
                Event::Error(e) => panic!("unexpected error: {e}"),
            }
        };
        assert_eq!(commits.len(), 8, "each target committed exactly once");
        let mut bytes = "ab________cd".as_bytes().to_vec();
        for &(pos, tok) in &commits {
            assert!(pos >= 2 && pos < 10, "commit outside the blanked span");
            bytes[pos] = tok as u8;
        }
        assert_eq!(String::from_utf8_lossy(&bytes).into_owned(), resp.text);
    }

    /// Cancelling one request mid-batch frees its slot and leaves its
    /// batch-mate's output BIT-IDENTICAL to an undisturbed run — the
    /// per-slot RNG streams are independent, so a retirement next door is
    /// invisible (the `deterministic_given_seed` pattern, extended).
    #[test]
    fn cancel_mid_batch_leaves_batchmates_bit_identical() {
        let long_text = || format!("ab{}cd", "_".repeat(12));
        // sequential = one token per iteration: plenty of iterations for
        // the cancel to land mid-decode
        let mate = |seed| InfillRequest {
            text: long_text(),
            seed,
            sampler: SamplerKind::Sequential,
            ..Default::default()
        };
        // undisturbed reference: the batch-mate alone
        let (h_ref, _) = slow_handle(2, 16, 3);
        let reference = h_ref.infill(mate(99)).unwrap().text;

        let (h, metrics) = slow_handle(2, 16, 3);
        let victim = h
            .submit(InfillRequest {
                text: long_text(),
                seed: 7,
                sampler: SamplerKind::Sequential,
                ..Default::default()
            })
            .unwrap();
        let survivor = h.submit(mate(99)).unwrap();
        // wait until the victim demonstrably occupies a slot (first
        // commit arrived), then cancel it mid-flight
        match victim.next_event() {
            Some(Event::Committed { .. }) => {}
            other => panic!("expected a commit first, got {other:?}"),
        }
        victim.cancel();
        let err = victim.wait().unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
        assert_eq!(survivor.wait().unwrap().text, reference);
        assert_eq!(metrics.cancelled(), 1);
        assert_eq!(h.replica_stats()[0].cancelled(), 1);
    }

    /// Deadline expiry retires the slot with a partial-progress error.
    #[test]
    fn deadline_expiry_returns_partial_progress_error() {
        let (h, metrics) = slow_handle(1, 16, 10);
        let err = h
            .infill(InfillRequest {
                text: format!("ab{}cd", "_".repeat(12)),
                seed: 3,
                sampler: SamplerKind::Sequential,
                timeout_ms: Some(45),
                ..Default::default()
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("deadline exceeded"), "{err}");
        assert!(err.contains("/12 tokens"), "no partial progress: {err}");
        assert_eq!(metrics.deadline_expired(), 1);
    }

    /// A deadlined request stuck in a saturated queue (no worker ever
    /// observes it) must still release its client: the handle's own
    /// deadline backstop fires at deadline + grace instead of blocking
    /// until the queue drains.
    #[test]
    fn deadline_in_saturated_queue_unblocks_client() {
        // 12 sequential targets x 40ms/forward ≈ 480ms of slot occupancy
        let (h, _metrics) = slow_handle(1, 16, 40);
        let blocker = h
            .submit(InfillRequest {
                text: format!("ab{}cd", "_".repeat(12)),
                seed: 1,
                sampler: SamplerKind::Sequential,
                ..Default::default()
            })
            .unwrap();
        assert!(matches!(
            blocker.next_event(),
            Some(Event::Committed { .. })
        ));
        let t0 = Instant::now();
        let err = h
            .infill(InfillRequest {
                text: "ab____cd".into(),
                seed: 2,
                timeout_ms: Some(30),
                ..Default::default()
            })
            .unwrap_err()
            .to_string();
        // Released by its own backstop (30ms deadline + 250ms grace),
        // NOT by the blocker finishing (~480ms in).
        assert!(err.contains("deadline"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_millis(450),
            "client blocked {}ms past its deadline",
            t0.elapsed().as_millis()
        );
        let _ = blocker.wait();
    }

    /// Dropping the request handle (dead reply channel) cancels the slot
    /// early instead of decoding to completion.
    #[test]
    fn abandoned_handle_frees_slot_early() {
        let (h, metrics) = slow_handle(1, 16, 5);
        let rh = h
            .submit(InfillRequest {
                text: format!("ab{}cd", "_".repeat(12)),
                seed: 5,
                sampler: SamplerKind::Sequential,
                ..Default::default()
            })
            .unwrap();
        drop(rh); // caller gives up; nobody will ever read the outcome
        let deadline = Instant::now() + Duration::from_secs(10);
        while metrics.cancelled() == 0 {
            assert!(Instant::now() < deadline, "abandoned slot never retired");
            thread::sleep(Duration::from_millis(5));
        }
        // The counter alone proves early retirement (no timing assert):
        // a completed decode books a request, never a cancellation.
        assert_eq!(metrics.requests(), 0);
    }

    // --- paged KV: block-budget admission, prefix cache, eviction -------

    fn paged_handle(pool_cfg: PagedKvConfig, max_batch: usize) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        let m2 = metrics.clone();
        let h = spawn(
            move || {
                Ok(Box::new(MockEngine::with_pool(3, 16, 258, 1.0, pool_cfg)) as Box<dyn Engine>)
            },
            SchedulerConfig {
                max_batch,
                idle_poll: Duration::from_millis(5),
                ..Default::default()
            },
            m2,
        );
        (h, metrics)
    }

    /// A repeated request hits the prefix cache (its retired predecessor's
    /// sealed prompt blocks seed the new lane, skipping prefill) and the
    /// warm decode is bit-identical to the cold one. Both granularities
    /// export the hit: pool-level /metrics and per-replica /replicas.
    #[test]
    fn warm_prefix_requests_hit_cache_and_match_cold_outputs() {
        let (h, metrics) = mock_handle(1);
        let req = || InfillRequest {
            text: "ab____cd".into(),
            seed: 17,
            sampler: SamplerKind::Sequential,
            ..Default::default()
        };
        let cold = h.infill(req()).unwrap();
        let warm = h.infill(req()).unwrap();
        assert_eq!(warm.text, cold.text, "warm decode must be bit-identical");
        assert!(metrics.prefix_misses() >= 1, "cold request should miss");
        assert!(
            metrics.prefix_hits() >= 1,
            "warm request never hit the prefix cache"
        );
        let r = &h.replica_stats()[0];
        assert!(r.prefix_hits() >= 1);
        assert!(r.prefix_misses() >= 1);
    }

    /// Admission is capped by the BLOCK BUDGET, not just `max_batch`: a
    /// pool that backs 2 worst-case lanes never runs more than 2 slots
    /// concurrently even with `max_batch = 4`, yet every request still
    /// completes (lanes recycle through the budget) and the block-pool
    /// gauges surface in the replica snapshot.
    #[test]
    fn block_budget_caps_concurrency_below_max_batch() {
        let (h, metrics) = paged_handle(
            PagedKvConfig {
                block_rows: 16,
                total_blocks: 2,
            },
            4,
        );
        let handles: Vec<_> = (0..6)
            .map(|i| {
                h.submit(InfillRequest {
                    text: "ab______".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rh in handles {
            assert_eq!(rh.wait().unwrap().n_generated, 6);
        }
        assert_eq!(metrics.requests(), 6);
        let j = metrics.snapshot_json();
        let occ = j.get("mean_batch_occupancy").unwrap().as_f64().unwrap();
        assert!(occ <= 2.0, "block budget exceeded: occupancy {occ}");
        let r = h.replica_stats()[0].snapshot_json();
        assert_eq!(r.get("kv_blocks_total").unwrap().as_f64(), Some(2.0));
    }

    /// Eviction under block pressure changes WHEN prefill happens, never
    /// WHAT is sampled: rotating prompts through a pool too small to cache
    /// them all must produce, for every (text, seed), exactly the output
    /// of a roomy-pool scheduler — while demonstrably evicting (the
    /// never-evicts reference pins down the counter's meaning).
    #[test]
    fn eviction_under_pressure_never_changes_scheduler_outputs() {
        let (roomy, _) = mock_handle(1);
        // blocks_per_seq = 16/4 = 4; 6 total blocks hold one active lane
        // plus half a sealed prefix, so every prompt rotation evicts.
        let (tiny, metrics) = paged_handle(
            PagedKvConfig {
                block_rows: 4,
                total_blocks: 6,
            },
            1,
        );
        let texts = ["ab____cd", "xy______", "pq__rs__"];
        for round in 0..2u64 {
            for (i, text) in texts.iter().enumerate() {
                let req = |seed| InfillRequest {
                    text: text.to_string(),
                    seed,
                    ..Default::default()
                };
                let seed = 31 + round * 10 + i as u64;
                assert_eq!(
                    tiny.infill(req(seed)).unwrap().text,
                    roomy.infill(req(seed)).unwrap().text,
                    "round {round}, prompt {i}"
                );
            }
        }
        assert!(
            tiny.replica_stats()[0].kv_evictions() > 0,
            "pressure pool never evicted — test lost its teeth"
        );
        assert!(metrics.kv_evictions() > 0, "pool aggregate missed evictions");
    }

    /// A full admission queue sheds instead of queueing without bound.
    #[test]
    fn queue_full_sheds_with_typed_error() {
        let (h, metrics) = slow_handle(1, 1, 20);
        let in_slot = h
            .submit(InfillRequest {
                text: format!("ab{}cd", "_".repeat(12)),
                seed: 1,
                sampler: SamplerKind::Sequential,
                ..Default::default()
            })
            .unwrap();
        // wait until the first request demonstrably LEFT the queue (its
        // first commit proves it occupies the only batch slot)
        assert!(matches!(
            in_slot.next_event(),
            Some(Event::Committed { .. })
        ));
        let _queued = h
            .submit(InfillRequest {
                text: "ab____cd".into(),
                seed: 2,
                ..Default::default()
            })
            .unwrap();
        // queue_depth = 1 and the slot is busy: the third submission sheds
        match h.submit(InfillRequest {
            text: "ab____cd".into(),
            seed: 3,
            ..Default::default()
        }) {
            Err(SubmitError::QueueFull(depth)) => assert_eq!(depth, 1),
            other => panic!("expected QueueFull, got {:?}", other.err()),
        }
        assert_eq!(metrics.shed(), 1);
    }

    // --- request-level tracing -------------------------------------------

    fn traced_handle(trace: bool, trace_capacity: usize) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        let h = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(5),
                trace,
                trace_capacity,
                ..Default::default()
            },
            metrics.clone(),
        );
        (h, metrics)
    }

    /// Tracing must be a pure observer: for every machine x drafter
    /// combination, a tracing-on scheduler and a tracing-off scheduler
    /// produce bit-identical text for the same seed — and the off pool
    /// records no traces at all.
    #[test]
    fn tracing_on_vs_off_outputs_bit_identical() {
        let (on, on_metrics) = traced_handle(true, 256);
        let (off, off_metrics) = traced_handle(false, 256);
        for sampler in SamplerKind::ALL {
            for kind in DraftKind::ALL {
                let req = |seed| InfillRequest {
                    text: "ab______cd".into(),
                    sampler,
                    draft: DraftSpec::from_options(DraftOptions {
                        kind,
                        max_len: 4,
                        adaptive: true,
                    }),
                    seed,
                    ..Default::default()
                };
                assert_eq!(
                    on.infill(req(33)).unwrap().text,
                    off.infill(req(33)).unwrap().text,
                    "{} x {}",
                    sampler.name(),
                    kind.name()
                );
            }
        }
        assert!(on_metrics.traces_recorded() > 0);
        assert_eq!(
            off_metrics.traces_recorded(),
            0,
            "tracing off must record nothing"
        );
        assert!(off.trace_recent_json(10).to_string().contains("[]"));
    }

    /// Every completed request's trace covers the full lifecycle (queue
    /// wait, admission, forwards, commits), satisfies Theorem 2
    /// (`model_nfe <= tokens_committed`), matches the response's counters,
    /// and renders as Chrome trace-event JSON.
    #[test]
    fn completed_traces_cover_lifecycle_and_respect_theorem2() {
        let (h, metrics) = traced_handle(true, 256);
        for (i, sampler) in SamplerKind::ALL.into_iter().enumerate() {
            let resp = h
                .infill(InfillRequest {
                    text: "ab______cd".into(),
                    sampler,
                    seed: 40 + i as u64,
                    ..Default::default()
                })
                .unwrap();
            assert!(resp.request_id > 0, "response must carry its trace key");
            let t = h.trace(resp.request_id).expect("trace retained");
            assert!(t.completed);
            assert!(t.theorem2_ok, "{}: Theorem 2 violated", sampler.name());
            assert!(t.model_nfe <= t.tokens_committed);
            assert_eq!(t.model_nfe, resp.model_nfe);
            assert_eq!(t.tokens_committed, resp.n_generated as u64);
            for kind in [
                SpanKind::QueueWait,
                SpanKind::Admit,
                SpanKind::Forward,
                SpanKind::Commit,
            ] {
                assert!(
                    t.spans.iter().any(|s| s.kind == kind),
                    "{}: missing {} span",
                    sampler.name(),
                    kind.name()
                );
            }
            let chrome = h.trace_chrome_json(resp.request_id).unwrap();
            let parsed = Json::parse(&chrome.to_string()).unwrap();
            assert!(
                matches!(parsed.get("traceEvents"), Some(Json::Arr(_))),
                "chrome export must parse back with a traceEvents array"
            );
        }
        assert_eq!(metrics.theorem2_violations(), 0);
        let recent = h.trace_recent_json(10).to_string();
        assert!(recent.contains("\"request_id\""), "{recent}");
    }

    /// The per-replica trace ring drops oldest under churn: run more
    /// requests than the ring holds, and only the newest survive.
    #[test]
    fn trace_ring_drops_oldest_under_churn() {
        let (h, _) = traced_handle(true, 3);
        let ids: Vec<u64> = (0..8)
            .map(|i| {
                h.infill(InfillRequest {
                    text: "ab____cd".into(),
                    seed: 60 + i,
                    ..Default::default()
                })
                .unwrap()
                .request_id
            })
            .collect();
        for id in &ids[..5] {
            assert!(h.trace(*id).is_none(), "evicted trace {id} still readable");
        }
        for id in &ids[5..] {
            assert!(h.trace(*id).is_some(), "recent trace {id} evicted");
        }
        if let Json::Arr(recent) = h.trace_recent_json(10) {
            assert_eq!(recent.len(), 3);
        } else {
            panic!("trace_recent_json must be an array");
        }
    }

    /// An aborted request still publishes a trace, marked incomplete (the
    /// Theorem-2 flag is only asserted on completed requests, so a decode
    /// cancelled mid-iteration can never trip the violation counter).
    #[test]
    fn aborted_request_trace_is_not_marked_completed() {
        let (h, _) = slow_handle(1, 16, 3);
        let rh = h
            .submit(InfillRequest {
                text: format!("ab{}cd", "_".repeat(12)),
                seed: 7,
                sampler: SamplerKind::Sequential,
                ..Default::default()
            })
            .unwrap();
        let id = rh.request_id();
        match rh.next_event() {
            Some(Event::Committed { .. }) => {}
            other => panic!("expected a commit first, got {other:?}"),
        }
        rh.cancel();
        let _ = rh.wait();
        // the worker publishes the trace when it observes the cancel at
        // its next iteration boundary
        let deadline = Instant::now() + Duration::from_secs(10);
        let t = loop {
            if let Some(t) = h.trace(id) {
                break t;
            }
            assert!(Instant::now() < deadline, "aborted trace never published");
            thread::sleep(Duration::from_millis(5));
        };
        assert!(!t.completed);
        assert!(t.theorem2_ok, "incomplete traces never flag Theorem 2");
        assert!(t.tokens_committed >= 1, "partial progress folded in");
    }

    // --- fault tolerance: retries, budgets, supervision -------------------

    use crate::runtime::EngineResult;

    /// An engine that fails every forward with a TRANSIENT error; used to
    /// drive the retry ladder to exhaustion without killing the worker.
    struct BrokenEngine;

    impl Engine for BrokenEngine {
        fn seq_len(&self) -> usize {
            16
        }
        fn vocab(&self) -> usize {
            258
        }
        fn forward(
            &self,
            _batch: usize,
            _tokens: &[u32],
            _mask_h: &[f32],
            _mask_g: &[f32],
        ) -> EngineResult<Vec<f32>> {
            Err(EngineError::transient("broken by construction"))
        }
        fn forward_ord(&self, _specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
            Err(EngineError::transient("broken by construction"))
        }
        fn nfe(&self) -> u64 {
            0
        }
    }

    /// An engine that fails every forward FATALLY: the incarnation dies
    /// on first use and the supervisor takes over.
    struct FatalEngine;

    impl Engine for FatalEngine {
        fn seq_len(&self) -> usize {
            16
        }
        fn vocab(&self) -> usize {
            258
        }
        fn forward(
            &self,
            _batch: usize,
            _tokens: &[u32],
            _mask_h: &[f32],
            _mask_g: &[f32],
        ) -> EngineResult<Vec<f32>> {
            Err(EngineError::fatal("device lost (test)"))
        }
        fn forward_ord(&self, _specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
            Err(EngineError::fatal("device lost (test)"))
        }
        fn nfe(&self) -> u64 {
            0
        }
    }

    #[test]
    fn healthz_reports_serving_pool() {
        let (h, _) = mock_handle(1);
        assert!(h.healthy());
        let j = h.healthz_json();
        assert_eq!(j.get("replicas_total").and_then(|v| v.as_f64()), Some(1.0));
        let body = j.to_string();
        assert!(body.contains("ok"), "{body}");
    }

    /// THE HEADLINE PROPERTY: under injected transient faults every
    /// request completes BIT-IDENTICAL to the fault-free run, machine
    /// NFE accounting (the Theorem-2 bound) is untouched by retries, all
    /// failures are typed and counted, and no worker dies. Deterministic:
    /// the chaos schedule is a pure function of (seed, call index) and
    /// requests are serialized, so a green run can never flake.
    #[test]
    fn injected_faults_recover_bit_identical_with_typed_counters() {
        let handle_at = |rate: f64| {
            let metrics = Metrics::new();
            let h = spawn(
                move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
                SchedulerConfig {
                    max_batch: 2,
                    idle_poll: Duration::from_millis(5),
                    chaos: ChaosConfig {
                        seed: 71,
                        rate,
                        spike: Duration::from_micros(50),
                    },
                    retry_budget: 64,
                    // Supervision is covered by its own tests; here the
                    // incarnation must survive the whole soak.
                    health: HealthPolicy {
                        degrade_after: 3,
                        quarantine_after: 1_000_000,
                    },
                    ..Default::default()
                },
                metrics.clone(),
            );
            (h, metrics)
        };
        let (clean, _) = handle_at(0.0);
        let (chaos, metrics) = handle_at(0.35);
        for sampler in SamplerKind::ALL {
            for seed in [1u64, 2, 3] {
                let req = || InfillRequest {
                    text: "ab______cd".into(),
                    sampler,
                    seed,
                    ..Default::default()
                };
                let want = clean.infill(req()).unwrap();
                let got = chaos.infill(req()).unwrap();
                assert_eq!(
                    got.text,
                    want.text,
                    "{} seed {seed}: recovery must be bit-identical",
                    sampler.name()
                );
                assert_eq!(
                    got.model_nfe, want.model_nfe,
                    "machine NFE accounting must ignore failed launches"
                );
            }
        }
        let (transient, lane_corrupt, fatal) = metrics.engine_errors();
        assert!(transient + lane_corrupt > 0, "rate-0.35 chaos never injected");
        assert_eq!(fatal, 0);
        assert!(metrics.forward_retries() > 0, "no retry ever ran");
        assert_eq!(metrics.requests_failed(), 0, "a retry budget exhausted");
        assert_eq!(metrics.replica_restarts(), 0, "a worker died under chaos");
        assert_eq!(metrics.theorem2_violations(), 0);
    }

    /// Retry-budget exhaustion retires the REQUEST (typed error, counted)
    /// while the worker survives to serve — and report health for — the
    /// next request.
    #[test]
    fn retry_budget_exhaustion_fails_request_typed_and_worker_survives() {
        let metrics = Metrics::new();
        let h = spawn(
            || Ok(Box::new(BrokenEngine) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(5),
                retry_budget: 2,
                health: HealthPolicy {
                    degrade_after: 2,
                    quarantine_after: 1_000_000,
                },
                ..Default::default()
            },
            metrics.clone(),
        );
        let req = || InfillRequest {
            text: "ab____cd".into(),
            seed: 9,
            ..Default::default()
        };
        let err = format!("{:#}", h.infill(req()).unwrap_err());
        assert!(err.contains("retry budget exhausted"), "{err}");
        assert!(err.contains("transient"), "typed root lost: {err}");
        // 1 batched failure + 2 failed retries, all transient.
        assert_eq!(metrics.engine_errors(), (3, 0, 0));
        assert_eq!(metrics.forward_retries(), 2);
        assert_eq!(metrics.requests_failed(), 1);
        // The worker is still alive and keeps serving (and failing)…
        assert!(h.infill(req()).is_err());
        assert_eq!(metrics.requests_failed(), 2);
        assert_eq!(metrics.replica_restarts(), 0);
        // …and two consecutive failed batched calls surface as Degraded.
        assert_eq!(h.replica_stats()[0].state().as_str(), "degraded");
        assert!(h.healthy(), "degraded still serves");
    }

    /// Supervised restart WITH MIGRATION: a fatally dying first
    /// incarnation no longer fails its in-flight request — the slot is
    /// checkpointed, the supervisor re-provisions through the pool
    /// factory, and the SAME request resumes and completes on the second
    /// incarnation. Replica death costs latency, not requests. The
    /// failed fatal call never absorbed, so the migrated output equals a
    /// run served entirely by the healthy engine.
    #[test]
    fn fatal_engine_death_triggers_supervised_restart_and_recovery() {
        let metrics = Metrics::new();
        let built = Arc::new(AtomicUsize::new(0));
        let b2 = Arc::clone(&built);
        let pool = EnginePool::from_fn(PoolConfig { replicas: 1 }, move |_| {
            if b2.fetch_add(1, AtomicOrdering::SeqCst) == 0 {
                Ok(Box::new(FatalEngine) as Box<dyn Engine>)
            } else {
                Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>)
            }
        });
        let h = spawn_pool(
            pool,
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(5),
                ..Default::default()
            },
            metrics.clone(),
        );
        let req = || InfillRequest {
            text: "ab____cd".into(),
            seed: 4,
            ..Default::default()
        };
        // The request admitted to the dying incarnation MIGRATES and
        // completes — no error surfaces to the client.
        let resp = h.infill(req()).unwrap();
        assert!(!resp.text.contains('_'), "unfilled masks: {}", resp.text);
        assert_eq!(built.load(AtomicOrdering::SeqCst), 2);
        assert_eq!(metrics.replica_restarts(), 1);
        assert_eq!(h.replica_stats()[0].restarts(), 1);
        assert_eq!(metrics.migrations(), 1, "slot must migrate, not fail");
        assert_eq!(h.replica_stats()[0].migrations(), 1);
        assert_eq!(metrics.requests_failed(), 0, "migration must not fail requests");
        // Migration is invisible in the output: the dead incarnation
        // never absorbed a forward, so the text matches a pool that was
        // healthy from the start.
        let healthy = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(5),
                ..Default::default()
            },
            Metrics::new(),
        );
        assert_eq!(resp.text, healthy.infill(req()).unwrap().text);
        assert!(h.healthy());
    }

    /// When every replica is permanently lost, submission surfaces the
    /// typed [`SubmitError::ReplicaLost`] (not a generic shutdown, never
    /// a hang) and /healthz goes unhealthy.
    #[test]
    fn pool_death_surfaces_replica_lost() {
        let metrics = Metrics::new();
        let pool = EnginePool::from_fn(PoolConfig { replicas: 2 }, |id| {
            bail!("replica {id} down")
        });
        let h = spawn_pool(
            pool,
            SchedulerConfig {
                supervisor: SupervisorPolicy {
                    max_restarts: 0,
                    restart_backoff: Duration::from_millis(1),
                },
                ..Default::default()
            },
            metrics,
        );
        let req = || InfillRequest {
            text: "ab__".into(),
            ..Default::default()
        };
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match h.submit(req()) {
                Err(SubmitError::ReplicaLost) => break,
                // Submitted before the pool finished dying: the last
                // guard drains it with an error. ShutDown can only show
                // in the instants between the explicit close and the
                // final receiver drop — keep polling through both.
                Ok(handle) => {
                    let _ = handle.wait();
                }
                Err(SubmitError::ShutDown) => {}
                Err(SubmitError::QueueFull(_)) => {}
                Err(SubmitError::Draining) => unreachable!("nobody set the drain flag"),
            }
            assert!(Instant::now() < deadline, "never observed ReplicaLost");
            thread::sleep(Duration::from_millis(2));
        }
        assert!(!h.healthy(), "a dead pool must report unhealthy");
        let body = h.healthz_json().to_string();
        assert!(body.contains("unavailable"), "{body}");
    }

    /// A decode-machine panic is contained to its own slot: the slot is
    /// retired with a typed error, counters tick, and the lane frees —
    /// nothing unwinds past the absorb.
    #[test]
    fn machine_panic_is_contained_to_its_slot() {
        struct PanicMachine;
        impl DecodeMachine for PanicMachine {
            fn done(&self) -> bool {
                false
            }
            fn forward_request(&mut self) -> Option<crate::decode::ForwardRequest<'_>> {
                None
            }
            fn absorb(&mut self, _logits: &[f32]) {
                panic!("machine bug (test)");
            }
            fn outcome(self: Box<Self>) -> DecodeOutcome {
                unreachable!("a panicked machine is never asked for its outcome")
            }
        }
        let engine = MockEngine::new(3, 16, 258, 1.0);
        let metrics = Metrics::new();
        let stats = ReplicaStats::new(0);
        let recorder = SpanRecorder::new(8);
        let flight_rec = FlightRecorder::new(8);
        let (life, handle) = lifecycle::channel(None, 16, 1);
        let t0 = Instant::now();
        let mut lanes: Vec<Option<Slot>> = vec![Some(Slot {
            machine: Box::new(PanicMachine),
            life,
            t0,
            last_commit: t0,
            committed: 0,
            text_len: 4,
            n_targets: 2,
            trace: None,
            flight: None,
            retries: 0,
        })];
        let rows = vec![0.0f32; 258];
        absorb_contained(
            &engine, &mut lanes, 0, &rows, 0, None, 1, &metrics, &stats, &recorder, &flight_rec,
        );
        assert!(lanes[0].is_none(), "panicking slot must be retired");
        let err = format!("{:#}", handle.wait().unwrap_err());
        assert!(err.contains("panicked"), "{err}");
        assert_eq!(metrics.requests_failed(), 1);
        assert_eq!(stats.requests_failed(), 1);
    }

    // --- speculation flight recorder & time-series -----------------------

    fn flight_handle(rate: f64) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        let h = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(5),
                flight_sample_rate: rate,
                flight_capacity: 512,
                ..Default::default()
            },
            metrics.clone(),
        );
        (h, metrics)
    }

    /// The flight recorder must be a pure observer: for every sampler x
    /// drafter combination, a flight-on scheduler (sample rate 1.0) and a
    /// flight-off scheduler (rate 0) produce bit-identical text AND
    /// bit-identical NFE/speculation counters for the same seed — and the
    /// off pool retains no flight records at all.
    #[test]
    fn flight_on_vs_off_outputs_bit_identical() {
        let (on, _) = flight_handle(1.0);
        let (off, _) = flight_handle(0.0);
        let mut on_ids = vec![];
        let mut off_ids = vec![];
        for sampler in SamplerKind::ALL {
            for kind in DraftKind::ALL {
                let req = |seed| InfillRequest {
                    text: "ab______cd".into(),
                    sampler,
                    draft: DraftSpec::from_options(DraftOptions {
                        kind,
                        max_len: 4,
                        adaptive: true,
                    }),
                    seed,
                    ..Default::default()
                };
                let a = on.infill(req(33)).unwrap();
                let b = off.infill(req(33)).unwrap();
                let what = format!("{} x {}", sampler.name(), kind.name());
                assert_eq!(a.text, b.text, "{what}");
                assert_eq!(a.model_nfe, b.model_nfe, "{what}");
                assert_eq!(a.aux_nfe, b.aux_nfe, "{what}");
                assert_eq!(a.proposed, b.proposed, "{what}");
                assert_eq!(a.accepted, b.accepted, "{what}");
                assert_eq!(a.iterations, b.iterations, "{what}");
                on_ids.push(a.request_id);
                off_ids.push(b.request_id);
            }
        }
        for id in on_ids {
            assert!(
                on.flight_json(id).is_some(),
                "rate 1.0 must record every request ({id})"
            );
        }
        for id in off_ids {
            assert!(off.flight_json(id).is_none(), "rate 0 must record nothing");
        }
    }

    /// A recorded ASSD flight carries the per-window speculation anatomy:
    /// window sizes, per-position outcomes from the accept/reject
    /// taxonomy, entropies, and the adaptive-window trajectory.
    #[test]
    fn flight_record_carries_speculation_windows() {
        let (h, _) = flight_handle(1.0);
        let resp = h
            .infill(InfillRequest {
                text: "ab______cd".into(),
                sampler: SamplerKind::Assd,
                seed: 5,
                ..Default::default()
            })
            .unwrap();
        let body = h.flight_json(resp.request_id).unwrap().to_string();
        for key in [
            "\"windows\"",
            "\"window_trajectory\"",
            "\"outcome\"",
            "\"target_entropy\"",
            "\"drafter\"",
            "\"completed\":true",
        ] {
            assert!(body.contains(key), "missing {key} in {body}");
        }
        let parsed = Json::parse(&body).unwrap();
        assert!(
            matches!(parsed.get("windows"), Some(Json::Arr(a)) if !a.is_empty()),
            "{body}"
        );
    }

    /// GET /debug/vars aggregates the per-replica rings and the flight
    /// heatmap: after serving traffic it must expose a non-empty series
    /// whose token sum matches activity, plus per-drafter heatmap rows.
    #[test]
    fn debug_vars_reports_series_and_heatmap() {
        let (h, _) = flight_handle(1.0);
        for seed in 0..4 {
            h.infill(InfillRequest {
                text: "ab____cd".into(),
                sampler: SamplerKind::Assd,
                seed,
                ..Default::default()
            })
            .unwrap();
        }
        let body = h.debug_vars_json(60).to_string();
        let parsed = Json::parse(&body).unwrap();
        assert!(
            matches!(parsed.get("series"), Some(Json::Arr(a)) if !a.is_empty()),
            "{body}"
        );
        assert!(
            matches!(parsed.get("heatmap"), Some(Json::Arr(a)) if !a.is_empty()),
            "{body}"
        );
        assert!(body.contains("\"tokens\""), "{body}");
        assert!(body.contains("\"positions\""), "{body}");
        // The merged series' token total covers the 4 requests' commits.
        let total: f64 = match parsed.get("series") {
            Some(Json::Arr(rows)) => rows
                .iter()
                .filter_map(|r| match r.get("tokens") {
                    Some(Json::Num(n)) => Some(*n),
                    _ => None,
                })
                .sum(),
            _ => 0.0,
        };
        assert!(total >= 4.0, "series tokens {total} < committed tokens");
    }

    /// The Prometheus exposition includes the flight heatmap families with
    /// per-drafter labels once speculation traffic has been served.
    #[test]
    fn prometheus_exposes_flight_heatmap_families() {
        let (h, _) = flight_handle(1.0);
        h.infill(InfillRequest {
            text: "ab______cd".into(),
            sampler: SamplerKind::Assd,
            seed: 9,
            ..Default::default()
        })
        .unwrap();
        let text = h.prometheus_text();
        for family in [
            "# TYPE asarm_flight_records_total counter",
            "# TYPE asarm_flight_windows_total counter",
            "# TYPE asarm_flight_position_proposed_total counter",
            "# TYPE asarm_flight_entropy_proposed_total counter",
            "# TYPE asarm_flight_target_entropy_nats histogram",
        ] {
            assert!(text.contains(family), "missing {family}");
        }
        assert!(
            text.contains("asarm_flight_position_proposed_total{drafter="),
            "heatmap samples must carry drafter labels:\n{text}"
        );
    }
}
