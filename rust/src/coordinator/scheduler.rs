//! The scheduler: continuous (iteration-level) batching of decode state
//! machines over a single engine thread.
//!
//! The PJRT client is single-threaded, so the scheduler OWNS the engine on
//! a dedicated thread. Requests arrive over a channel; each becomes a
//! decode state machine occupying a batch slot. Every loop iteration the
//! scheduler gathers each active machine's pending forward request,
//! executes ONE batched forward, scatters the logits back, and retires
//! finished machines — so a slot frees the moment its request completes and
//! a queued request joins mid-flight (vLLM-style continuous batching).
//! Draft-phase and verify-phase sequences can share a batch: both phases
//! use the same fwd executable and differ only in their per-slot masks.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::decode::assd::{AssdMachine, DraftSource};
use crate::decode::diffusion::DiffusionMachine;
use crate::decode::sequential::SequentialMachine;
use crate::decode::{DecodeMachine, DecodeOutcome};
use crate::data::masking::lattice_sigma;
use crate::model::mask::Ordering;
use crate::runtime::Engine;
use crate::tokenizer::{ByteTokenizer, MASK};
use crate::util::rng::Rng;

use super::metrics::Metrics;
use super::request::{InfillRequest, InfillResponse, SamplerKind};

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max sequences decoded concurrently (batch slots).
    pub max_batch: usize,
    /// How long to block waiting for work when idle.
    pub idle_poll: Duration,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 4,
            idle_poll: Duration::from_millis(50),
        }
    }
}

struct Job {
    request: InfillRequest,
    reply: mpsc::Sender<Result<InfillResponse>>,
}

/// Cloneable handle for submitting requests to the scheduler thread.
#[derive(Clone)]
pub struct SchedulerHandle {
    tx: mpsc::Sender<Job>,
}

impl SchedulerHandle {
    /// Blocking round-trip: submit and await the response.
    pub fn infill(&self, request: InfillRequest) -> Result<InfillResponse> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job {
                request,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("scheduler shut down"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("scheduler dropped request"))?
    }

    /// Async submit: returns the receiver immediately (load generators).
    pub fn submit(&self, request: InfillRequest) -> Result<mpsc::Receiver<Result<InfillResponse>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job {
                request,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("scheduler shut down"))?;
        Ok(reply_rx)
    }
}

struct Slot {
    machine: Box<dyn DecodeMachine>,
    reply: mpsc::Sender<Result<InfillResponse>>,
    t0: Instant,
    text_len: usize,
    n_targets: usize,
}

/// Spawn the scheduler thread. `factory` constructs the engine ON the
/// scheduler thread (the XLA engine is not Send).
pub fn spawn<F>(factory: F, cfg: SchedulerConfig, metrics: Metrics) -> SchedulerHandle
where
    F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Job>();
    thread::Builder::new()
        .name("scheduler".into())
        .spawn(move || {
            let engine = match factory() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("scheduler: engine init failed: {e:#}");
                    // Drain and fail all jobs.
                    while let Ok(job) = rx.recv() {
                        let _ = job.reply.send(Err(anyhow!("engine init failed")));
                    }
                    return;
                }
            };
            run_loop(engine.as_ref(), rx, cfg, metrics);
        })
        .expect("spawn scheduler");
    SchedulerHandle { tx }
}

fn run_loop(engine: &dyn Engine, rx: mpsc::Receiver<Job>, cfg: SchedulerConfig, metrics: Metrics) {
    let n = engine.seq_len();
    let v = engine.vocab();
    let tok = ByteTokenizer::new();
    let mut slots: Vec<Slot> = Vec::new();
    let mut channel_open = true;

    // Reusable batch buffers.
    let max_b = cfg.max_batch;
    let mut toks_buf = vec![0u32; max_b * n];
    let mut mh_buf = vec![0f32; max_b * n * n];
    let mut mg_buf = vec![0f32; max_b * n * n];

    while channel_open || !slots.is_empty() {
        // --- admission ---
        while slots.len() < cfg.max_batch && channel_open {
            let job = if slots.is_empty() {
                match rx.recv_timeout(cfg.idle_poll) {
                    Ok(j) => j,
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        channel_open = false;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        channel_open = false;
                        break;
                    }
                }
            };
            match admit(engine, &tok, job.request) {
                Ok(AdmitResult::Slot(machine, text_len, n_targets)) => slots.push(Slot {
                    machine,
                    reply: job.reply,
                    t0: Instant::now(),
                    text_len,
                    n_targets,
                }),
                Ok(AdmitResult::Immediate(resp)) => {
                    let _ = job.reply.send(Ok(resp));
                }
                Err(e) => {
                    metrics.record_failure();
                    let _ = job.reply.send(Err(e));
                }
            }
        }
        if slots.is_empty() {
            continue;
        }

        // --- one batched forward over all active machines ---
        let b = slots.len();
        for (s, slot) in slots.iter_mut().enumerate() {
            let req = slot
                .machine
                .forward_request()
                .expect("active machine must request a forward");
            toks_buf[s * n..(s + 1) * n].copy_from_slice(req.tokens);
            mh_buf[s * n * n..(s + 1) * n * n].copy_from_slice(req.mask_h);
            mg_buf[s * n * n..(s + 1) * n * n].copy_from_slice(req.mask_g);
        }
        metrics.record_batch_iteration(b);
        let logits = match engine.forward(
            b,
            &toks_buf[..b * n],
            &mh_buf[..b * n * n],
            &mg_buf[..b * n * n],
        ) {
            Ok(l) => l,
            Err(e) => {
                // Engine failure: fail all active requests.
                for slot in slots.drain(..) {
                    metrics.record_failure();
                    let _ = slot.reply.send(Err(anyhow!("engine error: {e:#}")));
                }
                continue;
            }
        };
        for (s, slot) in slots.iter_mut().enumerate() {
            slot.machine.absorb(&logits[s * n * v..(s + 1) * n * v]);
        }

        // --- retire finished machines ---
        let mut s = 0;
        while s < slots.len() {
            if slots[s].machine.done() {
                let slot = slots.swap_remove(s);
                let latency = slot.t0.elapsed().as_secs_f64();
                let outcome = slot.machine.outcome();
                let resp = outcome_to_response(&tok, outcome, latency, slot.text_len, slot.n_targets);
                metrics.record_request(
                    latency,
                    resp.n_generated as u64,
                    resp.model_nfe,
                    resp.aux_nfe,
                    0,
                    0,
                );
                let _ = slot.reply.send(Ok(resp));
            } else {
                s += 1;
            }
        }
    }
}

enum AdmitResult {
    Slot(Box<dyn DecodeMachine>, usize, usize),
    Immediate(InfillResponse),
}

/// Turn a request into a decode machine (or an immediate response when
/// there is nothing to infill).
fn admit(engine: &dyn Engine, tok: &ByteTokenizer, req: InfillRequest) -> Result<AdmitResult> {
    let n = engine.seq_len();
    let v = engine.vocab();
    if req.text.is_empty() {
        bail!("empty text");
    }
    let bytes = req.text.as_bytes();
    if bytes.len() > n {
        bail!("text longer than model window ({} > {n})", bytes.len());
    }
    // Token buffer: visible bytes, MASK at mask_char, PAD tail (visible).
    let mask_byte = {
        let mut buf = [0u8; 4];
        let s = req.mask_char.encode_utf8(&mut buf);
        if s.len() != 1 {
            bail!("mask_char must be a single byte");
        }
        buf[0]
    };
    let mut tokens = tok.encode_fixed(&req.text, n);
    let mut visible: Vec<usize> = Vec::with_capacity(n);
    let mut n_targets = 0;
    for (i, t) in tokens.iter_mut().enumerate() {
        if i < bytes.len() && bytes[i] == mask_byte {
            *t = MASK;
            n_targets += 1;
        } else {
            visible.push(i);
        }
    }
    if n_targets == 0 {
        return Ok(AdmitResult::Immediate(InfillResponse {
            text: req.text,
            model_nfe: 0,
            aux_nfe: 0,
            iterations: 0,
            acceptance_rate: 1.0,
            latency_s: 0.0,
            n_generated: 0,
        }));
    }
    let m = visible.len();
    let ord = Ordering::new(lattice_sigma(&visible, n), m);
    let rng = Rng::new(req.seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1));
    let machine: Box<dyn DecodeMachine> = match req.sampler {
        SamplerKind::Assd => Box::new(AssdMachine::new(
            ord,
            tokens,
            v,
            req.k,
            req.temperature,
            rng,
            DraftSource::SelfModel,
        )),
        SamplerKind::AssdNgram => Box::new(AssdMachine::new(
            ord,
            tokens,
            v,
            req.k,
            req.temperature,
            rng,
            DraftSource::NGram,
        )),
        SamplerKind::Sequential => Box::new(SequentialMachine::new(
            ord,
            tokens,
            v,
            req.temperature,
            rng,
        )),
        SamplerKind::Diffusion => Box::new(DiffusionMachine::new(
            tokens,
            v,
            req.steps,
            req.temperature,
            rng,
        )),
    };
    Ok(AdmitResult::Slot(machine, bytes.len(), n_targets))
}

fn outcome_to_response(
    tok: &ByteTokenizer,
    outcome: DecodeOutcome,
    latency_s: f64,
    text_len: usize,
    n_targets: usize,
) -> InfillResponse {
    // The original text occupied the first `text_len` byte positions; the
    // rest is PAD. Truncate at the token level (byte-level truncation of
    // the decoded string could split a multi-byte char).
    let text = tok.decode(&outcome.tokens[..text_len.min(outcome.tokens.len())]);
    InfillResponse {
        text,
        model_nfe: outcome.model_nfe,
        aux_nfe: outcome.aux_nfe,
        iterations: outcome.iterations,
        acceptance_rate: outcome.acceptance_rate(),
        latency_s,
        n_generated: n_targets,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::MockEngine;

    fn mock_handle(max_batch: usize) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        let m2 = metrics.clone();
        let h = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch,
                idle_poll: Duration::from_millis(5),
            },
            m2,
        );
        (h, metrics)
    }

    #[test]
    fn serves_single_request() {
        let (h, metrics) = mock_handle(2);
        let resp = h
            .infill(InfillRequest {
                text: "ab__cd__".into(),
                seed: 7,
                ..Default::default()
            })
            .unwrap();
        // The mock engine emits arbitrary bytes, so the lossy UTF-8 decode
        // may change byte lengths; assert structure, not exact bytes.
        assert!(resp.text.starts_with("ab"), "{:?}", resp.text);
        assert!(!resp.text.contains('_'));
        assert_eq!(resp.n_generated, 4);
        assert!(resp.model_nfe >= 1 && resp.model_nfe <= 4);
        assert_eq!(metrics.requests(), 1);
    }

    #[test]
    fn no_mask_is_immediate() {
        let (h, _) = mock_handle(2);
        let resp = h
            .infill(InfillRequest {
                text: "hello".into(),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.text, "hello");
        assert_eq!(resp.model_nfe, 0);
    }

    #[test]
    fn rejects_oversized_and_empty() {
        let (h, _) = mock_handle(2);
        assert!(h
            .infill(InfillRequest {
                text: "x".repeat(100),
                ..Default::default()
            })
            .is_err());
        assert!(h
            .infill(InfillRequest {
                text: "".into(),
                ..Default::default()
            })
            .is_err());
    }

    #[test]
    fn all_samplers_complete() {
        let (h, _) = mock_handle(4);
        for sampler in [
            SamplerKind::Assd,
            SamplerKind::AssdNgram,
            SamplerKind::Sequential,
            SamplerKind::Diffusion,
        ] {
            let resp = h
                .infill(InfillRequest {
                    text: "ab____cd".into(),
                    sampler,
                    seed: 11,
                    ..Default::default()
                })
                .unwrap();
            assert!(!resp.text.contains('_'), "{}: {}", sampler.name(), resp.text);
        }
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let (h, metrics) = mock_handle(4);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                h.submit(InfillRequest {
                    text: "ab______".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.n_generated, 6);
        }
        let j = metrics.snapshot_json();
        let occ = j.get("mean_batch_occupancy").unwrap().as_f64().unwrap();
        assert!(occ > 1.0, "continuous batching never batched (occ={occ})");
    }

    #[test]
    fn deterministic_given_seed() {
        let (h, _) = mock_handle(1);
        let get = |seed| {
            h.infill(InfillRequest {
                text: "xy____zw".into(),
                seed,
                ..Default::default()
            })
            .unwrap()
            .text
        };
        assert_eq!(get(5), get(5));
    }
}
