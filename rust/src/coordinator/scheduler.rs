//! The scheduler: continuous (iteration-level) batching of decode state
//! machines over a POOL of engine worker threads.
//!
//! The PJRT client is single-threaded, so each engine is OWNED by one
//! dedicated scheduler worker (constructed on that thread via
//! [`EnginePool`]). Requests arrive on one shared MPMC admission queue
//! ([`crate::util::mpmc`]) drained by all workers: whichever worker has a
//! free batch slot first picks up the next job, so a slow or dead replica
//! never stalls admission. Within a worker the loop is unchanged vLLM-style
//! continuous batching: each request becomes a decode state machine
//! occupying a batch slot; every iteration the worker gathers each active
//! machine's pending COMPACT forward request (ordering + decode state +
//! wanted rows — no materialized masks, see docs/ARCHITECTURE.md §Compact
//! forward ABI), executes ONE batched `forward_ord` on its own replica,
//! scatters the gathered rows back, and retires finished machines — a
//! slot frees the moment its request completes and a queued request joins
//! mid-flight. Draft-phase and verify-phase ASSD sequences still share a
//! batch (both phases use the same executable and differ only in their
//! per-slot `(known, want)` state), so the paper's NFE accounting is
//! preserved per worker.
//!
//! Aggregate serving metrics ([`Metrics`]) are shared by all workers;
//! per-replica counters ([`ReplicaStats`]) are exported per worker (GET
//! /replicas). Shutdown: dropping every [`SchedulerHandle`] closes the
//! queue and workers drain their remaining slots; conversely, if every
//! worker dies (e.g. all replicas fail to provision), the LAST one out
//! closes the queue and fails any still-queued jobs so clients get an
//! error instead of a hang.

use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::data::masking::lattice_sigma;
use crate::decode::assd::AssdMachine;
use crate::decode::diffusion::DiffusionMachine;
use crate::decode::sequential::SequentialMachine;
use crate::decode::{DecodeMachine, DecodeOutcome};
use crate::draft::DraftOptions;
use crate::model::mask::Ordering;
use crate::runtime::{Engine, EnginePool, PoolConfig};
use crate::tokenizer::{ByteTokenizer, MASK};
use crate::util::json::Json;
use crate::util::mpmc;
use crate::util::rng::Rng;

use super::metrics::{Metrics, ReplicaState, ReplicaStats};
use super::request::{InfillRequest, InfillResponse, SamplerKind};

/// Per-worker batching knobs (each pool worker runs its own copy).
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max sequences decoded concurrently PER WORKER (batch slots). The
    /// pool's total in-flight capacity is `replicas * max_batch`.
    pub max_batch: usize,
    /// How long an idle worker blocks on the admission queue before
    /// re-polling (bounds shutdown latency, not throughput).
    pub idle_poll: Duration,
    /// Draft configuration applied to ASSD requests that do not carry
    /// their own `draft` field (`asarm serve --draft/--draft-max-len/
    /// --adaptive`).
    pub default_draft: DraftOptions,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 4,
            idle_poll: Duration::from_millis(50),
            default_draft: DraftOptions::default(),
        }
    }
}

struct Job {
    request: InfillRequest,
    reply: mpsc::Sender<Result<InfillResponse>>,
}

/// Cloneable handle for submitting requests to the worker pool.
#[derive(Clone)]
pub struct SchedulerHandle {
    tx: mpmc::Sender<Job>,
    replicas: Arc<Vec<ReplicaStats>>,
}

impl SchedulerHandle {
    /// Blocking round-trip: submit and await the response.
    pub fn infill(&self, request: InfillRequest) -> Result<InfillResponse> {
        let rx = self.submit(request)?;
        rx.recv()
            .map_err(|_| anyhow!("scheduler dropped request"))?
    }

    /// Async submit: returns the receiver immediately (load generators).
    pub fn submit(&self, request: InfillRequest) -> Result<mpsc::Receiver<Result<InfillResponse>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job {
                request,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("scheduler shut down"))?;
        Ok(reply_rx)
    }

    /// Per-replica serving counters, indexed by replica id.
    pub fn replica_stats(&self) -> &[ReplicaStats] {
        &self.replicas
    }

    /// JSON array of per-replica snapshots (the GET /replicas payload).
    pub fn replicas_json(&self) -> Json {
        Json::Arr(self.replicas.iter().map(|r| r.snapshot_json()).collect())
    }
}

struct Slot {
    machine: Box<dyn DecodeMachine>,
    reply: mpsc::Sender<Result<InfillResponse>>,
    t0: Instant,
    text_len: usize,
    n_targets: usize,
}

/// Spawn a single-replica scheduler. `factory` constructs the engine ON
/// the worker thread (the XLA engine is not Send). Kept as the simple API
/// for tests and one-shot CLI use; [`spawn_pool`] is the general form.
pub fn spawn<F>(factory: F, cfg: SchedulerConfig, metrics: Metrics) -> SchedulerHandle
where
    F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
{
    let cell = Mutex::new(Some(factory));
    spawn_pool(
        EnginePool::from_fn(PoolConfig { replicas: 1 }, move |_| {
            let f = cell
                .lock()
                .unwrap()
                .take()
                .expect("single-replica factory invoked twice");
            f()
        }),
        cfg,
        metrics,
    )
}

/// Spawn one scheduler worker per pool replica, all draining one shared
/// admission queue. Each worker provisions its engine on its own thread
/// and runs the continuous-batching loop against that replica alone.
pub fn spawn_pool(pool: EnginePool, cfg: SchedulerConfig, metrics: Metrics) -> SchedulerHandle {
    let n_workers = pool.replicas();
    let (tx, rx) = mpmc::channel::<Job>();
    let replicas: Arc<Vec<ReplicaStats>> =
        Arc::new((0..n_workers).map(ReplicaStats::new).collect());
    let live = Arc::new(AtomicUsize::new(n_workers));
    let pool = Arc::new(pool);
    for id in 0..n_workers {
        let rx = rx.clone();
        let metrics = metrics.clone();
        let replicas = Arc::clone(&replicas);
        let live = Arc::clone(&live);
        let pool = Arc::clone(&pool);
        thread::Builder::new()
            .name(format!("scheduler-{id}"))
            .spawn(move || {
                // The guard must cover panics too (a panicking worker that
                // skipped the last-one-out bookkeeping would leave queued
                // clients blocked forever), hence Drop rather than a
                // trailing call.
                let _exit = WorkerExitGuard {
                    live,
                    rx: rx.clone(),
                };
                let stats = &replicas[id];
                match pool.provision(id) {
                    Ok(engine) => {
                        stats.set_state(ReplicaState::Running);
                        run_worker(engine.as_ref(), &rx, cfg, &metrics, stats);
                        stats.set_state(ReplicaState::Stopped);
                    }
                    Err(e) => {
                        eprintln!("scheduler-{id}: engine init failed: {e:#}");
                        stats.set_state(ReplicaState::Failed);
                    }
                }
            })
            .expect("spawn scheduler worker");
    }
    SchedulerHandle { tx, replicas }
}

/// Last-worker-out bookkeeping, panic-safe via Drop: when the final worker
/// exits (cleanly or by unwinding), close the admission queue and fail
/// whatever is still queued — otherwise those clients would block forever
/// on replies that can never come.
struct WorkerExitGuard {
    live: Arc<AtomicUsize>,
    rx: mpmc::Receiver<Job>,
}

impl Drop for WorkerExitGuard {
    fn drop(&mut self) {
        if self.live.fetch_sub(1, AtomicOrdering::AcqRel) == 1 {
            self.rx.close();
            while let Ok(job) = self.rx.try_recv() {
                let _ = job.reply.send(Err(anyhow!("engine pool shut down")));
            }
        }
    }
}

/// One worker's continuous-batching loop over its private engine replica.
fn run_worker(
    engine: &dyn Engine,
    rx: &mpmc::Receiver<Job>,
    cfg: SchedulerConfig,
    metrics: &Metrics,
    stats: &ReplicaStats,
) {
    let tok = ByteTokenizer::new();
    let mut slots: Vec<Slot> = Vec::new();
    let mut queue_open = true;

    while queue_open || !slots.is_empty() {
        // --- admission: top up free slots from the shared queue ---
        while slots.len() < cfg.max_batch && queue_open {
            let job = if slots.is_empty() {
                match rx.recv_timeout(cfg.idle_poll) {
                    Ok(j) => j,
                    Err(mpmc::RecvTimeoutError::Timeout) => break,
                    Err(mpmc::RecvTimeoutError::Disconnected) => {
                        queue_open = false;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(mpmc::TryRecvError::Empty) => break,
                    Err(mpmc::TryRecvError::Disconnected) => {
                        queue_open = false;
                        break;
                    }
                }
            };
            match admit(engine, &tok, job.request, cfg.default_draft) {
                Ok(AdmitResult::Slot(machine, text_len, n_targets)) => slots.push(Slot {
                    machine,
                    reply: job.reply,
                    t0: Instant::now(),
                    text_len,
                    n_targets,
                }),
                Ok(AdmitResult::Immediate(resp)) => {
                    let _ = job.reply.send(Ok(resp));
                }
                Err(e) => {
                    metrics.record_failure();
                    stats.record_failure();
                    let _ = job.reply.send(Err(e));
                }
            }
        }
        if slots.is_empty() {
            continue;
        }

        // --- one batched COMPACT forward over all active machines ---
        // Each machine's request borrows its own state (tokens, ordering,
        // wanted rows); no per-slot mask or token buffers are copied —
        // the engine's compact path packs the O(B·N) index vectors into
        // its own reusable scratch, and O(B·N²) mask traffic is gone
        // entirely (docs/ARCHITECTURE.md §Compact forward ABI).
        let b = slots.len();
        metrics.record_batch_iteration(b);
        stats.record_batch_iteration(b);
        let result = {
            let specs: Vec<crate::runtime::ForwardSpec<'_>> = slots
                .iter_mut()
                .map(|slot| {
                    slot.machine
                        .forward_request()
                        .expect("active machine must request a forward")
                })
                .collect();
            engine.forward_ord(&specs)
        };
        let rows = match result {
            Ok(r) => r,
            Err(e) => {
                // Engine failure: fail this worker's active requests; the
                // queue (and other replicas) keep serving.
                for slot in slots.drain(..) {
                    metrics.record_failure();
                    stats.record_failure();
                    let _ = slot.reply.send(Err(anyhow!("engine error: {e:#}")));
                }
                continue;
            }
        };
        debug_assert_eq!(rows.len(), b);
        for (slot, seq_rows) in slots.iter_mut().zip(&rows) {
            slot.machine.absorb(seq_rows);
        }

        // --- retire finished machines ---
        let mut s = 0;
        while s < slots.len() {
            if slots[s].machine.done() {
                let slot = slots.swap_remove(s);
                let latency = slot.t0.elapsed().as_secs_f64();
                let outcome = slot.machine.outcome();
                let resp =
                    outcome_to_response(&tok, outcome, latency, slot.text_len, slot.n_targets);
                metrics.record_request(
                    latency,
                    resp.n_generated as u64,
                    resp.model_nfe,
                    resp.aux_nfe,
                    resp.proposed,
                    resp.accepted,
                );
                stats.record_request(
                    resp.n_generated as u64,
                    resp.model_nfe,
                    resp.proposed,
                    resp.accepted,
                );
                let _ = slot.reply.send(Ok(resp));
            } else {
                s += 1;
            }
        }
    }
}

enum AdmitResult {
    Slot(Box<dyn DecodeMachine>, usize, usize),
    Immediate(InfillResponse),
}

/// Turn a request into a decode machine (or an immediate response when
/// there is nothing to infill).
fn admit(
    engine: &dyn Engine,
    tok: &ByteTokenizer,
    req: InfillRequest,
    default_draft: DraftOptions,
) -> Result<AdmitResult> {
    let n = engine.seq_len();
    let v = engine.vocab();
    if req.text.is_empty() {
        bail!("empty text");
    }
    let bytes = req.text.as_bytes();
    if bytes.len() > n {
        bail!("text longer than model window ({} > {n})", bytes.len());
    }
    // Token buffer: visible bytes, MASK at mask_char, PAD tail (visible).
    let mask_byte = {
        let mut buf = [0u8; 4];
        let s = req.mask_char.encode_utf8(&mut buf);
        if s.len() != 1 {
            bail!("mask_char must be a single byte");
        }
        buf[0]
    };
    let mut tokens = tok.encode_fixed(&req.text, n);
    let mut visible: Vec<usize> = Vec::with_capacity(n);
    let mut n_targets = 0;
    for (i, t) in tokens.iter_mut().enumerate() {
        if i < bytes.len() && bytes[i] == mask_byte {
            *t = MASK;
            n_targets += 1;
        } else {
            visible.push(i);
        }
    }
    if n_targets == 0 {
        return Ok(AdmitResult::Immediate(InfillResponse {
            text: req.text,
            model_nfe: 0,
            aux_nfe: 0,
            iterations: 0,
            proposed: 0,
            accepted: 0,
            acceptance_rate: 0.0,
            draft_kind: String::new(),
            draft_len: 0,
            latency_s: 0.0,
            n_generated: 0,
        }));
    }
    let m = visible.len();
    let ord = Ordering::new(lattice_sigma(&visible, n), m);
    let rng = Rng::new(req.seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1));
    let machine: Box<dyn DecodeMachine> = match req.sampler {
        SamplerKind::Assd | SamplerKind::AssdNgram => {
            let opts = req.sampler.effective_draft(req.draft.resolve(default_draft));
            // Window cap: the artifact sequence length AND the compact
            // path's row-gather width, so speculation never forces the
            // engine off its fwd_ord artifacts mid-request.
            let cap = n.min(engine.max_gather_rows());
            Box::new(AssdMachine::from_options(
                ord,
                tokens,
                v,
                opts,
                cap,
                req.temperature,
                rng,
            ))
        }
        SamplerKind::Sequential => Box::new(SequentialMachine::new(
            ord,
            tokens,
            v,
            req.temperature,
            rng,
        )),
        SamplerKind::Diffusion => Box::new(DiffusionMachine::new(
            tokens,
            v,
            req.steps,
            req.temperature,
            rng,
        )),
    };
    Ok(AdmitResult::Slot(machine, bytes.len(), n_targets))
}

fn outcome_to_response(
    tok: &ByteTokenizer,
    outcome: DecodeOutcome,
    latency_s: f64,
    text_len: usize,
    n_targets: usize,
) -> InfillResponse {
    // The original text occupied the first `text_len` byte positions; the
    // rest is PAD. Truncate at the token level (byte-level truncation of
    // the decoded string could split a multi-byte char).
    let text = tok.decode(&outcome.tokens[..text_len.min(outcome.tokens.len())]);
    InfillResponse {
        text,
        model_nfe: outcome.model_nfe,
        aux_nfe: outcome.aux_nfe,
        iterations: outcome.iterations,
        proposed: outcome.proposed,
        accepted: outcome.accepted,
        acceptance_rate: outcome.acceptance_rate(),
        draft_kind: outcome.draft_kind,
        draft_len: outcome.final_draft_len,
        latency_s,
        n_generated: n_targets,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DraftSpec;
    use crate::draft::DraftKind;
    use crate::runtime::mock::MockEngine;

    fn mock_handle(max_batch: usize) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        let m2 = metrics.clone();
        let h = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch,
                idle_poll: Duration::from_millis(5),
                ..Default::default()
            },
            m2,
        );
        (h, metrics)
    }

    fn mock_pool_handle(replicas: usize, max_batch: usize) -> (SchedulerHandle, Metrics) {
        let metrics = Metrics::new();
        // Every replica gets the SAME seed: replicas are share-nothing
        // copies of one model, so outputs must not depend on which worker
        // serves a request.
        let pool = EnginePool::from_fn(PoolConfig { replicas }, |_id| {
            Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>)
        });
        let h = spawn_pool(
            pool,
            SchedulerConfig {
                max_batch,
                idle_poll: Duration::from_millis(5),
                ..Default::default()
            },
            metrics.clone(),
        );
        (h, metrics)
    }

    #[test]
    fn serves_single_request() {
        let (h, metrics) = mock_handle(2);
        let resp = h
            .infill(InfillRequest {
                text: "ab__cd__".into(),
                seed: 7,
                ..Default::default()
            })
            .unwrap();
        // The mock engine emits arbitrary bytes, so the lossy UTF-8 decode
        // may change byte lengths; assert structure, not exact bytes.
        assert!(resp.text.starts_with("ab"), "{:?}", resp.text);
        assert!(!resp.text.contains('_'));
        assert_eq!(resp.n_generated, 4);
        assert!(resp.model_nfe >= 1 && resp.model_nfe <= 4);
        assert_eq!(metrics.requests(), 1);
    }

    #[test]
    fn no_mask_is_immediate() {
        let (h, _) = mock_handle(2);
        let resp = h
            .infill(InfillRequest {
                text: "hello".into(),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.text, "hello");
        assert_eq!(resp.model_nfe, 0);
    }

    #[test]
    fn rejects_oversized_and_empty() {
        let (h, _) = mock_handle(2);
        assert!(h
            .infill(InfillRequest {
                text: "x".repeat(100),
                ..Default::default()
            })
            .is_err());
        assert!(h
            .infill(InfillRequest {
                text: "".into(),
                ..Default::default()
            })
            .is_err());
    }

    #[test]
    fn all_samplers_complete() {
        let (h, _) = mock_handle(4);
        for sampler in SamplerKind::ALL {
            let resp = h
                .infill(InfillRequest {
                    text: "ab____cd".into(),
                    sampler,
                    seed: 11,
                    ..Default::default()
                })
                .unwrap();
            assert!(!resp.text.contains('_'), "{}: {}", sampler.name(), resp.text);
        }
    }

    /// Every drafter kind (fixed and adaptive) serves requests end to end,
    /// reports its identity and telemetry in the response, and feeds the
    /// aggregate speculation counters.
    #[test]
    fn all_drafters_serve_with_telemetry() {
        let (h, metrics) = mock_handle(2);
        for kind in DraftKind::ALL {
            for adaptive in [false, true] {
                let resp = h
                    .infill(InfillRequest {
                        text: "ab______cd".into(),
                        draft: DraftSpec::from_options(DraftOptions {
                            kind,
                            max_len: 4,
                            adaptive,
                        }),
                        seed: 21,
                        ..Default::default()
                    })
                    .unwrap();
                assert!(!resp.text.contains('_'), "{}: {}", kind.name(), resp.text);
                assert_eq!(resp.draft_kind, kind.name());
                assert!(resp.proposed > 0, "{}: no speculation", kind.name());
                assert!(resp.accepted <= resp.proposed);
                assert!(resp.draft_len >= 1);
                if kind == DraftKind::SelfModel {
                    assert!(resp.model_nfe <= 8, "Theorem 1: {}", resp.model_nfe);
                } else {
                    assert!(resp.aux_nfe > 0, "external drafter books aux NFE");
                }
            }
        }
        let j = metrics.snapshot_json();
        assert!(j.get("proposed").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("acceptance_rate").unwrap().as_f64().unwrap() > 0.0);
    }

    /// The scheduler's default draft config applies when a request carries
    /// no draft field (and per-request draft fields override it).
    #[test]
    fn default_draft_config_applies() {
        let metrics = Metrics::new();
        let h = spawn(
            move || Ok(Box::new(MockEngine::new(3, 16, 258, 1.0)) as Box<dyn Engine>),
            SchedulerConfig {
                max_batch: 2,
                idle_poll: Duration::from_millis(5),
                default_draft: DraftOptions {
                    kind: DraftKind::Lookup,
                    max_len: 3,
                    adaptive: false,
                },
            },
            metrics,
        );
        let resp = h
            .infill(InfillRequest {
                text: "ab____cd".into(),
                seed: 5,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.draft_kind, "lookup");
        let resp = h
            .infill(InfillRequest {
                text: "ab____cd".into(),
                draft: DraftSpec::from_options(DraftOptions::default()),
                seed: 5,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.draft_kind, "self", "per-request draft overrides default");
        // partial spec: only the specified field overrides, the rest
        // (kind = lookup) still inherits the pool default
        let resp = h
            .infill(InfillRequest {
                text: "ab____cd".into(),
                draft: DraftSpec {
                    max_len: Some(2),
                    ..Default::default()
                },
                seed: 5,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.draft_kind, "lookup", "partial spec must inherit kind");
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let (h, metrics) = mock_handle(4);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                h.submit(InfillRequest {
                    text: "ab______".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.n_generated, 6);
        }
        let j = metrics.snapshot_json();
        let occ = j.get("mean_batch_occupancy").unwrap().as_f64().unwrap();
        assert!(occ > 1.0, "continuous batching never batched (occ={occ})");
    }

    #[test]
    fn deterministic_given_seed() {
        let (h, _) = mock_handle(1);
        let get = |seed| {
            h.infill(InfillRequest {
                text: "xy____zw".into(),
                seed,
                ..Default::default()
            })
            .unwrap()
            .text
        };
        assert_eq!(get(5), get(5));
    }

    #[test]
    fn pool_output_matches_single_replica_given_seed() {
        // Replicas are share-nothing copies of the same model, so WHICH
        // worker serves a request must not change the sampled text.
        let (single, _) = mock_pool_handle(1, 1);
        let (pooled, _) = mock_pool_handle(3, 1);
        let req = |seed| InfillRequest {
            text: "xy____zw".into(),
            seed,
            ..Default::default()
        };
        for seed in [1u64, 9, 42] {
            assert_eq!(
                single.infill(req(seed)).unwrap().text,
                pooled.infill(req(seed)).unwrap().text
            );
        }
    }

    #[test]
    fn pool_serves_concurrent_load() {
        let (h, metrics) = mock_pool_handle(2, 2);
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                h.submit(InfillRequest {
                    text: "ab______".into(),
                    seed: i,
                    ..Default::default()
                })
                .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.n_generated, 6);
        }
        assert_eq!(metrics.requests(), 16);
        assert_eq!(h.replica_stats().len(), 2);
        let by_replica: u64 = h.replica_stats().iter().map(|r| r.requests()).sum();
        assert_eq!(by_replica, 16);
    }

    #[test]
    fn all_replicas_failing_errors_instead_of_hanging() {
        let metrics = Metrics::new();
        let pool = EnginePool::from_fn(PoolConfig { replicas: 2 }, |id| {
            bail!("replica {id} down")
        });
        let h = spawn_pool(pool, SchedulerConfig::default(), metrics);
        // Regardless of whether the workers have already exited (send
        // fails) or exit after we queue (drain-and-fail), we get an error.
        assert!(h
            .infill(InfillRequest {
                text: "ab__".into(),
                ..Default::default()
            })
            .is_err());
    }
}
