//! Layer-3 coordinator: the serving system around the AS-ARM.
//!
//! * [`scheduler`] — engine-pool front: one shared BOUNDED MPMC admission
//!   queue (load-shedding) drained by N continuous-batching workers, each
//!   owning one replica
//! * [`lifecycle`] — per-request event channel (streamed token commits),
//!   cancellation tokens, and deadlines
//! * [`request`] — the infill protocol (JSON codec)
//! * [`http`] — HTTP/1.1 front end over the threadpool substrate,
//!   including the SSE streaming surface (`POST /infill/stream`)
//! * [`metrics`] — aggregate counters/latency/TTFT/ITL/acceptance (GET
//!   /metrics, JSON or Prometheus text via `Accept: text/plain`) and
//!   per-replica stats (GET /replicas); per-request span traces live in
//!   [`crate::obs`] and surface at GET /trace/{id} and /trace/recent
//!
//! Request lifecycle (full diagram in docs/ARCHITECTURE.md §Request
//! lifecycle & streaming): HTTP connection -> JSON decode -> bounded
//! admission queue (429 when full) -> first free scheduler worker ->
//! decode state machine batched on that worker's engine -> per-iteration
//! `Committed` events plus one terminal `Done`/`Error` over the
//! per-request event channel -> blocking JSON response or SSE stream.

pub mod http;
pub mod lifecycle;
pub mod metrics;
pub mod request;
pub mod scheduler;

use std::path::Path;

use crate::runtime::{EnginePool, PagedKvConfig, PoolConfig};

pub use lifecycle::{Abort, CancelToken, Event, RequestHandle, TextAssembler};
pub use metrics::{Metrics, ReplicaState, ReplicaStats};
pub use request::{DraftSpec, InfillRequest, InfillResponse, SamplerKind};
pub use scheduler::{SchedulerConfig, SchedulerHandle, SubmitError};

/// Convenience: spawn a scheduler pool backed by real XLA engines, each
/// replica independently loading `artifacts_dir` (and optional checkpoint).
pub fn start_xla(
    artifacts_dir: impl AsRef<Path>,
    params_path: Option<std::path::PathBuf>,
    pool: PoolConfig,
    cfg: SchedulerConfig,
    metrics: Metrics,
) -> SchedulerHandle {
    start_xla_with(artifacts_dir, params_path, pool, cfg, metrics, None)
}

/// [`start_xla`] with explicit per-replica K/V block-pool sizing (the
/// `--block-size` / `--cache-blocks` serving flags); `None` uses the
/// engine's per-seq-len defaults.
pub fn start_xla_with(
    artifacts_dir: impl AsRef<Path>,
    params_path: Option<std::path::PathBuf>,
    pool: PoolConfig,
    cfg: SchedulerConfig,
    metrics: Metrics,
    kv_cfg: Option<PagedKvConfig>,
) -> SchedulerHandle {
    let dir = artifacts_dir.as_ref().to_path_buf();
    scheduler::spawn_pool(EnginePool::xla_with(pool, dir, params_path, kv_cfg), cfg, metrics)
}
