//! Layer-3 coordinator: the serving system around the AS-ARM.
//!
//! * [`scheduler`] — continuous-batching decode loop owning the engine
//! * [`request`] — the infill protocol (JSON codec)
//! * [`http`] — HTTP/1.1 front end over the threadpool substrate
//! * [`metrics`] — counters/latency/acceptance, exported at /metrics

pub mod http;
pub mod metrics;
pub mod request;
pub mod scheduler;

use std::path::Path;

use crate::runtime::{Engine, XlaEngine};

pub use metrics::Metrics;
pub use request::{InfillRequest, InfillResponse, SamplerKind};
pub use scheduler::{SchedulerConfig, SchedulerHandle};

/// Convenience: spawn a scheduler backed by the real XLA engine loading
/// `artifacts_dir` (and optional checkpoint).
pub fn start_xla(
    artifacts_dir: impl AsRef<Path>,
    params_path: Option<std::path::PathBuf>,
    cfg: SchedulerConfig,
    metrics: Metrics,
) -> SchedulerHandle {
    let dir = artifacts_dir.as_ref().to_path_buf();
    scheduler::spawn(
        move || {
            let e = XlaEngine::load(&dir, params_path.as_deref())?;
            Ok(Box::new(e) as Box<dyn Engine>)
        },
        cfg,
        metrics,
    )
}
