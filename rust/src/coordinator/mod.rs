//! Layer-3 coordinator: the serving system around the AS-ARM.
//!
//! * [`scheduler`] — engine-pool front: one shared MPMC admission queue
//!   drained by N continuous-batching workers, each owning one replica
//! * [`request`] — the infill protocol (JSON codec)
//! * [`http`] — HTTP/1.1 front end over the threadpool substrate
//! * [`metrics`] — aggregate counters/latency/acceptance (GET /metrics)
//!   and per-replica stats (GET /replicas)
//!
//! Request lifecycle (full diagram in docs/ARCHITECTURE.md): HTTP
//! connection -> JSON decode -> admission queue -> first free scheduler
//! worker -> decode state machine batched on that worker's engine ->
//! response back over the per-request reply channel.

pub mod http;
pub mod metrics;
pub mod request;
pub mod scheduler;

use std::path::Path;

use crate::runtime::{EnginePool, PoolConfig};

pub use metrics::{Metrics, ReplicaState, ReplicaStats};
pub use request::{DraftSpec, InfillRequest, InfillResponse, SamplerKind};
pub use scheduler::{SchedulerConfig, SchedulerHandle};

/// Convenience: spawn a scheduler pool backed by real XLA engines, each
/// replica independently loading `artifacts_dir` (and optional checkpoint).
pub fn start_xla(
    artifacts_dir: impl AsRef<Path>,
    params_path: Option<std::path::PathBuf>,
    pool: PoolConfig,
    cfg: SchedulerConfig,
    metrics: Metrics,
) -> SchedulerHandle {
    let dir = artifacts_dir.as_ref().to_path_buf();
    scheduler::spawn_pool(EnginePool::xla(pool, dir, params_path), cfg, metrics)
}
