//! Request lifecycle: the event channel, cancellation, and deadlines
//! around one admitted request (docs/ARCHITECTURE.md §Request lifecycle &
//! streaming).
//!
//! Every request submitted to the scheduler gets a paired
//! ([`LifecycleEmitter`], [`RequestHandle`]):
//!
//! * the EMITTER travels with the job into the scheduler worker and
//!   streams [`Event`]s — `Committed` chunks the moment the decode
//!   machine accepts tokens (for ASSD that is exactly the accepted prefix
//!   of each speculation window, so chunk sizes visualize Theorem 2 in
//!   action), then one terminal `Done`/`Error`;
//! * the HANDLE stays with the submitter: it reads events (SSE surface,
//!   progress UIs) or just [`RequestHandle::wait`]s for the terminal
//!   event (the blocking `POST /v1/infill` path), and can cancel the
//!   request at any time.
//!
//! The event channel is BOUNDED ([`crate::util::mpmc::bounded`]); the
//! backpressure policy is cancel-on-lag: a client that cannot drain its
//! events as fast as the worker commits tokens gets its request
//! cancelled rather than stalling the worker's whole batch (the emitter
//! never blocks). Dropping the handle closes the channel, which the
//! worker detects at its per-iteration retire check and treats the same
//! way — an abandoned request stops consuming a batch slot within one
//! iteration.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::util::mpmc;

use super::request::InfillResponse;

/// Cooperative cancellation flag shared by everyone holding a clone.
/// Flipped by the client ([`RequestHandle::cancel`]), by the HTTP layer
/// on client disconnect, and by the emitter itself on a lagging event
/// channel; observed by the scheduler worker between batch iterations.
#[derive(Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// One request's streamed lifecycle events, in emission order: any number
/// of `Committed` chunks followed by exactly one terminal `Done`/`Error`.
#[derive(Clone, Debug)]
pub enum Event {
    /// Tokens the decode machine accepted this iteration. `positions[i]`
    /// is the sequence position of `tokens[i]`; infilling commits out of
    /// order, so positions are not necessarily contiguous or ascending
    /// across events (use [`TextAssembler`] to rebuild the text view).
    Committed {
        positions: Vec<usize>,
        tokens: Vec<u32>,
    },
    /// Terminal: the full response the blocking path would have returned.
    Done(InfillResponse),
    /// Terminal: admission/engine failure, cancellation, or deadline
    /// expiry (the message carries the partial progress).
    Error(String),
}

/// Why the scheduler retired a slot before completion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Abort {
    /// Cancel token flipped (client cancel, disconnect, or lagging event
    /// channel).
    Cancelled,
    /// The request's deadline passed.
    DeadlineExpired,
    /// Every [`RequestHandle`] clone was dropped — nobody can read the
    /// outcome, so decoding further is pure waste.
    Abandoned,
}

/// Build the paired emitter/handle for one request. `timeout` starts now
/// (queue wait counts toward the deadline); `event_capacity` bounds the
/// event channel (cancel-on-lag backpressure); `request_id` is the
/// pool-unique id the scheduler assigned at submission (keys the
/// request's trace — both halves expose it so the SSE surface can
/// advertise it before the first commit).
pub fn channel(
    timeout: Option<Duration>,
    event_capacity: usize,
    request_id: u64,
) -> (LifecycleEmitter, RequestHandle) {
    let commit_capacity = event_capacity.max(1);
    // One extra physical slot, never used by commits: the terminal
    // Done/Error event must always have room, so a decode that filled
    // the commit budget still reports its outcome instead of surfacing
    // as a dropped request.
    let (tx, rx) = mpmc::bounded(commit_capacity + 1);
    let cancel = CancelToken::new();
    let now = Instant::now();
    let deadline = timeout.map(|t| now + t);
    (
        LifecycleEmitter {
            events: tx,
            cancel: cancel.clone(),
            deadline,
            submitted: now,
            commit_capacity,
            request_id,
        },
        RequestHandle {
            events: rx,
            cancel,
            deadline,
            request_id,
        },
    )
}

/// How far past its deadline a CLIENT waits before giving up on its own.
/// The worker's per-iteration check is the normal path (it knows the
/// partial progress); this grace keeps the client-side backstop from
/// racing it, while still bounding the wait when no worker ever observes
/// the request (e.g. it expires deep in a saturated admission queue).
const CLIENT_DEADLINE_GRACE: Duration = Duration::from_millis(250);

/// The scheduler-side half: carried in the job, then in the batch slot.
pub struct LifecycleEmitter {
    events: mpmc::Sender<Event>,
    cancel: CancelToken,
    deadline: Option<Instant>,
    /// When the request entered the system (queue wait included): the
    /// zero point for TTFT and the response's latency_s.
    submitted: Instant,
    /// Commit budget — one less than the physical channel capacity (the
    /// reserved terminal slot).
    commit_capacity: usize,
    /// Pool-unique id assigned at submission (trace key).
    request_id: u64,
}

impl LifecycleEmitter {
    /// The reason this request should be retired early, if any. Checked
    /// by the worker between iterations (and at admission, so a request
    /// that died in the queue never occupies a slot). The deadline is
    /// checked FIRST: the client-side backstop flips the cancel token
    /// when it gives up on an expired request, and that must still be
    /// booked as a deadline expiry, not a cancellation.
    pub fn abort_reason(&self) -> Option<Abort> {
        if self.deadline.is_some_and(|d| Instant::now() >= d) {
            return Some(Abort::DeadlineExpired);
        }
        if self.cancel.is_cancelled() {
            return Some(Abort::Cancelled);
        }
        if self.events.is_closed() {
            return Some(Abort::Abandoned);
        }
        None
    }

    /// True when the event stream can no longer faithfully reach the
    /// client: cancelled (possibly cancel-on-lag, i.e. a chunk was
    /// already dropped) or every handle gone. Unlike
    /// [`LifecycleEmitter::abort_reason`] this ignores the deadline —
    /// the retire path uses it so `Done` is never sent over a broken
    /// stream even when a deadline happens to have expired too.
    pub fn stream_broken(&self) -> Option<Abort> {
        if self.cancel.is_cancelled() {
            return Some(Abort::Cancelled);
        }
        if self.events.is_closed() {
            return Some(Abort::Abandoned);
        }
        None
    }

    /// Stream a committed chunk. Never blocks: a channel at the commit
    /// budget means the client is not keeping up, a closed one that it
    /// is gone — either way the cancel token flips and `false` comes
    /// back so the worker retires the slot at its next check. The
    /// reserved terminal slot is never consumed here (we are the sole
    /// sender, so the length check cannot race upward).
    pub fn commit(&self, positions: Vec<usize>, tokens: Vec<u32>) -> bool {
        debug_assert_eq!(positions.len(), tokens.len());
        if self.events.len() >= self.commit_capacity {
            self.cancel.cancel();
            return false;
        }
        match self.events.try_send(Event::Committed { positions, tokens }) {
            Ok(()) => true,
            Err(_) => {
                self.cancel.cancel();
                false
            }
        }
    }

    /// Terminal event. Consumes the emitter so nothing can be streamed
    /// after `Done`/`Error`. The reserved channel slot guarantees room,
    /// so this only fails when every handle is gone (a vanished client
    /// cannot read it anyway).
    pub fn finish(self, result: Result<InfillResponse>) {
        let event = match result {
            Ok(resp) => Event::Done(resp),
            Err(e) => Event::Error(format!("{e:#}")),
        };
        let _ = self.events.try_send(event);
    }

    /// When the request was submitted — the zero point for TTFT and
    /// response latency, so queue wait counts toward both (matching the
    /// deadline clock).
    pub fn submitted_at(&self) -> Instant {
        self.submitted
    }

    /// The shared cancel token (HTTP disconnect detection clones this).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Pool-unique id assigned at submission (trace key).
    pub fn request_id(&self) -> u64 {
        self.request_id
    }
}

/// The client-side half: read events, cancel, or block for the outcome.
pub struct RequestHandle {
    events: mpmc::Receiver<Event>,
    cancel: CancelToken,
    deadline: Option<Instant>,
    request_id: u64,
}

impl RequestHandle {
    /// Pool-unique id assigned at submission: the key for GET
    /// /trace/{request_id}, available before the first event arrives (the
    /// SSE surface advertises it in its opening frame).
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    /// Flip the cancel token; the worker retires the slot within one
    /// batch iteration and replies with a terminal `Error`.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// The shared cancel token.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Next event, blocking. `None` once the channel is closed with no
    /// terminal event delivered (scheduler died mid-request).
    pub fn next_event(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Next event, blocking up to `timeout` (SSE keepalive cadence).
    pub fn next_event_timeout(&self, timeout: Duration) -> Result<Event, mpmc::RecvTimeoutError> {
        self.events.recv_timeout(timeout)
    }

    /// True once the request's deadline (plus the client-side grace) is
    /// behind us with no terminal event delivered. The worker normally
    /// reports expiry first, with partial progress; this is the backstop
    /// for requests no worker ever observes (expired deep in a saturated
    /// queue) so a deadlined client is never parked indefinitely.
    pub fn deadline_overdue(&self) -> bool {
        self.deadline
            .is_some_and(|d| Instant::now() >= d + CLIENT_DEADLINE_GRACE)
    }

    /// Drain to the terminal event: the blocking round-trip. `Committed`
    /// chunks are discarded — callers that want them read events
    /// themselves. Returns a deadline error on its own if the deadline
    /// passes (plus grace) without the scheduler answering.
    pub fn wait(self) -> Result<InfillResponse> {
        loop {
            let event = match self.deadline {
                None => self.events.recv(),
                Some(d) => {
                    let limit = d + CLIENT_DEADLINE_GRACE;
                    let remaining = limit.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        // Overdue: deliver anything already queued (the
                        // worker may have answered at the wire), else
                        // flip the token — the queued job is reaped the
                        // moment a worker sees it — and stop waiting.
                        match self.events.try_recv() {
                            Ok(ev) => Ok(ev),
                            Err(mpmc::TryRecvError::Empty) => {
                                self.cancel.cancel();
                                return Err(anyhow!("deadline exceeded awaiting scheduler"));
                            }
                            Err(mpmc::TryRecvError::Disconnected) => {
                                Err(mpmc::RecvTimeoutError::Disconnected)
                            }
                        }
                    } else {
                        match self.events.recv_timeout(remaining) {
                            Err(mpmc::RecvTimeoutError::Timeout) => continue,
                            other => other,
                        }
                    }
                }
            };
            match event {
                Ok(Event::Committed { .. }) => continue,
                Ok(Event::Done(resp)) => return Ok(resp),
                Ok(Event::Error(e)) => return Err(anyhow!(e)),
                Err(_) => return Err(anyhow!("scheduler dropped request")),
            }
        }
    }
}

/// Incremental text view over a stream of `Committed` events, for the SSE
/// surface: byte-level tokens land at arbitrary positions (any-subset
/// infilling), and this tracks the growing fully-committed PREFIX,
/// flushing only complete UTF-8 (a multi-byte character split across
/// commits, or across a mask boundary, is held back until its last byte
/// lands). Invalid sequences are replaced exactly like
/// `String::from_utf8_lossy` so the concatenated flushes plus
/// [`TextAssembler::finish`] reproduce the blocking path's response text
/// byte for byte.
pub struct TextAssembler {
    bytes: Vec<u8>,
    pending: Vec<bool>,
    /// Bytes already flushed (always a UTF-8 boundary in the lossy sense).
    emitted: usize,
}

impl TextAssembler {
    /// Start from the request text: `mask_char` positions are pending,
    /// everything else is committed from the outset.
    pub fn new(text: &str, mask_char: char) -> TextAssembler {
        let mut mask_buf = [0u8; 4];
        let mask = mask_char.encode_utf8(&mut mask_buf).as_bytes();
        let bytes = text.as_bytes().to_vec();
        let pending = if mask.len() == 1 {
            bytes.iter().map(|&b| b == mask[0]).collect()
        } else {
            // multi-byte mask_char is rejected at admission; nothing pends
            vec![false; bytes.len()]
        };
        TextAssembler {
            bytes,
            pending,
            emitted: 0,
        }
    }

    /// Apply one committed chunk; returns the newly-decodable text (may
    /// be empty while a multi-byte character is still incomplete).
    /// Positions outside the text (PAD tail) are ignored.
    pub fn apply(&mut self, positions: &[usize], tokens: &[u32]) -> String {
        for (&pos, &tok) in positions.iter().zip(tokens) {
            if pos < self.bytes.len() {
                // committed tokens are always plain bytes (MASK/PAD are
                // banned from sampling); map specials defensively the way
                // the tokenizer's decode renders MASK
                self.bytes[pos] = if tok < 256 { tok as u8 } else { b'_' };
                self.pending[pos] = false;
            }
        }
        self.flush(false)
    }

    /// Flush whatever is still held back (lossily), closing the stream.
    /// Empty unless the text ends in an invalid or incomplete sequence.
    pub fn finish(&mut self) -> String {
        self.flush(true)
    }

    /// The committed prefix length in bytes (progress indicator).
    pub fn frontier(&self) -> usize {
        self.pending
            .iter()
            .position(|&p| p)
            .unwrap_or(self.bytes.len())
    }

    fn flush(&mut self, at_end: bool) -> String {
        let frontier = self.frontier();
        let mut out = String::new();
        while self.emitted < frontier {
            let chunk = &self.bytes[self.emitted..frontier];
            match std::str::from_utf8(chunk) {
                Ok(s) => {
                    out.push_str(s);
                    self.emitted = frontier;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(std::str::from_utf8(&chunk[..valid]).unwrap());
                    self.emitted += valid;
                    match e.error_len() {
                        // invalid sequence: one replacement char, skip it
                        Some(bad) => {
                            out.push('\u{FFFD}');
                            self.emitted += bad;
                        }
                        // incomplete tail: wait for more bytes — unless
                        // the frontier can never advance past it
                        None => {
                            if at_end || frontier == self.bytes.len() {
                                out.push('\u{FFFD}');
                                self.emitted = frontier;
                            }
                            return out;
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_is_shared() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }

    #[test]
    fn wait_collects_done_through_commits() {
        let (emitter, handle) = channel(None, 8, 1);
        assert!(emitter.commit(vec![2, 3], vec![97, 98]));
        emitter.finish(Ok(InfillResponse {
            request_id: 1,
            text: "done".into(),
            model_nfe: 1,
            aux_nfe: 0,
            iterations: 1,
            proposed: 0,
            accepted: 0,
            acceptance_rate: 0.0,
            draft_kind: String::new(),
            draft_len: 0,
            latency_s: 0.0,
            n_generated: 2,
        }));
        let resp = handle.wait().unwrap();
        assert_eq!(resp.text, "done");
    }

    #[test]
    fn wait_surfaces_error_event() {
        let (emitter, handle) = channel(None, 8, 1);
        emitter.finish(Err(anyhow!("deadline exceeded after 3/8 tokens")));
        let err = handle.wait().unwrap_err().to_string();
        assert!(err.contains("deadline"), "{err}");
    }

    #[test]
    fn dropped_handle_reports_abandoned() {
        let (emitter, handle) = channel(None, 8, 1);
        assert!(emitter.abort_reason().is_none());
        drop(handle);
        assert_eq!(emitter.abort_reason(), Some(Abort::Abandoned));
        assert!(!emitter.commit(vec![0], vec![97]));
    }

    #[test]
    fn deadline_wins_over_cancel_for_attribution() {
        // The client-side backstop cancels BECAUSE the deadline passed,
        // so when both flags are up the expiry is the true cause.
        let (emitter, handle) = channel(Some(Duration::ZERO), 8, 1);
        handle.cancel();
        std::thread::sleep(Duration::from_millis(1));
        assert_eq!(emitter.abort_reason(), Some(Abort::DeadlineExpired));
        // a plain cancel (no deadline configured) stays a cancel
        let (emitter, handle) = channel(None, 8, 1);
        handle.cancel();
        assert_eq!(emitter.abort_reason(), Some(Abort::Cancelled));
    }

    #[test]
    fn expired_deadline_reports_deadline() {
        let (emitter, _handle) = channel(Some(Duration::ZERO), 8, 1);
        std::thread::sleep(Duration::from_millis(1));
        assert_eq!(emitter.abort_reason(), Some(Abort::DeadlineExpired));
    }

    #[test]
    fn lagging_event_channel_flips_cancel() {
        let (emitter, handle) = channel(None, 1, 1);
        assert!(emitter.commit(vec![0], vec![97]));
        // capacity 1, nothing drained: the next commit must shed the
        // client rather than block the worker
        assert!(!emitter.commit(vec![1], vec![98]));
        assert!(handle.cancel_token().is_cancelled());
    }

    /// The terminal event has a reserved slot: a decode whose commits
    /// exactly fill the channel still reports Done instead of surfacing
    /// as a dropped request to a client that drains late.
    #[test]
    fn terminal_event_survives_full_commit_buffer() {
        let (emitter, handle) = channel(None, 2, 1);
        assert!(emitter.commit(vec![0], vec![97]));
        assert!(emitter.commit(vec![1], vec![98]));
        emitter.finish(Ok(InfillResponse {
            request_id: 1,
            text: "full".into(),
            model_nfe: 2,
            aux_nfe: 0,
            iterations: 2,
            proposed: 0,
            accepted: 0,
            acceptance_rate: 0.0,
            draft_kind: String::new(),
            draft_len: 0,
            latency_s: 0.0,
            n_generated: 2,
        }));
        // nothing was drained until now — the commits AND the terminal
        // must all come through
        assert_eq!(handle.wait().unwrap().text, "full");
    }

    // --- TextAssembler ---------------------------------------------------

    #[test]
    fn assembler_streams_ascii_prefix_in_commit_order() {
        let mut a = TextAssembler::new("ab__cd", '_');
        assert_eq!(a.frontier(), 2);
        assert_eq!(a.apply(&[2], &[b'X' as u32]), "abX");
        assert_eq!(a.apply(&[3], &[b'Y' as u32]), "Ycd");
        assert_eq!(a.finish(), "");
    }

    #[test]
    fn assembler_holds_back_out_of_order_commits() {
        let mut a = TextAssembler::new("__cd", '_');
        // position 1 commits before position 0: nothing decodable yet
        assert_eq!(a.apply(&[1], &[b'B' as u32]), "");
        assert_eq!(a.apply(&[0], &[b'A' as u32]), "ABcd");
    }

    #[test]
    fn assembler_flushes_only_complete_utf8() {
        // é = 0xC3 0xA9: commit the lead byte alone, then the tail
        let mut a = TextAssembler::new("x__y", '_');
        assert_eq!(a.apply(&[1], &[0xC3]), "x");
        assert_eq!(a.apply(&[2], &[0xA9]), "éy");
    }

    #[test]
    fn assembler_replaces_invalid_sequences_like_lossy() {
        // a lone continuation byte is invalid wherever it lands
        let mut a = TextAssembler::new("a_b", '_');
        let s = a.apply(&[1], &[0xA9]);
        assert_eq!(s, "a\u{FFFD}b");
        assert_eq!(
            s,
            String::from_utf8_lossy(&[b'a', 0xA9, b'b']).into_owned()
        );
    }

    #[test]
    fn assembler_finish_flushes_incomplete_tail() {
        let mut a = TextAssembler::new("a_", '_');
        // trailing lead byte with no continuation: held, then lossy at end
        assert_eq!(a.apply(&[1], &[0xC3]), "a\u{FFFD}");
        assert_eq!(a.finish(), "");
        assert_eq!(
            "a\u{FFFD}",
            String::from_utf8_lossy(&[b'a', 0xC3]).into_owned()
        );
    }

    #[test]
    fn assembler_concatenation_matches_lossy_decode_of_final_bytes() {
        // arbitrary byte soup, committed in a scrambled order
        let text = "ab______cd";
        let fills: &[(usize, u8)] = &[
            (4, 0xE2),
            (2, b'h'),
            (7, b'!'),
            (3, 0xC3),
            (6, 0x82),
            (5, 0x82),
        ];
        let mut final_bytes = text.as_bytes().to_vec();
        for &(p, b) in fills {
            final_bytes[p] = b;
        }
        let expect = String::from_utf8_lossy(&final_bytes).into_owned();
        let mut a = TextAssembler::new(text, '_');
        let mut got = String::new();
        for &(p, b) in fills {
            got.push_str(&a.apply(&[p], &[b as u32]));
        }
        got.push_str(&a.finish());
        assert_eq!(got, expect);
    }
}
