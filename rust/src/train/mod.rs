//! The rust training loop (paper §6.2 + App. D.2/D.3).
//!
//! The train-step MATH lives in Layer 2 (python/compile/model.py,
//! AdamW + the teacher-forced joint loss of Eq. 7) and was AOT-lowered to
//! `train_step_b{B}.hlo.txt`. This module owns everything around it:
//! batch assembly (sampling m ~ f(·) with the low-discrepancy in-batch
//! scheme, sigma ~ s(·|m) under the lattice or permutation protocol,
//! verify-mask construction, loss weights), the LR/mask-rate schedules,
//! validation, and checkpointing. Python never runs here.

pub mod ablation;

use std::path::PathBuf;

use anyhow::Result;

use crate::data::masking::{sample_sigma, MaskRateSchedule, OrderProtocol, PromptDist};
use crate::model::mask::{verify_masks_into, Ordering};
use crate::runtime::engine::TrainRunner;
use crate::tokenizer::PAD;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr_max: f32,
    pub warmup_steps: usize,
    /// total steps for linear decay after warmup (>= steps - warmup_steps)
    pub decay_steps: usize,
    pub mask_schedule: MaskRateSchedule,
    /// Fixed prompt distribution override (ablations); when None the
    /// mask-rate schedule drives f(·).
    pub prompt_dist: Option<PromptDist>,
    pub protocol: OrderProtocol,
    pub seed: u64,
    pub log_every: usize,
    pub val_every: usize,
    pub val_batches: usize,
    pub checkpoint: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 400,
            lr_max: 3e-4,
            warmup_steps: 40,
            decay_steps: 400,
            mask_schedule: MaskRateSchedule::paper_default(),
            prompt_dist: None,
            protocol: OrderProtocol::Lattice,
            seed: 0,
            log_every: 20,
            val_every: 100,
            val_batches: 2,
            checkpoint: None,
        }
    }
}

/// One log record (the Fig. 3/4 curves are series of these).
#[derive(Clone, Debug)]
pub struct TrainLog {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub val_nll_per_token: Option<f64>,
}

/// Linear warmup then linear decay (paper App. D.3).
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f32 {
    if step < cfg.warmup_steps {
        cfg.lr_max * (step as f32 + 1.0) / cfg.warmup_steps as f32
    } else {
        let t = (step - cfg.warmup_steps) as f32 / cfg.decay_steps.max(1) as f32;
        (cfg.lr_max * (1.0 - t)).max(0.0)
    }
}

/// Assemble one training batch: tokens + verify masks + loss weights.
///
/// Loss weights are 1.0 exactly at target positions (order >= m) that are
/// not PAD — Eq. 7's joint conditional covers the masked set; PAD tails
/// carry no signal.
pub fn build_batch(
    rng: &mut Rng,
    chunks: &[Vec<u32>],
    batch: usize,
    n: usize,
    dist: &PromptDist,
    protocol: OrderProtocol,
    tokens: &mut [u32],
    mask_h: &mut [f32],
    mask_g: &mut [f32],
    loss_w: &mut [f32],
) {
    assert_eq!(tokens.len(), batch * n);
    let ms = dist.sample_batch(rng, n, batch);
    for (s, &m) in ms.iter().enumerate() {
        let chunk = &chunks[rng.below(chunks.len())];
        assert_eq!(chunk.len(), n);
        tokens[s * n..(s + 1) * n].copy_from_slice(chunk);
        let sigma = sample_sigma(rng, n, m, protocol);
        let ord = Ordering::new(sigma, m);
        verify_masks_into(
            &ord,
            &mut mask_h[s * n * n..(s + 1) * n * n],
            &mut mask_g[s * n * n..(s + 1) * n * n],
        );
        for pos in 0..n {
            let is_target = !ord.is_prompt_pos(pos);
            let not_pad = chunk[pos] != PAD;
            loss_w[s * n + pos] = if is_target && not_pad { 1.0 } else { 0.0 };
        }
    }
}

/// Teacher-forced validation NLL per target token over held-out chunks.
pub fn validation_nll(
    engine: &dyn crate::runtime::Engine,
    rng: &mut Rng,
    val_chunks: &[Vec<u32>],
    batches: usize,
    dist: &PromptDist,
    protocol: OrderProtocol,
) -> Result<f64> {
    let n = engine.seq_len();
    let v = engine.vocab();
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for _ in 0..batches {
        let chunk = &val_chunks[rng.below(val_chunks.len())];
        let m = dist.sample(rng, n);
        let sigma = sample_sigma(rng, n, m, protocol);
        let ord = Ordering::new(sigma, m);
        let (h, g) = crate::model::mask::verify_masks(&ord);
        let logits = engine.forward(1, chunk, &h, &g)?;
        for i in m..n {
            let pos = ord.sigma[i];
            if chunk[pos] == PAD {
                continue;
            }
            let lp =
                crate::decode::sampling::log_softmax(&logits[pos * v..(pos + 1) * v], 1.0);
            total_nll -= lp[chunk[pos] as usize] as f64;
            total_tokens += 1;
        }
    }
    Ok(total_nll / total_tokens.max(1) as f64)
}

/// Run the training loop. When `val_engine` is provided it receives the
/// current weights before each validation pass.
pub fn train(
    runner: &mut TrainRunner,
    train_chunks: &[Vec<u32>],
    val_chunks: &[Vec<u32>],
    cfg: &TrainConfig,
    mut val_engine: Option<&mut crate::runtime::XlaEngine>,
) -> Result<Vec<TrainLog>> {
    let n = runner.meta.seq_len;
    let b = runner.batch;
    let mut rng = Rng::new(cfg.seed);
    let mut tokens = vec![0u32; b * n];
    let mut mask_h = vec![0f32; b * n * n];
    let mut mask_g = vec![0f32; b * n * n];
    let mut loss_w = vec![0f32; b * n];
    let mut logs = vec![];

    for step in 0..cfg.steps {
        let dist = cfg.prompt_dist.unwrap_or_else(|| cfg.mask_schedule.at(step));
        build_batch(
            &mut rng,
            train_chunks,
            b,
            n,
            &dist,
            cfg.protocol,
            &mut tokens,
            &mut mask_h,
            &mut mask_g,
            &mut loss_w,
        );
        let lr = lr_at(cfg, step);
        let out = runner.step(&tokens, &mask_h, &mask_g, &loss_w, lr)?;

        let mut val = None;
        let is_log_step = step % cfg.log_every == 0 || step + 1 == cfg.steps;
        let is_val_step =
            cfg.val_every > 0 && (step % cfg.val_every == 0 || step + 1 == cfg.steps);
        if is_val_step && !val_chunks.is_empty() {
            if let Some(ve) = val_engine.as_deref_mut() {
                ve.set_params(runner.theta.clone())?;
                let final_dist = cfg.prompt_dist.unwrap_or(PromptDist::narrow());
                let mut vrng = Rng::new(cfg.seed ^ 0xabcdef);
                val = Some(validation_nll(
                    ve,
                    &mut vrng,
                    val_chunks,
                    cfg.val_batches,
                    &final_dist,
                    OrderProtocol::Lattice,
                )?);
            }
        }
        if is_log_step || val.is_some() {
            logs.push(TrainLog {
                step,
                loss: out.loss,
                lr,
                val_nll_per_token: val,
            });
            eprintln!(
                "step {step:5}  loss {:.4}  lr {:.2e}{}",
                out.loss,
                lr,
                val.map(|v| format!("  val_nll/tok {v:.4}"))
                    .unwrap_or_default()
            );
        }
    }
    if let Some(path) = &cfg.checkpoint {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        crate::model::save_params(path, &runner.theta)?;
        eprintln!("checkpoint -> {}", path.display());
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrainConfig {
        TrainConfig {
            steps: 10,
            warmup_steps: 4,
            decay_steps: 10,
            ..Default::default()
        }
    }

    #[test]
    fn lr_schedule_shape() {
        let c = cfg();
        assert!(lr_at(&c, 0) > 0.0);
        assert!(lr_at(&c, 3) < c.lr_max + 1e-9);
        assert!((lr_at(&c, 4) - c.lr_max).abs() < c.lr_max * 0.3);
        assert!(lr_at(&c, 9) < lr_at(&c, 5));
        assert!(lr_at(&c, 10_000) == 0.0);
    }

    #[test]
    fn build_batch_invariants() {
        let mut rng = Rng::new(1);
        let n = 16;
        let b = 4;
        let chunks: Vec<Vec<u32>> = (0..8)
            .map(|i| (0..n).map(|j| ((i * 7 + j) % 250) as u32).collect())
            .collect();
        let mut tokens = vec![0u32; b * n];
        let mut mh = vec![0f32; b * n * n];
        let mut mg = vec![0f32; b * n * n];
        let mut lw = vec![0f32; b * n];
        build_batch(
            &mut rng,
            &chunks,
            b,
            n,
            &PromptDist::new(0.2, 0.5),
            OrderProtocol::Lattice,
            &mut tokens,
            &mut mh,
            &mut mg,
            &mut lw,
        );
        for s in 0..b {
            let w: f32 = lw[s * n..(s + 1) * n].iter().sum();
            assert!(w >= 1.0, "slot {s} has no loss targets");
            assert!(w < n as f32, "slot {s} has no prompt");
            for a in 0..n {
                assert_eq!(mh[s * n * n + a * n + a], 1.0);
            }
        }
    }

    #[test]
    fn build_batch_pads_carry_no_loss() {
        let mut rng = Rng::new(2);
        let n = 8;
        let chunks = vec![vec![65u32, 66, 67, PAD, PAD, PAD, PAD, PAD]];
        let mut tokens = vec![0u32; n];
        let mut mh = vec![0f32; n * n];
        let mut mg = vec![0f32; n * n];
        let mut lw = vec![0f32; n];
        build_batch(
            &mut rng,
            &chunks,
            1,
            n,
            &PromptDist::new(0.2, 0.3),
            OrderProtocol::Lattice,
            &mut tokens,
            &mut mh,
            &mut mg,
            &mut lw,
        );
        for pos in 3..8 {
            assert_eq!(lw[pos], 0.0, "PAD at {pos} got loss weight");
        }
    }

    #[test]
    fn validation_nll_on_mock_is_finite() {
        use crate::runtime::mock::MockEngine;
        let e = MockEngine::new(1, 16, 258, 1.0);
        let mut rng = Rng::new(3);
        let chunks: Vec<Vec<u32>> = (0..4)
            .map(|i| (0..16).map(|j| ((i + j) % 250) as u32).collect())
            .collect();
        let nll = validation_nll(
            &e,
            &mut rng,
            &chunks,
            3,
            &PromptDist::new(0.1, 0.3),
            OrderProtocol::Lattice,
        )
        .unwrap();
        assert!(nll.is_finite());
        assert!(nll > 0.0);
    }
}
