//! Ablation runners for the paper's training figures.
//!
//! * Fig. 3 — binary-lattice sigma vs unrestricted-permutation sigma
//!   training (§2.4's 2^N-vs-N! argument).
//! * Fig. 4 — narrow (1–10%) vs wide (1–85%) prompt-rate training
//!   (App. D.2 / F.2).
//!
//! Each arm trains from the same init on the same data, logging validation
//! NLL curves; the fig3/fig4 bench binaries print the paper-style series.

use anyhow::Result;

use crate::data::masking::{OrderProtocol, PromptDist};
use crate::runtime::engine::TrainRunner;
use crate::runtime::XlaEngine;

use super::{train, TrainConfig, TrainLog};

/// One ablation arm: a label + config deltas applied to a base config.
pub struct Arm {
    pub label: String,
    pub protocol: OrderProtocol,
    pub prompt_dist: Option<PromptDist>,
}

/// Train every arm from the same initialization; returns (label, logs).
pub fn run_arms(
    artifacts_dir: &std::path::Path,
    batch: usize,
    base: &TrainConfig,
    arms: &[Arm],
    train_chunks: &[Vec<u32>],
    val_chunks: &[Vec<u32>],
) -> Result<Vec<(String, Vec<TrainLog>)>> {
    let mut runner = TrainRunner::load(artifacts_dir, batch)?;
    let mut val_engine = XlaEngine::load(artifacts_dir, None)?;
    let theta0 = runner.theta.clone();
    let mut out = vec![];
    for arm in arms {
        eprintln!("=== ablation arm: {} ===", arm.label);
        runner.reset(theta0.clone());
        let cfg = TrainConfig {
            protocol: arm.protocol,
            prompt_dist: arm.prompt_dist,
            checkpoint: None,
            ..base.clone()
        };
        let logs = train(
            &mut runner,
            train_chunks,
            val_chunks,
            &cfg,
            Some(&mut val_engine),
        )?;
        out.push((arm.label.clone(), logs));
    }
    Ok(out)
}

/// Fig. 3 arms: lattice vs permutation, same prompt distribution.
pub fn fig3_arms() -> Vec<Arm> {
    vec![
        Arm {
            label: "lattice (Eq. 4)".into(),
            protocol: OrderProtocol::Lattice,
            prompt_dist: Some(PromptDist::narrow()),
        },
        Arm {
            label: "any permutation".into(),
            protocol: OrderProtocol::Permutation,
            prompt_dist: Some(PromptDist::narrow()),
        },
    ]
}

/// Fig. 4 arms: narrow vs wide prompt rates, both lattice.
pub fn fig4_arms() -> Vec<Arm> {
    vec![
        Arm {
            label: "narrow prompts (1-10%)".into(),
            protocol: OrderProtocol::Lattice,
            prompt_dist: Some(PromptDist::narrow()),
        },
        Arm {
            label: "wide prompts (1-85%)".into(),
            protocol: OrderProtocol::Lattice,
            prompt_dist: Some(PromptDist::wide()),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_definitions_differ_along_one_axis() {
        let f3 = fig3_arms();
        assert_eq!(f3.len(), 2);
        assert_ne!(f3[0].protocol, f3[1].protocol);

        let f4 = fig4_arms();
        assert_eq!(f4.len(), 2);
        assert_eq!(f4[0].protocol, f4[1].protocol);
        let a = f4[0].prompt_dist.unwrap();
        let b = f4[1].prompt_dist.unwrap();
        assert!(a.hi_frac < b.hi_frac);
    }
}
