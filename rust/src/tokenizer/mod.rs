//! Byte-level tokenizer with MASK/PAD specials.
//!
//! The paper finetunes XLNet with a 32k SentencePiece vocab; we substitute a
//! byte-level vocabulary (256 bytes + MASK + PAD = 258) so the tokenizer is
//! trivially identical between the python compile path and the rust request
//! path (docs/ARCHITECTURE.md). The ids mirror python/compile/config.py.

pub const VOCAB: usize = 258;
pub const MASK: u32 = 256;
pub const PAD: u32 = 257;

#[derive(Clone, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Encode into a fixed-length window, truncating or PAD-filling.
    pub fn encode_fixed(&self, text: &str, len: usize) -> Vec<u32> {
        let mut ids = self.encode(text);
        ids.truncate(len);
        while ids.len() < len {
            ids.push(PAD);
        }
        ids
    }

    /// Decode ids to text. MASK renders as `\u{FFFD}`-style placeholder '_',
    /// PAD is dropped; invalid UTF-8 is replaced lossily.
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter_map(|&id| match id {
                PAD => None,
                MASK => Some(b'_'),
                b if b < 256 => Some(b as u8),
                _ => None,
            })
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, id: u32) -> bool {
        id >= 256
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let s = "Hello, AS-ARM world! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new();
        let s = "héllo — 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn fixed_pads_and_truncates() {
        let t = ByteTokenizer::new();
        let ids = t.encode_fixed("abc", 5);
        assert_eq!(ids, vec![97, 98, 99, PAD, PAD]);
        let ids = t.encode_fixed("abcdef", 4);
        assert_eq!(ids.len(), 4);
        assert_eq!(t.decode(&ids), "abcd");
    }

    #[test]
    fn mask_renders_placeholder_pad_dropped() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[104, MASK, 105, PAD]), "h_i");
    }

    #[test]
    fn specials() {
        let t = ByteTokenizer::new();
        assert!(t.is_special(MASK));
        assert!(t.is_special(PAD));
        assert!(!t.is_special(255));
        assert_eq!(t.vocab_size(), 258);
    }

    /// Property: encode/decode round-trips for arbitrary valid UTF-8.
    #[test]
    fn prop_roundtrip() {
        use crate::util::{propcheck, rng::Rng};
        propcheck::check_no_shrink(
            99,
            100,
            |r: &mut Rng| {
                let n = r.below(64);
                (0..n)
                    .map(|_| char::from_u32(r.range(32, 0x2000) as u32).unwrap_or('x'))
                    .collect::<String>()
            },
            |s| {
                let t = ByteTokenizer::new();
                if t.decode(&t.encode(s)) == *s {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }
}
