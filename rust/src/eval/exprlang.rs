//! Mini expression language — the HumanEval-infilling substitute
//! (docs/ARCHITECTURE.md, Table 3).
//!
//! Programs are short straight-line integer programs:
//!
//! ```text
//! a = 3
//! b = a + 4
//! c = b * 2
//! print c
//! ```
//!
//! The benchmark blanks one interior line; a completion PASSES (pass@1) if
//! the completed program still parses, evaluates without error, and prints
//! the same value as the reference program — functional correctness, like
//! HumanEval's test-based judging, checkable entirely in-repo.

use std::collections::HashMap;

use crate::util::rng::Rng;

/// Evaluate a program; returns the printed value or an error string.
pub fn eval_program(src: &str) -> Result<i64, String> {
    let mut env: HashMap<String, i64> = HashMap::new();
    let mut printed: Option<i64> = None;
    for (lineno, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("print ") {
            let v = eval_expr(rest.trim(), &env).map_err(|e| format!("line {lineno}: {e}"))?;
            printed = Some(v);
        } else if let Some((lhs, rhs)) = line.split_once('=') {
            let var = lhs.trim();
            if var.is_empty() || !var.chars().all(|c| c.is_ascii_lowercase()) {
                return Err(format!("line {lineno}: bad lhs '{var}'"));
            }
            let v = eval_expr(rhs.trim(), &env).map_err(|e| format!("line {lineno}: {e}"))?;
            env.insert(var.to_string(), v);
        } else {
            return Err(format!("line {lineno}: unparseable '{line}'"));
        }
    }
    printed.ok_or_else(|| "no print".to_string())
}

fn eval_atom(tok: &str, env: &HashMap<String, i64>) -> Result<i64, String> {
    if let Ok(n) = tok.parse::<i64>() {
        return Ok(n);
    }
    env.get(tok)
        .copied()
        .ok_or_else(|| format!("undefined var '{tok}'"))
}

fn eval_expr(expr: &str, env: &HashMap<String, i64>) -> Result<i64, String> {
    let toks: Vec<&str> = expr.split_whitespace().collect();
    match toks.as_slice() {
        [a] => eval_atom(a, env),
        [a, op, b] => {
            let x = eval_atom(a, env)?;
            let y = eval_atom(b, env)?;
            match *op {
                "+" => Ok(x.wrapping_add(y)),
                "-" => Ok(x.wrapping_sub(y)),
                "*" => Ok(x.wrapping_mul(y)),
                _ => Err(format!("bad op '{op}'")),
            }
        }
        _ => Err(format!("bad expr '{expr}'")),
    }
}

/// Generate a random program with `n_lines` assignment lines + print.
pub fn gen_program(rng: &mut Rng, n_lines: usize) -> String {
    assert!(n_lines >= 1);
    let ops = ["+", "-", "*"];
    let mut vars: Vec<String> = vec![];
    let mut lines: Vec<String> = vec![];
    for i in 0..n_lines {
        let var = ((b'a' + (i % 26) as u8) as char).to_string();
        let rhs = if vars.is_empty() || rng.below(4) == 0 {
            format!("{}", rng.range(1, 10))
        } else {
            let a = &vars[rng.below(vars.len())];
            let op = ops[rng.below(ops.len())];
            if rng.below(2) == 0 {
                format!("{a} {op} {}", rng.range(1, 10))
            } else {
                let b = &vars[rng.below(vars.len())];
                format!("{a} {op} {b}")
            }
        };
        lines.push(format!("{var} = {rhs}"));
        vars.push(var);
    }
    lines.push(format!("print {}", vars[rng.below(vars.len())]));
    lines.join("\n")
}

/// An infilling task: the program with line `blank_line` removed.
pub struct InfillTask {
    pub program: String,
    pub blank_line: usize,
    pub prefix: String,
    pub suffix: String,
    pub reference_line: String,
    pub expected: i64,
}

/// Build a single-line infilling task from a generated program (blanks a
/// random interior assignment, never the first line or the print).
pub fn make_task(rng: &mut Rng, n_lines: usize) -> InfillTask {
    loop {
        let program = gen_program(rng, n_lines);
        let expected = match eval_program(&program) {
            Ok(v) => v,
            Err(_) => continue,
        };
        let lines: Vec<&str> = program.lines().collect();
        if lines.len() < 3 {
            continue;
        }
        let blank_line = rng.range(1, lines.len() - 1);
        let prefix = lines[..blank_line].join("\n") + "\n";
        let suffix = "\n".to_string() + &lines[blank_line + 1..].join("\n");
        let task = InfillTask {
            reference_line: lines[blank_line].to_string(),
            program,
            blank_line,
            prefix,
            suffix,
            expected,
        };
        // Only keep tasks whose blanked line is semantically load-bearing:
        // substituting a wrong constant must change the printed value
        // (otherwise any syntactically valid completion would "pass").
        let var = task
            .reference_line
            .split('=')
            .next()
            .unwrap_or("")
            .trim()
            .to_string();
        let matters = [101, 107]
            .iter()
            .any(|c| !task.passes(&format!("{var} = {c}")));
        if matters {
            return task;
        }
    }
}

impl InfillTask {
    /// Judge a completion line: pass iff the reassembled program prints the
    /// expected value.
    pub fn passes(&self, completion_line: &str) -> bool {
        let candidate = format!("{}{}{}", self.prefix, completion_line.trim(), self.suffix);
        matches!(eval_program(&candidate), Ok(v) if v == self.expected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_known_program() {
        let p = "a = 3\nb = a + 4\nc = b * 2\nprint c";
        assert_eq!(eval_program(p), Ok(14));
    }

    #[test]
    fn eval_rejects_undefined_var() {
        assert!(eval_program("a = b + 1\nprint a").is_err());
    }

    #[test]
    fn eval_rejects_garbage() {
        assert!(eval_program("a = 1\nblah blah\nprint a").is_err());
        assert!(eval_program("a = 1").is_err()); // no print
        assert!(eval_program("a = 1 / 2\nprint a").is_err()); // bad op
    }

    #[test]
    fn negative_numbers_and_wrapping() {
        assert_eq!(eval_program("a = 5\nb = a - 9\nprint b"), Ok(-4));
    }

    #[test]
    fn generated_programs_always_evaluate() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let n = rng.range(2, 7);
            let p = gen_program(&mut rng, n);
            assert!(eval_program(&p).is_ok(), "unevaluable: {p}");
            assert!(p.len() < 120, "too long for model window: {p}");
        }
    }

    #[test]
    fn task_reference_line_passes() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let t = make_task(&mut rng, 4);
            assert!(
                t.passes(&t.reference_line),
                "reference fails its own task: {}",
                t.program
            );
        }
    }

    #[test]
    fn task_wrong_line_usually_fails() {
        let mut rng = Rng::new(3);
        let mut fails = 0;
        let total = 100;
        for _ in 0..total {
            let t = make_task(&mut rng, 4);
            // a syntactically valid but (usually) semantically wrong line
            let wrong = format!(
                "{} = 7",
                t.reference_line.split('=').next().unwrap().trim()
            );
            if !t.passes(&wrong) {
                fails += 1;
            }
        }
        assert!(fails > 50, "wrong lines passed too often ({fails}/{total})");
    }

    #[test]
    fn garbage_completion_fails() {
        let mut rng = Rng::new(4);
        let t = make_task(&mut rng, 4);
        assert!(!t.passes("@@@ nonsense"));
        assert!(!t.passes(""));
    }
}
