//! Generation-quality metrics: generative perplexity and Shannon entropy
//! (paper App. D.4).
//!
//! The paper judges generations with GPT-2 Large; offline we substitute the
//! AS-ARM's own one-pass joint density under the left-to-right ordering as
//! the judge (docs/ARCHITECTURE.md) — any fixed density model supports the
//! sampler-vs-sampler comparisons of Tables 1/4, and the AS-ARM evaluates
//! exact joints in a single forward (the paper's Sec. 4.2 capability, used
//! here for evaluation as well as verification).

use anyhow::Result;

use crate::data::masking::lattice_sigma;
use crate::decode::sampling::log_softmax_into;
use crate::model::mask::{verify_masks, Ordering};
use crate::runtime::Engine;

/// Exact joint log-density log p(x_sigma(>=m) | x_sigma(<m)) in ONE forward
/// (the paper's one-pass density estimation, Fig. 1b).
pub fn joint_logprob(engine: &dyn Engine, ord: &Ordering, tokens: &[u32]) -> Result<f64> {
    let n = engine.seq_len();
    let v = engine.vocab();
    assert_eq!(tokens.len(), n);
    let (h, g) = verify_masks(ord);
    let logits = engine.forward(1, tokens, &h, &g)?;
    let mut total = 0.0f64;
    let mut lp = Vec::with_capacity(v);
    for i in ord.m..n {
        let pos = ord.sigma[i];
        log_softmax_into(&logits[pos * v..(pos + 1) * v], 1.0, &mut lp);
        total += lp[tokens[pos] as usize] as f64;
    }
    Ok(total)
}

/// Generative perplexity of a sequence under the judge: the judge scores
/// the FULL sequence left-to-right given the first `ctx` tokens as context.
pub fn generative_perplexity(
    judge: &dyn Engine,
    tokens: &[u32],
    ctx: usize,
) -> Result<f64> {
    let n = judge.seq_len();
    assert!(ctx >= 1 && ctx < n);
    let vis: Vec<usize> = (0..ctx).collect();
    let ord = Ordering::new(lattice_sigma(&vis, n), ctx);
    let lp = joint_logprob(judge, &ord, tokens)?;
    let scored = (n - ctx) as f64;
    Ok((-lp / scored).exp())
}

/// Shannon entropy over the token frequencies of a sequence (paper Eq. 22,
/// base 2). High = diverse; low = repetitive.
pub fn shannon_entropy(tokens: &[u32]) -> f64 {
    if tokens.is_empty() {
        return 0.0;
    }
    let mut counts = std::collections::HashMap::new();
    for &t in tokens {
        *counts.entry(t).or_insert(0usize) += 1;
    }
    let n = tokens.len() as f64;
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::MockEngine;
    use crate::util::rng::Rng;

    #[test]
    fn entropy_extremes() {
        assert_eq!(shannon_entropy(&[5, 5, 5, 5]), 0.0);
        let uniform: Vec<u32> = (0..16).collect();
        assert!((shannon_entropy(&uniform) - 4.0).abs() < 1e-12);
        assert_eq!(shannon_entropy(&[]), 0.0);
    }

    #[test]
    fn entropy_of_pair() {
        // 50/50 two symbols = 1 bit
        assert!((shannon_entropy(&[1, 2, 1, 2]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn joint_logprob_is_negative_and_finite() {
        let e = MockEngine::new(1, 8, 5, 1.0);
        let mut rng = Rng::new(2);
        let vis = vec![0usize, 3];
        let ord = Ordering::new(lattice_sigma(&vis, 8), 2);
        let toks: Vec<u32> = (0..8).map(|_| rng.below(5) as u32).collect();
        let lp = joint_logprob(&e, &ord, &toks).unwrap();
        assert!(lp.is_finite());
        assert!(lp < 0.0);
    }

    /// The one-pass joint must equal the sum of chain conditionals on the
    /// mock engine too (it does on the real model — integration tests).
    #[test]
    fn joint_matches_chain_on_mock() {
        use crate::decode::sampling::log_softmax;
        use crate::model::mask::draft_masks;
        let e = MockEngine::new(5, 6, 4, 1.0);
        let mut rng = Rng::new(7);
        let vis = vec![1usize, 4];
        let m = vis.len();
        let ord = Ordering::new(lattice_sigma(&vis, 6), m);
        let toks: Vec<u32> = (0..6).map(|_| rng.below(4) as u32).collect();
        let joint = joint_logprob(&e, &ord, &toks).unwrap();

        let mut chain = 0.0f64;
        let mut cur: Vec<u32> = toks
            .iter()
            .enumerate()
            .map(|(p, &t)| if ord.is_prompt_pos(p) { t } else { crate::tokenizer::MASK })
            .collect();
        for i in m..6 {
            let (h, g) = draft_masks(&ord, i);
            let logits = e.forward(1, &cur, &h, &g).unwrap();
            let pos = ord.sigma[i];
            let lp = log_softmax(&logits[pos * 4..(pos + 1) * 4], 1.0);
            chain += lp[toks[pos] as usize] as f64;
            cur[pos] = toks[pos];
        }
        assert!((joint - chain).abs() < 1e-4, "joint {joint} chain {chain}");
    }

    #[test]
    fn generative_perplexity_reasonable() {
        let e = MockEngine::new(9, 8, 5, 1.0);
        let toks: Vec<u32> = vec![0, 1, 2, 3, 4, 0, 1, 2];
        let ppl = generative_perplexity(&e, &toks, 2).unwrap();
        assert!(ppl.is_finite());
        assert!(ppl > 1.0);
        // A random mock model can assign well-below-uniform mass to the
        // actual tokens; just require a sane magnitude.
        assert!(ppl < 1e4, "ppl {ppl} implausibly large");
    }
}
