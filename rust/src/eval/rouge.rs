//! ROUGE-1/2/L (Table 2's metric), implemented from the original
//! definitions: n-gram recall/precision F1 and longest-common-subsequence
//! F1 over whitespace tokens.

use std::collections::HashMap;

fn tokens(s: &str) -> Vec<&str> {
    s.split_whitespace().collect()
}

fn ngram_counts<'a>(toks: &[&'a str], n: usize) -> HashMap<Vec<&'a str>, usize> {
    let mut m = HashMap::new();
    if toks.len() < n {
        return m;
    }
    for w in toks.windows(n) {
        *m.entry(w.to_vec()).or_insert(0) += 1;
    }
    m
}

fn f1(matches: usize, cand_total: usize, ref_total: usize) -> f64 {
    if cand_total == 0 || ref_total == 0 {
        return 0.0;
    }
    let p = matches as f64 / cand_total as f64;
    let r = matches as f64 / ref_total as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// ROUGE-N F1.
pub fn rouge_n(candidate: &str, reference: &str, n: usize) -> f64 {
    let c = tokens(candidate);
    let r = tokens(reference);
    let cc = ngram_counts(&c, n);
    let rc = ngram_counts(&r, n);
    let matches: usize = cc
        .iter()
        .map(|(g, &cnt)| cnt.min(rc.get(g).copied().unwrap_or(0)))
        .sum();
    let cand_total = c.len().saturating_sub(n - 1);
    let ref_total = r.len().saturating_sub(n - 1);
    f1(matches, cand_total, ref_total)
}

/// Length of the longest common subsequence (O(|a|*|b|) DP).
fn lcs_len(a: &[&str], b: &[&str]) -> usize {
    let (la, lb) = (a.len(), b.len());
    if la == 0 || lb == 0 {
        return 0;
    }
    let mut prev = vec![0usize; lb + 1];
    let mut cur = vec![0usize; lb + 1];
    for i in 1..=la {
        for j in 1..=lb {
            cur[j] = if a[i - 1] == b[j - 1] {
                prev[j - 1] + 1
            } else {
                prev[j].max(cur[j - 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[lb]
}

/// ROUGE-L F1.
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c = tokens(candidate);
    let r = tokens(reference);
    let l = lcs_len(&c, &r);
    f1(l, c.len(), r.len())
}

/// The (ROUGE-1, ROUGE-2, ROUGE-L) triple the paper tables report.
pub fn rouge_triple(candidate: &str, reference: &str) -> (f64, f64, f64) {
    (
        rouge_n(candidate, reference, 1),
        rouge_n(candidate, reference, 2),
        rouge_l(candidate, reference),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_strings_score_one() {
        let s = "the cat sat on the mat";
        assert!((rouge_n(s, s, 1) - 1.0).abs() < 1e-12);
        assert!((rouge_n(s, s, 2) - 1.0).abs() < 1e-12);
        assert!((rouge_l(s, s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_strings_score_zero() {
        assert_eq!(rouge_n("a b c", "x y z", 1), 0.0);
        assert_eq!(rouge_n("a b c", "x y z", 2), 0.0);
        assert_eq!(rouge_l("a b c", "x y z"), 0.0);
    }

    #[test]
    fn rouge1_known_value() {
        // cand: "the cat" ref: "the cat sat": matches=2, P=1, R=2/3 -> F1=0.8
        let f = rouge_n("the cat", "the cat sat", 1);
        assert!((f - 0.8).abs() < 1e-12, "{f}");
    }

    #[test]
    fn rouge2_counts_bigrams() {
        // cand bigrams: {the cat, cat sat}; ref: {the cat, cat ate}
        // matches=1, P=1/2, R=1/2 -> F1=1/2
        let f = rouge_n("the cat sat", "the cat ate", 2);
        assert!((f - 0.5).abs() < 1e-12, "{f}");
    }

    #[test]
    fn rouge_l_subsequence_not_substring() {
        // LCS("a b c d", "a x b y d") = a b d = 3; P=3/4, R=3/5 -> F1=2*…
        let f = rouge_l("a b c d", "a x b y d");
        let p: f64 = 3.0 / 4.0;
        let r: f64 = 3.0 / 5.0;
        let want = 2.0 * p * r / (p + r);
        assert!((f - want).abs() < 1e-12);
    }

    #[test]
    fn empty_candidate_is_zero() {
        assert_eq!(rouge_n("", "a b", 1), 0.0);
        assert_eq!(rouge_l("", "a b"), 0.0);
    }

    #[test]
    fn repeated_ngrams_clipped() {
        // cand "the the the" vs ref "the cat": matches clipped to 1
        let f = rouge_n("the the the", "the cat", 1);
        let want = 2.0 * (1.0 / 3.0) * (1.0 / 2.0) / (1.0 / 3.0 + 1.0 / 2.0);
        assert!((f - want).abs() < 1e-12);
    }
}
