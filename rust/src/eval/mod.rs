//! Evaluation substrates for the paper's tables and figures.
//!
//! * [`rouge`] — ROUGE-1/2/L f-measures for the infilling task (Table 2)
//! * [`ppl`] — generative perplexity + entropy under a fixed density
//!   model (Tables 1/4, Figs. 3/4)
//! * [`exprlang`] — the expression mini-language generator + exact judge,
//!   our offline stand-in for the code-generation benchmark (Table 3)
//! * [`harness`] — shared workload construction and sampler drivers so
//!   every bench binary scores decoders on identical inputs
//!
//! Everything here is engine-agnostic: benches run hermetically against
//! [`crate::runtime::mock::MockEngine`] or against real artifacts.

pub mod exprlang;
pub mod harness;
pub mod ppl;
pub mod rouge;
