//! Evaluation substrates: ROUGE (Table 2), generative perplexity + entropy
//! (Tables 1/4, Figs. 3/4), the expression mini-language judge (Table 3),
//! and the shared experiment harness for the bench binaries.

pub mod exprlang;
pub mod harness;
pub mod ppl;
pub mod rouge;
