//! Shared experiment harness used by the bench binaries (rust/benches/*)
//! and examples: workload builders and sampler runners that mirror the
//! paper's evaluation setups.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::SamplerKind;
use crate::data::masking::lattice_sigma;
use crate::data::{pack_chunks, stories};
use crate::decode::assd::AssdMachine;
use crate::decode::diffusion::DiffusionMachine;
use crate::decode::sequential::SequentialMachine;
use crate::decode::{run_machine, DecodeOutcome};
use crate::draft::DraftOptions;
use crate::model::mask::Ordering;
use crate::runtime::Engine;
use crate::tokenizer::{ByteTokenizer, MASK, PAD};
use crate::util::rng::Rng;

/// One evaluation item: the ordering, the masked input tokens, and the
/// ground-truth sequence.
#[derive(Clone)]
pub struct WorkItem {
    pub ord: Ordering,
    pub tokens: Vec<u32>,
    pub reference: Vec<u32>,
}

/// Table 1/4 workload: packed prose chunks with `mask_frac` of positions
/// masked, uniformly scattered (the paper masks 95% of WikiText chunks).
pub fn masked_prose_workload(
    seq_len: usize,
    n_seqs: usize,
    mask_frac: f64,
    seed: u64,
) -> Vec<WorkItem> {
    let mut rng = Rng::new(seed);
    let docs = stories::corpus(seed ^ 0x5151, n_seqs * 3 + 8);
    let chunks = pack_chunks(&docs, seq_len);
    let mut items = vec![];
    for chunk in chunks.into_iter().take(n_seqs) {
        let n = chunk.len();
        let n_masked = ((n as f64) * mask_frac).round() as usize;
        let masked = rng.choose_sorted(n, n_masked.clamp(1, n - 1));
        let is_masked: Vec<bool> = {
            let mut v = vec![false; n];
            for &p in &masked {
                v[p] = true;
            }
            v
        };
        let visible: Vec<usize> = (0..n).filter(|&p| !is_masked[p]).collect();
        let m = visible.len();
        let ord = Ordering::new(lattice_sigma(&visible, n), m);
        let mut tokens = chunk.clone();
        for &p in &masked {
            tokens[p] = MASK;
        }
        items.push(WorkItem {
            ord,
            tokens,
            reference: chunk,
        });
    }
    items
}

/// Table 2 workload: five-sentence stories with the middle 1 or 3
/// sentences blanked. Returns (item, reference middle text).
pub fn story_infill_workload(
    seq_len: usize,
    n_stories: usize,
    blank_middle_three: bool,
    seed: u64,
) -> Vec<(WorkItem, String)> {
    let mut rng = Rng::new(seed);
    let tok = ByteTokenizer::new();
    let mut out = vec![];
    let mut rejected = 0usize;
    while out.len() < n_stories {
        let sents = stories::story(&mut rng);
        let full = sents.join(" ");
        if full.len() > seq_len {
            rejected += 1;
            assert!(
                rejected < 10_000,
                "seq_len {seq_len} too small for the story corpus"
            );
            continue;
        }
        // byte ranges of each sentence within `full`
        let mut ranges = vec![];
        let mut start = 0usize;
        for s in &sents {
            ranges.push((start, start + s.len()));
            start += s.len() + 1; // the joining space
        }
        let (blank_from, blank_to) = if blank_middle_three {
            (ranges[1].0, ranges[3].1)
        } else {
            (ranges[2].0, ranges[2].1)
        };
        let reference_middle = full[blank_from..blank_to].to_string();
        let full_tokens = tok.encode_fixed(&full, seq_len);
        let mut tokens = full_tokens.clone();
        let mut visible = vec![];
        for p in 0..seq_len {
            if p >= blank_from && p < blank_to {
                tokens[p] = MASK;
            } else {
                visible.push(p);
            }
        }
        let m = visible.len();
        let ord = Ordering::new(lattice_sigma(&visible, seq_len), m);
        out.push((
            WorkItem {
                ord,
                tokens,
                reference: full_tokens,
            },
            reference_middle,
        ));
    }
    out
}

/// Decode one work item with the given sampler and a fixed draft length
/// `k`; returns outcome + seconds. See [`run_sampler_with`] for full draft
/// control (drafter kind, adaptive speculation).
pub fn run_sampler(
    engine: &dyn Engine,
    item: &WorkItem,
    sampler: SamplerKind,
    k: usize,
    steps: usize,
    temp: f32,
    seed: u64,
) -> Result<(DecodeOutcome, f64)> {
    run_sampler_with(
        engine,
        item,
        sampler,
        DraftOptions {
            max_len: k,
            ..Default::default()
        },
        steps,
        temp,
        seed,
    )
}

/// Build the decode machine a (sampler, draft, seed) combination runs —
/// shared by the compact and incremental harness drivers so path
/// comparisons start from identical machines.
pub fn build_machine(
    engine: &dyn Engine,
    item: &WorkItem,
    sampler: SamplerKind,
    draft: DraftOptions,
    steps: usize,
    temp: f32,
    seed: u64,
) -> Box<dyn crate::decode::DecodeMachine> {
    let rng = Rng::new(seed);
    let v = engine.vocab();
    match sampler {
        SamplerKind::Assd | SamplerKind::AssdNgram => Box::new(AssdMachine::from_options(
            item.ord.clone(),
            item.tokens.clone(),
            v,
            sampler.effective_draft(draft),
            engine.seq_len().min(engine.max_gather_rows()),
            temp,
            rng,
        )),
        SamplerKind::Sequential => Box::new(SequentialMachine::new(
            item.ord.clone(),
            item.tokens.clone(),
            v,
            temp,
            rng,
        )),
        SamplerKind::Diffusion => Box::new(DiffusionMachine::new(
            item.tokens.clone(),
            v,
            steps,
            temp,
            rng,
        )),
    }
}

/// Decode one work item with the given sampler and draft configuration
/// (compact forward path).
pub fn run_sampler_with(
    engine: &dyn Engine,
    item: &WorkItem,
    sampler: SamplerKind,
    draft: DraftOptions,
    steps: usize,
    temp: f32,
    seed: u64,
) -> Result<(DecodeOutcome, f64)> {
    let machine = build_machine(engine, item, sampler, draft, steps, temp, seed);
    let t0 = Instant::now();
    let outcome = run_machine(engine, machine)?;
    Ok((outcome, t0.elapsed().as_secs_f64()))
}

/// Decode one work item through the INCREMENTAL forward path, pinned to
/// `lane` (the perf_engine incremental-vs-compact ablation and the
/// equivalence tests drive this).
pub fn run_sampler_inc(
    engine: &dyn Engine,
    item: &WorkItem,
    sampler: SamplerKind,
    draft: DraftOptions,
    steps: usize,
    temp: f32,
    seed: u64,
    lane: usize,
) -> Result<(DecodeOutcome, f64)> {
    let machine = build_machine(engine, item, sampler, draft, steps, temp, seed);
    let t0 = Instant::now();
    let outcome = crate::decode::run_machine_inc(engine, machine, lane)?;
    Ok((outcome, t0.elapsed().as_secs_f64()))
}

/// Left-to-right AR baseline for infilling (Table 2's GPT row): the model
/// only receives the LEFT context (paper D.6 gives GPT only the left
/// conditioning) and decodes the blanked span sequentially left-to-right.
/// Implemented as sequential decoding where positions right of the blank
/// are also treated as targets (the model regenerates them, but only the
/// blank span is evaluated).
pub fn run_ar_left_to_right(
    engine: &dyn Engine,
    item: &WorkItem,
    temp: f32,
    seed: u64,
) -> Result<(DecodeOutcome, f64)> {
    let n = item.tokens.len();
    // first masked position
    let first_blank = (0..n).find(|&p| item.tokens[p] == MASK).unwrap_or(n);
    let visible: Vec<usize> = (0..first_blank).collect();
    let m = visible.len().max(1);
    let visible: Vec<usize> = (0..m).collect();
    let ord = Ordering::new(lattice_sigma(&visible, n), m);
    let mut tokens = item.tokens.clone();
    for p in m..n {
        tokens[p] = MASK;
    }
    // ensure prompt has no MASK (if the text starts masked, seed with PAD)
    let mut toks = tokens;
    for (pos, t) in toks.iter_mut().enumerate().take(m) {
        if *t == MASK {
            *t = PAD;
            let _ = pos;
        }
    }
    let t0 = Instant::now();
    let machine = SequentialMachine::new(ord, toks, engine.vocab(), temp, Rng::new(seed));
    let outcome = run_machine(engine, Box::new(machine))?;
    Ok((outcome, t0.elapsed().as_secs_f64()))
}

/// Extract the text at the positions that were masked in `item` from a
/// completed token buffer (for ROUGE against the reference middle).
pub fn masked_span_text(item: &WorkItem, completed: &[u32]) -> String {
    let tok = ByteTokenizer::new();
    let span: Vec<u32> = (0..item.tokens.len())
        .filter(|&p| item.tokens[p] == MASK)
        .map(|p| completed[p])
        .collect();
    tok.decode(&span)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::DraftKind;
    use crate::runtime::mock::MockEngine;

    #[test]
    fn masked_prose_workload_shapes() {
        let items = masked_prose_workload(64, 4, 0.95, 1);
        assert_eq!(items.len(), 4);
        for it in &items {
            let masked = it.tokens.iter().filter(|&&t| t == MASK).count();
            assert!((55..=63).contains(&masked), "masked={masked}");
            assert_eq!(it.reference.len(), 64);
            // reference agrees with tokens at visible positions
            for p in 0..64 {
                if it.tokens[p] != MASK {
                    assert_eq!(it.tokens[p], it.reference[p]);
                }
            }
        }
    }

    #[test]
    fn story_workload_blanks_middle() {
        let w1 = story_infill_workload(128, 3, false, 2);
        let w3 = story_infill_workload(128, 3, true, 2);
        for (it, mid) in &w1 {
            assert!(!mid.is_empty());
            let masked = it.tokens.iter().filter(|&&t| t == MASK).count();
            assert_eq!(masked, mid.len());
        }
        // 3-sentence blanks are bigger
        let m1 = w1[0].0.tokens.iter().filter(|&&t| t == MASK).count();
        let m3 = w3[0].0.tokens.iter().filter(|&&t| t == MASK).count();
        assert!(m3 > m1);
    }

    #[test]
    fn all_samplers_run_on_workload() {
        let e = MockEngine::new(1, 32, 258, 1.0);
        let items = masked_prose_workload(32, 1, 0.9, 3);
        for s in [
            SamplerKind::Sequential,
            SamplerKind::Assd,
            SamplerKind::AssdNgram,
            SamplerKind::Diffusion,
        ] {
            let (out, secs) = run_sampler(&e, &items[0], s, 5, 8, 1.0, 7).unwrap();
            assert!(out.tokens.iter().all(|&t| t != MASK), "{s:?}");
            assert!(secs >= 0.0);
        }
    }

    #[test]
    fn drafter_sweep_runs_on_workload() {
        let e = MockEngine::new(4, 32, 258, 1.0);
        let items = masked_prose_workload(32, 1, 0.9, 5);
        for kind in DraftKind::ALL {
            for adaptive in [false, true] {
                let opts = DraftOptions {
                    kind,
                    max_len: 5,
                    adaptive,
                };
                let (out, _) =
                    run_sampler_with(&e, &items[0], SamplerKind::Assd, opts, 8, 1.0, 9).unwrap();
                assert!(out.tokens.iter().all(|&t| t != MASK), "{kind:?}");
                assert_eq!(out.draft_kind, kind.name());
            }
        }
    }

    #[test]
    fn ar_baseline_runs() {
        // Stories need a >=128-byte window; use a modest vocab so the AR
        // chain (~100 sequential forwards) stays fast on the mock.
        let e = MockEngine::new(2, 160, 64, 1.0);
        let items = story_infill_workload(160, 1, false, 4);
        let (out, _) = run_ar_left_to_right(&e, &items[0].0, 1.0, 9).unwrap();
        assert!(out.tokens.iter().all(|&t| t != MASK));
    }

    #[test]
    fn masked_span_text_extracts_blank() {
        let items = story_infill_workload(128, 1, false, 5);
        let (it, mid) = &items[0];
        let text = masked_span_text(it, &it.reference);
        assert_eq!(&text, mid);
    }
}
