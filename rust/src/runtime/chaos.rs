//! Deterministic chaos injection around any [`Engine`].
//!
//! [`ChaosEngine`] wraps an inner engine and injects a SEEDED fault
//! schedule into the forward surface: transient failures, latency
//! spikes, lane-cache invalidation, and allocation exhaustion (the
//! [`FaultKind`] taxonomy). The schedule is a pure function of
//! `(seed, call index)` — no wall clock, no global RNG — so a soak run
//! is exactly reproducible from its seed, and the scheduler's recovery
//! ladder can be asserted BIT-IDENTICAL against a fault-free reference
//! run (`rust/tests/chaos_soak.rs`).
//!
//! Faults are injected BEFORE delegating to the inner engine, so a
//! failed call leaves inner state (NFE counters, cache lanes) exactly
//! as it was — the property that makes retries bit-identical and keeps
//! Theorem-2 NFE accounting honest. The two exceptions are deliberate:
//! a latency spike sleeps and then serves the call normally (the output
//! must be unaffected), and a lane invalidation resets the victim lane
//! through the inner engine's own `reset_lane` (a legitimate retire:
//! sealed prefixes stay bit-equivalent to recompute) before failing the
//! call with [`EngineError::LaneCorrupt`].
//!
//! Enabled in the serve binary via `--chaos-seed S --chaos-rate F`.

use std::cell::Cell;
use std::time::Duration;

use crate::util::rng::splitmix64;

use super::error::{EngineError, EngineResult, FaultKind};
use super::paged::KvStats;
use super::{Engine, ForwardSpec, IncSpec};

/// Seeded fault-schedule parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    /// Schedule seed: same seed + same call sequence = same faults.
    pub seed: u64,
    /// Per-forward-call fault probability in `[0, 1]`; `0.0` disables
    /// injection entirely (the wrapper becomes a transparent proxy).
    pub rate: f64,
    /// Sleep length for [`FaultKind::LatencySpike`] faults.
    pub spike: Duration,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0,
            rate: 0.0,
            spike: Duration::from_millis(1),
        }
    }
}

impl ChaosConfig {
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }
}

/// An [`Engine`] wrapper that injects the seeded fault schedule of its
/// [`ChaosConfig`] into every forward call. All non-forward methods
/// delegate untouched. Thread-pinned like every engine (`Cell`, not
/// atomics, for the call counter).
pub struct ChaosEngine {
    inner: Box<dyn Engine>,
    cfg: ChaosConfig,
    /// Forward calls seen so far — the schedule's clock.
    calls: Cell<u64>,
    /// Faults injected so far, indexed by [`FaultKind`] discriminant
    /// (transient, spike, lane, alloc).
    injected: Cell<[u64; 4]>,
}

impl ChaosEngine {
    pub fn new(inner: Box<dyn Engine>, cfg: ChaosConfig) -> ChaosEngine {
        ChaosEngine {
            inner,
            cfg,
            calls: Cell::new(0),
            injected: Cell::new([0; 4]),
        }
    }

    /// Wrap only when the config injects anything; a zero rate returns
    /// the inner engine unchanged (no proxy overhead on the hot path).
    pub fn wrap(inner: Box<dyn Engine>, cfg: ChaosConfig) -> Box<dyn Engine> {
        if cfg.enabled() {
            Box::new(ChaosEngine::new(inner, cfg))
        } else {
            inner
        }
    }

    /// Total faults injected so far (all kinds).
    pub fn faults_injected(&self) -> u64 {
        self.injected.get().iter().sum()
    }

    /// The fault (if any) scheduled for call index `call` — a pure
    /// function of `(cfg.seed, call)`.
    pub fn fault_at(&self, call: u64) -> Option<FaultKind> {
        if self.cfg.rate <= 0.0 {
            return None;
        }
        let mut s = self
            .cfg
            .seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(call)
            .wrapping_mul(0xbf58476d1ce4e5b9)
            .wrapping_add(1);
        // 53-bit uniform in [0, 1) — the standard f64 construction.
        let u = (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u >= self.cfg.rate {
            return None;
        }
        Some(match splitmix64(&mut s) % 4 {
            0 => FaultKind::TransientFailure,
            1 => FaultKind::LatencySpike {
                delay: self.cfg.spike,
            },
            2 => FaultKind::LaneInvalidation,
            _ => FaultKind::AllocExhausted,
        })
    }

    /// Advance the schedule clock and return this call's fault.
    fn tick(&self) -> Option<FaultKind> {
        let call = self.calls.get();
        self.calls.set(call + 1);
        let fault = self.fault_at(call)?;
        let mut counts = self.injected.get();
        counts[match fault {
            FaultKind::TransientFailure => 0,
            FaultKind::LatencySpike { .. } => 1,
            FaultKind::LaneInvalidation => 2,
            FaultKind::AllocExhausted => 3,
        }] += 1;
        self.injected.set(counts);
        Some(fault)
    }

    /// Resolve a scheduled fault on a LANE-LESS call (dense / compact
    /// paths): lane invalidation has no victim, so it degrades to a
    /// transient failure; a spike sleeps and lets the call proceed.
    /// Returns the error to fail with, or None to serve normally.
    fn resolve_laneless(&self, fault: FaultKind, call: u64) -> Option<EngineError> {
        match fault {
            FaultKind::LatencySpike { delay } => {
                std::thread::sleep(delay);
                None
            }
            FaultKind::AllocExhausted => Some(EngineError::transient(format!(
                "chaos: allocation exhausted (injected, call {call})"
            ))),
            FaultKind::TransientFailure | FaultKind::LaneInvalidation => Some(
                EngineError::transient(format!("chaos: injected transient fault (call {call})")),
            ),
        }
    }
}

impl Engine for ChaosEngine {
    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        let call = self.calls.get();
        if let Some(fault) = self.tick() {
            if let Some(err) = self.resolve_laneless(fault, call) {
                return Err(err);
            }
        }
        self.inner.forward(batch, tokens, mask_h, mask_g)
    }

    fn forward_ord(&self, specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        let call = self.calls.get();
        if let Some(fault) = self.tick() {
            if let Some(err) = self.resolve_laneless(fault, call) {
                return Err(err);
            }
        }
        self.inner.forward_ord(specs)
    }

    fn forward_inc(&self, specs: &[IncSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        let call = self.calls.get();
        if let Some(fault) = self.tick() {
            match fault {
                FaultKind::LaneInvalidation => {
                    // Invalidate the first lane named by the call, then
                    // fail typed so the scheduler resets + recomputes.
                    let lane = specs.first().map(|s| s.lane).unwrap_or(0);
                    self.inner.reset_lane(lane);
                    return Err(EngineError::lane_corrupt(
                        lane,
                        format!("chaos: lane cache invalidated (injected, call {call})"),
                    ));
                }
                other => {
                    if let Some(err) = self.resolve_laneless(other, call) {
                        return Err(err);
                    }
                }
            }
        }
        self.inner.forward_inc(specs)
    }

    fn max_gather_rows(&self) -> usize {
        self.inner.max_gather_rows()
    }

    fn inc_lanes(&self) -> usize {
        self.inner.inc_lanes()
    }

    fn reset_lane(&self, lane: usize) {
        self.inner.reset_lane(lane)
    }

    fn kv_stats(&self) -> Option<KvStats> {
        self.inner.kv_stats()
    }

    fn nfe(&self) -> u64 {
        self.inner.nfe()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.inner.batch_sizes()
    }
}

#[cfg(test)]
mod tests {
    use super::super::mock::MockEngine;
    use super::*;
    use crate::model::mask::Ordering as GenOrdering;

    fn chaos(rate: f64, seed: u64) -> ChaosEngine {
        ChaosEngine::new(
            Box::new(MockEngine::new(3, 16, 258, 1.0)),
            ChaosConfig {
                seed,
                rate,
                spike: Duration::from_micros(10),
            },
        )
    }

    #[test]
    fn schedule_is_deterministic_in_seed_and_call() {
        let a = chaos(0.3, 42);
        let b = chaos(0.3, 42);
        for call in 0..500 {
            assert_eq!(a.fault_at(call), b.fault_at(call));
        }
        // A different seed produces a different schedule (overwhelmingly).
        let c = chaos(0.3, 43);
        assert!((0..500).any(|call| a.fault_at(call) != c.fault_at(call)));
    }

    #[test]
    fn rate_zero_is_transparent_and_rate_scales_injection() {
        let off = chaos(0.0, 7);
        assert!((0..1000).all(|call| off.fault_at(call).is_none()));
        let on = chaos(0.25, 7);
        let hits = (0..2000).filter(|&c| on.fault_at(c).is_some()).count();
        // Loose band around 0.25 * 2000 = 500 — deterministic, so this
        // can never flake once green.
        assert!((300..700).contains(&hits), "hits = {hits}");
    }

    #[test]
    fn injected_failure_leaves_inner_state_untouched() {
        let e = chaos(1.0, 0);
        // Find a seed position whose fault is a hard failure (not a
        // spike) — with rate 1.0 every call faults.
        let ord = GenOrdering::new((0..16).collect(), 0);
        let toks = vec![1u32; 16];
        let spec = ForwardSpec {
            tokens: &toks,
            ord: &ord,
            known: 16,
            want: &[0],
        };
        let mut failures = 0;
        for _ in 0..20 {
            match e.forward_ord(std::slice::from_ref(&spec)) {
                Err(err) => {
                    failures += 1;
                    // Typed and transient: the retry ladder's contract.
                    assert_eq!(
                        err.class(),
                        super::super::error::ErrorClass::Transient,
                        "laneless faults must degrade to transient"
                    );
                }
                Ok(rows) => assert_eq!(rows[0].len(), 258),
            }
        }
        assert!(failures > 0, "rate-1.0 schedule never failed a call");
        // Failed calls never reached the inner engine: NFE counts only
        // the served (spike) calls.
        assert_eq!(e.nfe(), 20 - failures);
    }

    #[test]
    fn latency_spike_output_is_bit_identical() {
        let plain = MockEngine::new(3, 16, 258, 1.0);
        let e = chaos(1.0, 0);
        let ord = GenOrdering::new((0..16).collect(), 0);
        let toks = vec![1u32; 16];
        let spec = ForwardSpec {
            tokens: &toks,
            ord: &ord,
            known: 16,
            want: &[0, 5],
        };
        let want = plain.forward_ord(std::slice::from_ref(&spec)).unwrap();
        for _ in 0..50 {
            if let Ok(rows) = e.forward_ord(std::slice::from_ref(&spec)) {
                assert_eq!(rows, want, "spiked call altered the output");
                return;
            }
        }
        panic!("no spike (served) call in 50 tries at rate 1.0");
    }
}
