//! Engine pool: N share-nothing model replicas behind one factory.
//!
//! The PJRT client is single-threaded (`Rc` internally), so an engine can
//! never be *moved* between threads — replication instead transfers
//! *construction*: the pool holds a thread-safe factory, and each scheduler
//! worker invokes it ON its own thread, yielding a private engine whose
//! PJRT client, compiled executables, and device-resident theta are all
//! owned by that worker alone (share-nothing, mistral.rs-pipeline style).
//! Scaling the pool therefore multiplies device memory: every replica keeps
//! its own copy of theta resident.
//!
//! The pool itself performs no routing — that is the coordinator's job
//! (one shared MPMC admission queue drained by all workers, see
//! [`crate::coordinator::scheduler::spawn_pool`]). Keeping provisioning
//! (here) separate from scheduling (coordinator) lets the decode and train
//! layers reuse replica provisioning without pulling in the serving stack.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;

use super::{Engine, PagedKvConfig, XlaEngine};

/// Sizing knobs for an engine pool.
///
/// Documented invariants: replicas are fully independent (no weight
/// sharing, no cross-replica batching); a request is served end-to-end by
/// the single replica whose worker dequeued it.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Number of engine replicas (= scheduler worker threads). Each
    /// replica loads its own copy of the model, so memory scales linearly;
    /// values above the physical core count waste memory without adding
    /// throughput. Clamped to >= 1.
    pub replicas: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { replicas: 1 }
    }
}

/// A pool of lazily constructed engine replicas.
///
/// `EnginePool` is `Send + Sync` even though the engines it produces are
/// not: it stores only the factory. [`EnginePool::provision`] must be
/// called on the thread that will own the resulting engine.
pub struct EnginePool {
    cfg: PoolConfig,
    factory: Box<dyn Fn(usize) -> Result<Box<dyn Engine>> + Send + Sync>,
}

impl EnginePool {
    /// Build a pool from an arbitrary replica factory. The factory is
    /// called once per replica with the replica id (0..replicas), on the
    /// worker thread that will own the engine.
    pub fn from_fn<F>(cfg: PoolConfig, factory: F) -> EnginePool
    where
        F: Fn(usize) -> Result<Box<dyn Engine>> + Send + Sync + 'static,
    {
        EnginePool {
            cfg,
            factory: Box::new(factory),
        }
    }

    /// A pool of XLA engines, each independently loading the AOT artifact
    /// set from `artifacts_dir` (and optional checkpoint). Every replica
    /// compiles its own executables and uploads its own theta.
    pub fn xla(cfg: PoolConfig, artifacts_dir: PathBuf, params_path: Option<PathBuf>) -> EnginePool {
        Self::xla_with(cfg, artifacts_dir, params_path, None)
    }

    /// [`EnginePool::xla`] with explicit per-replica K/V pool sizing
    /// (each replica owns a private block pool + prefix cache — caches
    /// are never shared across replicas, matching the share-nothing
    /// contract above). `None` uses the engine's per-seq-len defaults.
    pub fn xla_with(
        cfg: PoolConfig,
        artifacts_dir: PathBuf,
        params_path: Option<PathBuf>,
        kv_cfg: Option<PagedKvConfig>,
    ) -> EnginePool {
        EnginePool::from_fn(cfg, move |_replica| {
            let e = XlaEngine::load_with(&artifacts_dir, params_path.as_deref(), kv_cfg)?;
            Ok(Box::new(e) as Box<dyn Engine>)
        })
    }

    /// The pool's sizing config.
    pub fn config(&self) -> PoolConfig {
        self.cfg
    }

    /// Number of replicas this pool provisions (>= 1).
    pub fn replicas(&self) -> usize {
        self.cfg.replicas.max(1)
    }

    /// Construct replica `id`'s engine. Must run on the owning thread.
    pub fn provision(&self, id: usize) -> Result<Box<dyn Engine>> {
        (self.factory)(id)
    }
}

/// Replica health, as driven by [`HealthTracker`]:
///
/// ```text
/// Healthy --errors >= degrade_after--> Degraded
/// Degraded --errors >= quarantine_after--> Quarantined
/// Healthy/Degraded --any success--> Healthy
/// ```
///
/// `Quarantined` is terminal for the current engine incarnation: the
/// worker stops serving on it and hands the replica back to the
/// supervisor for re-provisioning (see
/// [`crate::coordinator::scheduler::spawn_pool`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Degraded,
    Quarantined,
}

/// Thresholds for the health state machine, in CONSECUTIVE failed
/// batched forwards (a success resets the streak — transient blips under
/// retry never accumulate into a quarantine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HealthPolicy {
    /// Consecutive errors before the replica is marked Degraded (still
    /// serving; surfaced via `/healthz` and `/replicas`).
    pub degrade_after: u32,
    /// Consecutive errors before the replica is Quarantined and handed
    /// to the supervisor for re-provisioning.
    pub quarantine_after: u32,
}

impl Default for HealthPolicy {
    fn default() -> HealthPolicy {
        HealthPolicy {
            degrade_after: 3,
            quarantine_after: 16,
        }
    }
}

/// Worker-local health state machine (plain struct: it lives on the
/// replica's own thread; the worker mirrors transitions into the shared
/// [`crate::coordinator::ReplicaStats`] for observability).
#[derive(Debug, Clone)]
pub struct HealthTracker {
    policy: HealthPolicy,
    streak: u32,
    health: Health,
}

impl HealthTracker {
    pub fn new(policy: HealthPolicy) -> HealthTracker {
        HealthTracker {
            policy,
            streak: 0,
            health: Health::Healthy,
        }
    }

    pub fn health(&self) -> Health {
        self.health
    }

    /// A batched forward succeeded: any error streak ends and the
    /// replica recovers to Healthy (quarantine is never revoked — the
    /// worker has already stopped consulting the tracker by then).
    pub fn record_success(&mut self) -> Health {
        self.streak = 0;
        if self.health != Health::Quarantined {
            self.health = Health::Healthy;
        }
        self.health
    }

    /// A batched forward failed: advance the streak and derive the
    /// state. Called once per failed BATCHED call (not once per
    /// per-slot retry), so the thresholds count independent faults.
    pub fn record_error(&mut self) -> Health {
        self.streak = self.streak.saturating_add(1);
        self.health = if self.streak >= self.policy.quarantine_after {
            Health::Quarantined
        } else if self.streak >= self.policy.degrade_after {
            Health::Degraded
        } else {
            self.health
        };
        self.health
    }
}

/// Restart policy for the replica supervisor: how many times a dead
/// engine incarnation (fatal error, quarantine, panic, or a failed
/// provision) may be re-provisioned through the pool factory before the
/// replica is declared Failed for good.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SupervisorPolicy {
    /// Re-provision attempts per replica across its lifetime. 0 restores
    /// the pre-supervision behavior (first death is final).
    pub max_restarts: u32,
    /// Pause before each re-provision — keeps a crash-looping factory
    /// from spinning a core (kept small: tests restart in-process).
    pub restart_backoff: Duration,
}

impl Default for SupervisorPolicy {
    fn default() -> SupervisorPolicy {
        SupervisorPolicy {
            max_restarts: 2,
            restart_backoff: Duration::from_millis(10),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::MockEngine;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn mock_pool(replicas: usize) -> (EnginePool, Arc<AtomicUsize>) {
        let built = Arc::new(AtomicUsize::new(0));
        let b2 = Arc::clone(&built);
        let pool = EnginePool::from_fn(PoolConfig { replicas }, move |id| {
            b2.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(MockEngine::new(id as u64, 8, 16, 1.0)) as Box<dyn Engine>)
        });
        (pool, built)
    }

    #[test]
    fn provisions_independent_replicas() {
        let (pool, built) = mock_pool(3);
        assert_eq!(pool.replicas(), 3);
        let a = pool.provision(0).unwrap();
        let b = pool.provision(1).unwrap();
        assert_eq!(built.load(Ordering::SeqCst), 2);
        // Replicas are share-nothing: NFE counters do not alias.
        let toks = vec![0u32; 8];
        let mask = vec![0f32; 64];
        a.forward(1, &toks, &mask, &mask).unwrap();
        assert_eq!(a.nfe(), 1);
        assert_eq!(b.nfe(), 0);
    }

    #[test]
    fn zero_replicas_clamps_to_one() {
        let (pool, _) = mock_pool(0);
        assert_eq!(pool.replicas(), 1);
    }

    #[test]
    fn pool_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<EnginePool>();
    }

    #[test]
    fn health_tracker_degrades_quarantines_and_recovers() {
        let mut t = HealthTracker::new(HealthPolicy {
            degrade_after: 2,
            quarantine_after: 4,
        });
        assert_eq!(t.health(), Health::Healthy);
        assert_eq!(t.record_error(), Health::Healthy);
        assert_eq!(t.record_error(), Health::Degraded);
        // A success anywhere before quarantine fully recovers.
        assert_eq!(t.record_success(), Health::Healthy);
        assert_eq!(t.record_error(), Health::Healthy);
        assert_eq!(t.record_error(), Health::Degraded);
        assert_eq!(t.record_error(), Health::Degraded);
        assert_eq!(t.record_error(), Health::Quarantined);
        // Quarantine is terminal for this incarnation.
        assert_eq!(t.record_success(), Health::Quarantined);
    }
}
