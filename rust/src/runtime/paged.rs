//! Paged KV block pool + cross-request prefix cache (vLLM-style).
//!
//! PR 5's per-lane cache mirrors were fixed `[L, N, D]` slabs pinned for a
//! request's whole lifetime, so engine memory scaled with
//! `lanes × max_seq` and identical prompt prefixes — the common case under
//! real traffic (shared system prompts, retried infills) — were recomputed
//! from scratch every time. This module replaces the slabs with:
//!
//! * a **block allocator**: cache rows live in fixed-size blocks
//!   (`block_rows` rows of `row_width` elements each) drawn from one pool;
//!   a lane holds a *block table* (`Vec<BlockId>`) instead of a slab, so
//!   memory is bounded by the pool size, not `lanes × max_seq`;
//! * **ref counts + copy-on-write**: blocks may be shared between lanes
//!   and cache entries; appending into a shared block first copies it
//!   (the CoW rule: a block with `refs > 1` is never mutated);
//! * a **prefix cache**: at lane retirement ([`Engine::reset_lane`]) the
//!   lane's committed rows are *sealed* — the block table is retained
//!   ref-counted under a chain hash of the committed (order, token)
//!   prefix — and a later lane whose prefix hashes to a sealed entry is
//!   *seeded* from it, skipping prefill entirely;
//! * **LRU eviction**: when the free list runs dry, sealed entries are
//!   evicted least-recently-used first. Blocks referenced by an active
//!   lane always carry a lane ref, so eviction can only ever free
//!   cache-only blocks — active lanes are structurally evict-proof.
//!
//! Why the chain hash is sound (and why it is 128-bit): a cached row
//! `j`'s K/V is a pure function of `(n, m, sigma[..=j],
//! tokens[sigma[..=j]])` — prompt rows attend bidirectionally *within the
//! prompt* and committed target rows attend only to earlier orders
//! (Lemma 1), so folding exactly those inputs into the hash makes equal
//! keys imply equal K/V. Keys are 128 bits (two independent splitmix64
//! lanes) because the serving guarantee is *bit-identity*: at 2^-128
//! collision odds the cache is indistinguishable from recompute, which
//! the warm-vs-cold test battery then checks literally.
//!
//! A hit is only usable when it covers the whole prompt (`rows >= m`):
//! prompt rows are bidirectional, so a partial-prompt entry could not be
//! completed by causal appends. Entries are therefore sealed at every
//! full-block boundary `> m` plus the boundaries `m` and `cached`, and
//! lookup walks those same boundaries longest-first.
//!
//! The pool is generic over the row payload `T` so the same allocator,
//! CoW rule, and cache serve both engines: [`MockEngine`] stores one
//! `u32` token per row (its analytic "K/V"), [`XlaEngine`] stores
//! `2·L·D` f32s (K then V, all layers, one order-row).
//!
//! [`Engine::reset_lane`]: super::Engine::reset_lane
//! [`MockEngine`]: super::mock::MockEngine
//! [`XlaEngine`]: super::XlaEngine

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::model::mask::Ordering;

/// 128-bit prefix chain hash (see module docs for the collision budget).
pub type PrefixKey = u128;

#[inline]
fn mix64(x: u64) -> u64 {
    // splitmix64 finalizer (Steele et al.) — same mixer as util::rng.
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Fold one 64-bit word into a 128-bit chain state: the two halves are
/// mixed with independent constants so they behave as two independent
/// 64-bit hashes of the same prefix.
#[inline]
pub fn chain_fold(h: PrefixKey, x: u64) -> PrefixKey {
    let lo = mix64((h as u64) ^ x);
    let hi = mix64(((h >> 64) as u64) ^ x.wrapping_mul(0xc2b2ae3d27d4eb4f) ^ 0x165667b19e3779f9);
    ((hi as u128) << 64) | lo as u128
}

/// Per-order chain hashes for a committed prefix: `out[j]` keys rows
/// `0..=j`. The seed folds `(n, m)`; each link folds `(sigma[j],
/// tokens[sigma[j]])` — exactly the inputs a cached row's K/V is a
/// function of (module docs). `tokens` is position-indexed, as in
/// [`super::ForwardSpec`].
pub fn chain_hashes(ord: &Ordering, tokens: &[u32], committed: usize) -> Vec<PrefixKey> {
    let mut h = chain_fold(chain_fold(0x243f6a8885a308d3, ord.n() as u64), ord.m as u64);
    let mut out = Vec::with_capacity(committed);
    for j in 0..committed {
        let pos = ord.sigma[j];
        h = chain_fold(chain_fold(h, pos as u64), tokens[pos] as u64);
        out.push(h);
    }
    out
}

/// Extend a chain by one committed row (used on incremental appends so
/// the full chain never needs recomputing).
#[inline]
pub fn chain_extend(h: PrefixKey, pos: usize, tok: u32) -> PrefixKey {
    chain_fold(chain_fold(h, pos as u64), tok as u64)
}

/// Pool sizing knobs (the `--block-size` / `--cache-blocks` serving
/// flags land here).
#[derive(Clone, Copy, Debug)]
pub struct PagedKvConfig {
    /// Rows (orders) per block. Smaller blocks seal/seed at finer
    /// granularity but cost more table entries per lane.
    pub block_rows: usize,
    /// Total blocks in the pool — THE engine memory bound. Active lanes
    /// draw from the same pool as sealed prefixes; sizing below
    /// `lanes × ceil(N / block_rows)` reduces the number of lanes the
    /// scheduler will admit concurrently (block-budget admission).
    pub total_blocks: usize,
}

impl PagedKvConfig {
    /// Default sizing for a sequence length: blocks of 16 rows, room for
    /// 8 worst-case lanes (4 active at the default `--max-batch`, the
    /// rest prefix-cache headroom).
    pub fn for_seq_len(n: usize) -> PagedKvConfig {
        PagedKvConfig {
            block_rows: 0,
            total_blocks: 0,
        }
        .normalized(n)
    }

    /// Resolve partial sizing against a sequence length: 0 in either
    /// field derives the [`PagedKvConfig::for_seq_len`] default for `n`
    /// (so `--block-size` and `--cache-blocks` can be set independently),
    /// and `block_rows` is clamped to the window — larger blocks would
    /// only waste payload.
    pub fn normalized(self, n: usize) -> PagedKvConfig {
        let block_rows = match self.block_rows {
            0 => 16.min(n.max(1)),
            b => b.min(n.max(1)),
        };
        let total_blocks = match self.total_blocks {
            0 => 8 * n.div_ceil(block_rows),
            t => t,
        };
        PagedKvConfig {
            block_rows,
            total_blocks,
        }
    }
}

/// Block-pool occupancy + prefix-cache counters, surfaced through
/// [`super::Engine::kv_stats`] into `/metrics` and `/replicas`, and used
/// by the scheduler's block-budget admission gate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvStats {
    pub block_rows: usize,
    pub total_blocks: usize,
    pub free_blocks: usize,
    /// Blocks referenced by at least one sealed prefix entry.
    pub cached_blocks: usize,
    /// Cached blocks whose ONLY references are sealed entries — what
    /// eviction could reclaim right now.
    pub evictable_blocks: usize,
    /// Live sealed entries.
    pub sealed_entries: usize,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// Sealed entries evicted under allocation pressure.
    pub evictions: u64,
    pub cow_copies: u64,
}

impl KvStats {
    /// Worst-case blocks one lane of an `n`-row sequence can hold.
    pub fn blocks_per_seq(&self, n: usize) -> usize {
        n.div_ceil(self.block_rows.max(1))
    }

    /// How many worst-case lanes the pool can back concurrently (>= 1 so
    /// a deliberately tiny pool degrades to serial serving rather than a
    /// dead scheduler; a pool smaller than one sequence then fails the
    /// request with a pool-exhausted error instead).
    pub fn lane_budget(&self, n: usize) -> usize {
        (self.total_blocks / self.blocks_per_seq(n).max(1)).max(1)
    }

    /// Difference of the MONOTONE counters against an earlier snapshot
    /// (gauges are copied through unchanged). This is the delta-fold
    /// seam the scheduler uses to turn engine-cumulative counters into
    /// pool-level increments without double counting across replicas —
    /// the same snapshot `prev` must be updated to `self` by the caller
    /// after folding.
    pub fn delta(&self, prev: &KvStats) -> KvStats {
        KvStats {
            prefix_hits: self.prefix_hits - prev.prefix_hits,
            prefix_misses: self.prefix_misses - prev.prefix_misses,
            evictions: self.evictions - prev.evictions,
            cow_copies: self.cow_copies - prev.cow_copies,
            ..*self
        }
    }
}

/// One sealed prefix entry: a retained block table covering committed
/// rows `0..rows`, LRU-stamped.
struct SealedEntry {
    blocks: Vec<usize>,
    rows: usize,
    tick: u64,
}

/// The paged block pool + prefix cache. One per engine; engines wrap it
/// in the same `RefCell` discipline as the lane maps (never contended —
/// engines are thread-pinned).
pub struct PagedKv<T> {
    block_rows: usize,
    row_width: usize,
    /// `[total_blocks, block_rows, row_width]`, flat.
    payload: Vec<T>,
    /// Total references per block: lane tables + sealed entries.
    refs: Vec<u32>,
    /// References from sealed entries only (`cache_refs[b] <= refs[b]`);
    /// a block with `refs == cache_refs > 0` is evictable.
    cache_refs: Vec<u32>,
    free: Vec<usize>,
    sealed: HashMap<PrefixKey, SealedEntry>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    cow_copies: u64,
}

impl<T: Copy + Default> PagedKv<T> {
    pub fn new(cfg: PagedKvConfig, row_width: usize) -> PagedKv<T> {
        let block_rows = cfg.block_rows.max(1);
        let total = cfg.total_blocks.max(1);
        PagedKv {
            block_rows,
            row_width: row_width.max(1),
            payload: vec![T::default(); total * block_rows * row_width.max(1)],
            refs: vec![0; total],
            cache_refs: vec![0; total],
            // pop() order matches ascending ids for determinism
            free: (0..total).rev().collect(),
            sealed: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            cow_copies: 0,
        }
    }

    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    #[inline]
    fn row_slice(&self, block: usize, slot: usize) -> &[T] {
        let off = (block * self.block_rows + slot) * self.row_width;
        &self.payload[off..off + self.row_width]
    }

    /// Allocate one block, evicting LRU sealed prefixes under pressure.
    /// Never touches a block with a non-cache reference (active lanes
    /// keep `refs > cache_refs`).
    fn alloc_block(&mut self) -> Result<usize> {
        loop {
            if let Some(b) = self.free.pop() {
                debug_assert_eq!(self.refs[b], 0, "free block with live refs");
                self.refs[b] = 1;
                return Ok(b);
            }
            if !self.evict_lru() {
                bail!(
                    "KV block pool exhausted ({} blocks of {} rows, nothing evictable) — \
                     raise --cache-blocks or lower --max-batch",
                    self.refs.len(),
                    self.block_rows
                );
            }
        }
    }

    /// Evict the least-recently-used sealed entry. Returns false when no
    /// entry remains. May free zero blocks (all shared with live lanes
    /// or other entries) — callers loop.
    fn evict_lru(&mut self) -> bool {
        let Some(key) = self
            .sealed
            .iter()
            .min_by_key(|(_, e)| e.tick)
            .map(|(k, _)| *k)
        else {
            return false;
        };
        let entry = self.sealed.remove(&key).expect("key just observed");
        for b in entry.blocks {
            self.cache_refs[b] -= 1;
            self.release_block(b);
        }
        self.evictions += 1;
        true
    }

    fn release_block(&mut self, b: usize) {
        assert!(self.refs[b] > 0, "double-free of block {b}");
        self.refs[b] -= 1;
        if self.refs[b] == 0 {
            debug_assert_eq!(self.cache_refs[b], 0, "cache ref outliving total refs");
            self.free.push(b);
        }
    }

    /// Read row `row` (a committed order index) through a block table.
    pub fn read_row(&self, table: &[usize], row: usize) -> &[T] {
        let block = table[row / self.block_rows];
        self.row_slice(block, row % self.block_rows)
    }

    /// Get the writable slice for row `row`, extending the table and
    /// applying copy-on-write as needed. Rows must be appended in order
    /// (`row < table.len() * block_rows + block_rows`); the CoW rule —
    /// never mutate a block with `refs > 1` — is enforced here, so
    /// callers cannot violate it.
    pub fn append_row(&mut self, table: &mut Vec<usize>, row: usize) -> Result<&mut [T]> {
        let idx = row / self.block_rows;
        assert!(
            idx <= table.len(),
            "non-contiguous append: row {row} into a {}-block table",
            table.len()
        );
        if idx == table.len() {
            table.push(self.alloc_block()?);
        }
        let mut block = table[idx];
        if self.refs[block] > 1 {
            // Shared with a sealed entry (or another lane seeded from the
            // same prefix): copy before writing.
            let fresh = self.alloc_block()?;
            let (src, dst) = (
                block * self.block_rows * self.row_width,
                fresh * self.block_rows * self.row_width,
            );
            let plane = self.block_rows * self.row_width;
            self.payload.copy_within(src..src + plane, dst);
            self.release_block(block);
            table[idx] = fresh;
            block = fresh;
            self.cow_copies += 1;
        }
        let off = (block * self.block_rows + row % self.block_rows) * self.row_width;
        Ok(&mut self.payload[off..off + self.row_width])
    }

    /// Release a lane's block table back to the pool (blocks shared with
    /// sealed entries survive under their cache refs).
    pub fn release_table(&mut self, table: &mut Vec<usize>) {
        for b in table.drain(..) {
            self.release_block(b);
        }
    }

    /// Seal a retiring lane's committed rows into the prefix cache: one
    /// entry per usable boundary (full blocks past the prompt, plus the
    /// prompt boundary `m` and the final `cached` row count). Boundaries
    /// below `m` are never usable (bidirectional prompt; module docs) so
    /// they are not sealed. Blocks gain one cache ref per entry.
    pub fn seal(&mut self, table: &[usize], chain: &[PrefixKey], m: usize, cached: usize) {
        debug_assert!(chain.len() >= cached, "chain shorter than cached rows");
        if cached == 0 || m == 0 || cached < m {
            return; // nothing reusable (m == 0: no prompt to key on)
        }
        for b in self.boundaries(m, cached) {
            let key = chain[b - 1];
            let tick = self.next_tick();
            if let Some(e) = self.sealed.get_mut(&key) {
                // Same prefix already cached (hash-equal => bit-equal
                // K/V): just refresh recency.
                e.tick = tick;
                continue;
            }
            let blocks: Vec<usize> = table[..b.div_ceil(self.block_rows)].to_vec();
            for &blk in &blocks {
                self.refs[blk] += 1;
                self.cache_refs[blk] += 1;
            }
            self.sealed.insert(key, SealedEntry { blocks, rows: b, tick });
        }
    }

    /// Usable seal/lookup boundaries for a (prompt `m`, committed `c`)
    /// pair, ascending: `m`, every full-block edge in `(m, c)`, and `c`.
    fn boundaries(&self, m: usize, c: usize) -> Vec<usize> {
        let mut out = vec![m];
        let mut b = (m / self.block_rows + 1) * self.block_rows;
        while b < c {
            out.push(b);
            b += self.block_rows;
        }
        if c > m {
            out.push(c);
        }
        out
    }

    /// Look up the longest sealed prefix covering `>= m` of this chain's
    /// rows. On a hit, returns a retained clone of the entry's block
    /// table plus the row count it covers — the caller owns the new refs
    /// and MUST eventually `release_table` them. Counts a hit/miss.
    pub fn lookup(&mut self, chain: &[PrefixKey], m: usize, committed: usize) -> Option<(Vec<usize>, usize)> {
        if m == 0 || committed < m {
            return None; // unkeyable — not a cache decision, no miss count
        }
        for b in self.boundaries(m, committed).into_iter().rev() {
            let tick = self.next_tick();
            if let Some(entry) = self.sealed.get_mut(&chain[b - 1]) {
                if entry.rows != b {
                    continue; // 128-bit collision backstop
                }
                entry.tick = tick;
                let blocks = entry.blocks.clone();
                for &blk in &blocks {
                    self.refs[blk] += 1;
                }
                self.hits += 1;
                return Some((blocks, b));
            }
        }
        self.misses += 1;
        None
    }

    /// Drop every sealed entry (param swaps invalidate all cached K/V).
    pub fn clear_sealed(&mut self) {
        let keys: Vec<PrefixKey> = self.sealed.keys().copied().collect();
        for key in keys {
            let entry = self.sealed.remove(&key).expect("key just listed");
            for b in entry.blocks {
                self.cache_refs[b] -= 1;
                self.release_block(b);
            }
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    pub fn stats(&self) -> KvStats {
        let cached = self.cache_refs.iter().filter(|&&c| c > 0).count();
        let evictable = self
            .refs
            .iter()
            .zip(&self.cache_refs)
            .filter(|&(&r, &c)| c > 0 && r == c)
            .count();
        KvStats {
            block_rows: self.block_rows,
            total_blocks: self.refs.len(),
            free_blocks: self.free.len(),
            cached_blocks: cached,
            evictable_blocks: evictable,
            sealed_entries: self.sealed.len(),
            prefix_hits: self.hits,
            prefix_misses: self.misses,
            evictions: self.evictions,
            cow_copies: self.cow_copies,
        }
    }

    /// Full-pool invariant audit for the property-test battery. `tables`
    /// is every live lane block table. Checks: no block is both free and
    /// referenced; refcount(block) == lane references + sealed-entry
    /// references exactly; free list has no duplicates; every block is
    /// accounted (free or referenced) — i.e. zero leaks.
    pub fn check_invariants(&self, tables: &[&[usize]]) -> std::result::Result<(), String> {
        let total = self.refs.len();
        let mut expected = vec![0u32; total];
        let mut expected_cache = vec![0u32; total];
        for t in tables {
            for &b in *t {
                if b >= total {
                    return Err(format!("table references out-of-range block {b}"));
                }
                expected[b] += 1;
            }
        }
        for e in self.sealed.values() {
            for &b in &e.blocks {
                expected[b] += 1;
                expected_cache[b] += 1;
            }
        }
        let mut seen_free = vec![false; total];
        for &b in &self.free {
            if seen_free[b] {
                return Err(format!("block {b} appears twice in the free list"));
            }
            seen_free[b] = true;
        }
        for b in 0..total {
            if self.refs[b] != expected[b] {
                return Err(format!(
                    "refcount({b}) = {} but {} references exist",
                    self.refs[b], expected[b]
                ));
            }
            if self.cache_refs[b] != expected_cache[b] {
                return Err(format!(
                    "cache_refs({b}) = {} but {} sealed references exist",
                    self.cache_refs[b], expected_cache[b]
                ));
            }
            if seen_free[b] && self.refs[b] != 0 {
                return Err(format!("block {b} is free AND referenced"));
            }
            if !seen_free[b] && self.refs[b] == 0 {
                return Err(format!("block {b} leaked (unreferenced, not free)"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, rng::Rng};

    fn pool(total: usize, rows: usize) -> PagedKv<u32> {
        PagedKv::new(
            PagedKvConfig {
                block_rows: rows,
                total_blocks: total,
            },
            1,
        )
    }

    #[test]
    fn append_read_roundtrip_across_blocks() {
        let mut kv = pool(4, 2);
        let mut table = vec![];
        for row in 0..7 {
            kv.append_row(&mut table, row).unwrap()[0] = 100 + row as u32;
        }
        assert_eq!(table.len(), 4);
        for row in 0..7 {
            assert_eq!(kv.read_row(&table, row)[0], 100 + row as u32);
        }
        kv.release_table(&mut table);
        assert_eq!(kv.stats().free_blocks, 4);
    }

    #[test]
    fn pool_exhaustion_errors_instead_of_corrupting() {
        let mut kv = pool(2, 2);
        let mut table = vec![];
        for row in 0..4 {
            kv.append_row(&mut table, row).unwrap()[0] = row as u32;
        }
        let err = kv.append_row(&mut table, 4).unwrap_err().to_string();
        assert!(err.contains("exhausted"), "got: {err}");
        // The failed append must not have grown the table.
        kv.check_invariants(&[&table]).unwrap();
    }

    #[test]
    fn cow_preserves_sealed_payload() {
        let mut kv = pool(8, 4);
        let ord = Ordering::new((0..8).collect(), 2);
        let tokens = vec![7u32, 8, 9, 10, 11, 12, 13, 14];
        let chain = chain_hashes(&ord, &tokens, 2);
        let mut table = vec![];
        for row in 0..2 {
            kv.append_row(&mut table, row).unwrap()[0] = tokens[row];
        }
        kv.seal(&table, &chain, 2, 2);
        kv.release_table(&mut table);
        // A lane seeded from the sealed entry shares its (partial) block;
        // appending row 2 lands IN that shared block and must CoW —
        // never mutate the sealed original.
        let (mut lane2, rows) = kv.lookup(&chain, 2, 2).expect("hit");
        assert_eq!(rows, 2);
        let shared_block = lane2[0];
        kv.append_row(&mut lane2, 2).unwrap()[0] = 99;
        assert_ne!(lane2[0], shared_block, "CoW must have replaced the block");
        assert_eq!(kv.stats().cow_copies, 1);
        // Sealed payload intact: a second seeded lane still reads 7, 8.
        let (lane3, _) = kv.lookup(&chain, 2, 2).expect("second hit");
        assert_eq!(kv.read_row(&lane3, 0)[0], 7);
        assert_eq!(kv.read_row(&lane3, 1)[0], 8);
        // And the CoW copy carried the prefix payload over.
        assert_eq!(kv.read_row(&lane2, 0)[0], 7);
        assert_eq!(kv.read_row(&lane2, 2)[0], 99);
        kv.check_invariants(&[&lane2, &lane3]).unwrap();
    }

    #[test]
    fn seal_lookup_hit_requires_full_prompt() {
        let mut kv = pool(8, 4);
        let ord = Ordering::new((0..8).collect(), 6);
        let tokens: Vec<u32> = (0..8).map(|i| i as u32 + 1).collect();
        let chain = chain_hashes(&ord, &tokens, 8);
        let mut table = vec![];
        for row in 0..8 {
            kv.append_row(&mut table, row).unwrap()[0] = tokens[row];
        }
        kv.seal(&table, &chain, 6, 8);
        kv.release_table(&mut table);
        // Same prompt, fresh request at committed == m == 6: boundary 6
        // must hit even though 6 is not block-aligned.
        let (mut t2, rows) = kv.lookup(&chain, 6, 6).expect("prompt-boundary hit");
        assert_eq!(rows, 6);
        kv.release_table(&mut t2);
        // A request whose prompt extends PAST the sealed rows (m = 7
        // boundary was never sealed under these keys… chain differs at
        // seed anyway — emulate by asking for m larger than any entry).
        let ord_b = Ordering::new((0..8).collect(), 7);
        let chain_b = chain_hashes(&ord_b, &tokens, 8);
        assert!(kv.lookup(&chain_b, 7, 7).is_none(), "different m must miss");
        let s = kv.stats();
        assert_eq!(s.prefix_hits, 1);
        assert_eq!(s.prefix_misses, 1);
    }

    #[test]
    fn eviction_frees_lru_entry_only_and_never_active_blocks() {
        let mut kv = pool(4, 2);
        let ord = Ordering::new((0..4).collect(), 2);
        // Seal two distinct 1-block prompts (m=2, block_rows=2).
        let mut chains = vec![];
        for tok0 in [1u32, 2] {
            let tokens = vec![tok0, 5, 0, 0];
            let chain = chain_hashes(&ord, &tokens, 2);
            let mut t = vec![];
            for row in 0..2 {
                kv.append_row(&mut t, row).unwrap()[0] = tokens[row];
            }
            kv.seal(&t, &chain, 2, 2);
            kv.release_table(&mut t);
            chains.push(chain);
        }
        assert_eq!(kv.stats().free_blocks, 2);
        // Touch entry 0 so entry 1 is the LRU.
        let (mut t0, _) = kv.lookup(&chains[0], 2, 2).expect("hit");
        // An active 6-row lane needs 3 blocks: 2 from the free list plus
        // 1 eviction. The evictor must pick entry 1 (the LRU) — and if it
        // wrongly picked entry 0 first, its block is pinned by t0's
        // active ref, so a second eviction would show up in the counter.
        let mut lane = vec![];
        for row in 0..6 {
            kv.append_row(&mut lane, row).unwrap()[0] = 9;
        }
        let s = kv.stats();
        assert_eq!(s.evictions, 1, "exactly the LRU entry evicted");
        // Entry 0's block is still shared with t0 (active ref): it was
        // NOT freed even if its entry were evicted later.
        assert!(kv.lookup(&chains[1], 2, 2).is_none(), "LRU entry gone");
        kv.check_invariants(&[&t0, &lane]).unwrap();
        kv.release_table(&mut t0);
        kv.release_table(&mut lane);
    }

    #[test]
    fn clear_sealed_releases_everything() {
        let mut kv = pool(6, 2);
        let ord = Ordering::new((0..4).collect(), 2);
        for tok0 in [1u32, 2, 3] {
            let tokens = vec![tok0, 5, 0, 0];
            let chain = chain_hashes(&ord, &tokens, 2);
            let mut t = vec![];
            for row in 0..2 {
                kv.append_row(&mut t, row).unwrap()[0] = tokens[row];
            }
            kv.seal(&t, &chain, 2, 2);
            kv.release_table(&mut t);
        }
        assert_eq!(kv.stats().sealed_entries, 3);
        kv.clear_sealed();
        let s = kv.stats();
        assert_eq!((s.sealed_entries, s.cached_blocks, s.free_blocks), (0, 0, 6));
        kv.check_invariants(&[]).unwrap();
    }

    #[test]
    fn chain_hash_distinguishes_order_tokens_and_prompt_size() {
        let sigma: Vec<usize> = (0..6).collect();
        let sigma_swapped = vec![1usize, 0, 2, 3, 4, 5];
        let tokens = vec![3u32, 4, 5, 6, 7, 8];
        let a = chain_hashes(&Ordering::new(sigma.clone(), 2), &tokens, 4);
        let b = chain_hashes(&Ordering::new(sigma_swapped, 2), &tokens, 4);
        let c = chain_hashes(&Ordering::new(sigma.clone(), 3), &tokens, 4);
        let mut t2 = tokens.clone();
        t2[0] = 9;
        let d = chain_hashes(&Ordering::new(sigma, 2), &t2, 4);
        assert_ne!(a[3], b[3], "sigma permutation must change the key");
        assert_ne!(a[3], c[3], "prompt size must change the key");
        assert_ne!(a[3], d[3], "token value must change the key");
        // Deterministic: same inputs, same chain.
        let a2 = chain_hashes(&Ordering::new((0..6).collect(), 2), &tokens, 4);
        assert_eq!(a, a2);
        // chain_extend agrees with the batch recomputation link by link.
        let mut h = a[0];
        for j in 1..4 {
            h = chain_extend(h, j, tokens[j]);
            assert_eq!(h, a[j]);
        }
    }

    /// One step of the random schedule the property battery replays.
    #[derive(Clone, Debug)]
    enum Op {
        /// Append the next row to lane (i % lanes).
        Append(usize),
        /// Release lane (i % lanes)'s table without sealing.
        Free(usize),
        /// Seal lane (i % lanes) then release it (a retire).
        SealRetire(usize),
        /// Fork: look up lane (i % lanes)'s chain from the cache into a
        /// fresh seeded lane replacing it (tests shared-block refs).
        Fork(usize),
    }

    /// Random alloc/fork/append/free schedules uphold the pool
    /// invariants at EVERY step: no double-free (release_block asserts),
    /// refcount(block) == number of referencing tables + sealed entries,
    /// CoW never mutates a shared block (checked via payload probes),
    /// and the pool leaks zero blocks after full churn.
    #[test]
    fn prop_random_schedules_uphold_pool_invariants() {
        const LANES: usize = 3;
        propcheck::check(
            41,
            60,
            |r: &mut Rng| {
                let n_ops = r.range(4, 40);
                let ops: Vec<Op> = (0..n_ops)
                    .map(|_| match r.below(8) {
                        0 | 1 | 2 | 3 => Op::Append(r.below(LANES)),
                        4 => Op::Free(r.below(LANES)),
                        5 | 6 => Op::SealRetire(r.below(LANES)),
                        _ => Op::Fork(r.below(LANES)),
                    })
                    .collect();
                (r.next_u64(), ops)
            },
            |(seed, ops)| run_schedule(*seed, ops),
            |(seed, ops)| {
                propcheck::shrink_vec(ops)
                    .into_iter()
                    .map(|o| (*seed, o))
                    .collect()
            },
        );
    }

    fn run_schedule(seed: u64, ops: &[Op]) -> std::result::Result<(), String> {
        const N: usize = 12;
        const M: usize = 2;
        let mut kv = pool(10, 3);
        let ord = Ordering::new((0..N).collect(), M);
        // Per-lane state: (table, chain, rows, tokens). Tokens are the
        // lane id hashed with the fork generation so forked prefixes
        // collide across lanes deliberately.
        let mut rng = Rng::new(seed);
        struct Lane {
            table: Vec<usize>,
            chain: Vec<PrefixKey>,
            rows: usize,
            tokens: Vec<u32>,
        }
        let fresh = |rng: &mut Rng| {
            // Tiny alphabet so independently drawn lanes share prefixes
            // often — forks then genuinely exercise shared-block refs.
            let tokens: Vec<u32> = (0..N).map(|_| rng.below(2) as u32).collect();
            Lane {
                table: vec![],
                chain: vec![],
                rows: 0,
                tokens,
            }
        };
        let mut lanes: Vec<Lane> = (0..3).map(|_| fresh(&mut rng)).collect();
        for op in ops {
            match op {
                Op::Append(l) => {
                    let lane = &mut lanes[*l];
                    if lane.rows >= N {
                        continue;
                    }
                    let row = lane.rows;
                    let tok = lane.tokens[row];
                    match kv.append_row(&mut lane.table, row) {
                        Ok(slice) => slice[0] = tok,
                        Err(_) => continue, // pool pressure: legitimate
                    }
                    // Cross-check the incremental link against the batch
                    // recomputation while we extend the chain.
                    let full = chain_hashes(&ord, &lane.tokens, row + 1);
                    let link = if row == 0 {
                        full[0]
                    } else {
                        chain_extend(lane.chain[row - 1], ord.sigma[row], tok)
                    };
                    if link != full[row] {
                        return Err("chain_extend diverges from chain_hashes".into());
                    }
                    lane.chain.push(link);
                    lane.rows += 1;
                }
                Op::Free(l) => {
                    let lane = &mut lanes[*l];
                    kv.release_table(&mut lane.table);
                    lanes[*l] = fresh(&mut rng);
                }
                Op::SealRetire(l) => {
                    let lane = &mut lanes[*l];
                    kv.seal(&lane.table, &lane.chain, M, lane.rows);
                    kv.release_table(&mut lane.table);
                    lanes[*l] = fresh(&mut rng);
                }
                Op::Fork(l) => {
                    let chain = lanes[*l].chain.clone();
                    let rows = lanes[*l].rows;
                    let tokens = lanes[*l].tokens.clone();
                    if let Some((t, covered)) = kv.lookup(&chain, M, rows) {
                        let old = &mut lanes[*l];
                        kv.release_table(&mut old.table);
                        lanes[*l] = Lane {
                            table: t,
                            chain: chain[..covered].to_vec(),
                            rows: covered,
                            tokens,
                        };
                    }
                }
            }
            // CoW probe: every lane's payload must still read back its
            // own tokens (a CoW bug that mutates a shared block shows up
            // as another lane's token appearing here).
            for lane in &lanes {
                for row in 0..lane.rows {
                    let got = kv.read_row(&lane.table, row)[0];
                    if got != lane.tokens[row] {
                        return Err(format!(
                            "payload corrupted: row {row} reads {got}, expected {} \
                             (CoW mutated a shared block?)",
                            lane.tokens[row]
                        ));
                    }
                }
            }
            let tables: Vec<&[usize]> = lanes.iter().map(|l| l.table.as_slice()).collect();
            kv.check_invariants(&tables)?;
        }
        // Full churn: release every lane and drop the cache — the pool
        // must end exactly full, i.e. zero leaked blocks.
        for lane in &mut lanes {
            kv.release_table(&mut lane.table);
        }
        kv.clear_sealed();
        let s = kv.stats();
        if s.free_blocks != s.total_blocks {
            return Err(format!(
                "leak: {} of {} blocks free after full churn",
                s.free_blocks, s.total_blocks
            ));
        }
        kv.check_invariants(&[])
    }
}
