//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! request path. Wraps the `xla` crate (PJRT C API, CPU plugin).
//!
//! Everything the decoders and the trainer need is behind the [`Engine`]
//! trait so that the coordinator and the decode algorithms can be tested
//! hermetically against [`mock::MockEngine`] (an analytic log-linear model
//! with exact conditionals) without compiled artifacts.

pub mod chaos;
pub mod engine;
pub mod error;
pub mod mock;
pub mod paged;
pub mod pool;

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::mask::{draft_masks_into, Ordering};

pub use chaos::{ChaosConfig, ChaosEngine};
pub use engine::{TrainOutput, XlaEngine};
pub use error::{EngineError, EngineResult, ErrorClass, FaultKind};
pub use paged::{KvStats, PagedKvConfig};
pub use pool::{EnginePool, Health, HealthPolicy, HealthTracker, PoolConfig, SupervisorPolicy};

/// One sequence's COMPACT forward request: instead of materialized
/// `[N, N]` attention masks, it carries the generation ordering and decode
/// state the masks are pure functions of (paper §3, Lemma 1), plus the
/// logit rows the caller will actually read. This is the ABI the decode
/// state machines speak (`decode::ForwardRequest` is an alias) and the
/// engines consume via [`Engine::forward_ord`].
#[derive(Clone, Copy)]
pub struct ForwardSpec<'a> {
    /// Full-sequence token ids, `[N]`.
    pub tokens: &'a [u32],
    /// The generation ordering (sigma, position -> order, prompt size m).
    pub ord: &'a Ordering,
    /// Decode state: orders `< known` hold committed tokens.
    /// `known == ord.n()` yields the verify masks (Fig. 1b);
    /// `ord.m <= known < ord.n()` the draft masks at that state (Fig. 1a)
    /// — one parameterization covers both families because
    /// `draft_masks(ord, N) == verify_masks(ord)`.
    pub known: usize,
    /// Positions whose logit rows to return, in exactly the order the
    /// caller's `absorb` will read them. Must be non-empty.
    pub want: &'a [usize],
}

/// One sequence's INCREMENTAL forward request: a [`ForwardSpec`] plus the
/// cache-lane bookkeeping the engine needs to reuse the sequence's
/// persistent per-layer content-stream K/V (see docs/ARCHITECTURE.md
/// §Incremental forward & KV cache). Valid only for machines whose
/// generation ordering is FIXED for the request's lifetime
/// ([`crate::decode::DecodeMachine::incremental`]).
#[derive(Clone, Copy)]
pub struct IncSpec<'a> {
    pub spec: ForwardSpec<'a>,
    /// Orders `< committed` hold FINAL token values in `spec.tokens`
    /// (accepted or resampled — never an unverified draft). The engine
    /// appends rows `lane.cached..committed` to the lane cache before
    /// computing the wanted rows. Monotone per lane between resets;
    /// always `ord.m <= committed <= known`.
    pub committed: usize,
    /// Cache lane this sequence is pinned to for its lifetime (the
    /// scheduler's batch-slot index). The scheduler calls
    /// [`Engine::reset_lane`] when a slot is (re)assigned, so a lane
    /// never leaks state across requests.
    pub lane: usize,
}

/// The forward interface the decoders run against.
///
/// The COMPACT path ([`Engine::forward_ord`]) is what the decode machines
/// and the scheduler use: per sequence it ships O(N) indices host→device
/// and returns only the requested logit rows (O(R·V)) device→host. The
/// INCREMENTAL path ([`Engine::forward_inc`]) additionally reuses each
/// sequence's cached content-stream K/V so the device computes only the
/// newly-committed and wanted rows — O(R·(C+R)·d) instead of O(N²·d) per
/// iteration. The dense [`Engine::forward`] contract (`tokens` row-major
/// [batch, N] u32; `mask_h`/`mask_g` row-major [batch, N, N], 1.0 =
/// may-attend; returns logits [batch, N, V]) remains the substrate for
/// training, density evaluation (eval/ppl.rs), and the fallback ladder's
/// floor (inc → ord → dense).
///
/// NOTE: deliberately NOT `Send` — the PJRT client is single-threaded
/// (`Rc` internally). Ownership transfer to a worker thread happens at
/// CONSTRUCTION time instead: a scheduler worker invokes an
/// [`pool::EnginePool`] factory (`Send + Sync`) on its own thread and owns
/// the resulting engine for its lifetime. The coordinator serves
/// concurrent requests to the worker(s) through the shared admission
/// queue (see coordinator/).
/// All three forward entry points return [`EngineResult`] — a typed
/// taxonomy (transient / lane-corrupt / fatal, see [`error`]) the
/// scheduler's fault-isolation ladder routes on. Engine internals may
/// keep using `anyhow` and convert at the boundary with
/// [`EngineError::from_anyhow`], which preserves the class of any
/// `EngineError` buried in the chain.
pub trait Engine {
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>>;

    /// Compact batched forward: one entry per sequence, returning for each
    /// spec the gathered logit rows (`spec.want.len() * vocab` f32s,
    /// row-major in `want` order). NFE accounting follows
    /// [`Engine::forward`]'s convention: one underlying executable launch
    /// = one network function evaluation — a batch that fits one compiled
    /// variant counts 1 on either path, while batches the engine has to
    /// split (larger than the biggest variant, or mixed compact/dense
    /// routing) count one per launch, exactly as the dense path's
    /// chunking always has.
    ///
    /// The default implementation routes through [`forward_ord_dense`]
    /// (materialize masks host-side, run the dense forward, gather rows)
    /// so every engine is correct by construction; engines with a cheaper
    /// native path override it (MockEngine computes only the wanted rows;
    /// XlaEngine executes `fwd_ord_b{B}` artifacts that rebuild the masks
    /// on device and gather before crossing back to the host).
    fn forward_ord(&self, specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        forward_ord_dense(self, specs)
    }

    /// Largest `want` length the engine's NATIVE compact path can serve in
    /// one call (`usize::MAX` when unbounded, e.g. the dense fallback).
    /// The scheduler clamps speculation windows to this so compact
    /// artifacts are never bypassed mid-request.
    fn max_gather_rows(&self) -> usize {
        usize::MAX
    }

    /// Incremental batched forward: like [`Engine::forward_ord`], but each
    /// sequence runs in its pinned cache lane — the engine appends the
    /// newly-committed rows' K/V to the lane's persistent cache and
    /// computes only those plus the wanted rows. Returns the gathered
    /// wanted rows exactly as `forward_ord` does.
    ///
    /// The default implementation drops the cache bookkeeping and routes
    /// through [`Engine::forward_ord`] (which itself defaults to
    /// [`forward_ord_dense`]) — the inc → ord → dense fallback ladder —
    /// so every engine is correct by construction and callers never need
    /// a capability check for correctness. Engines with a native path
    /// ([`mock::MockEngine`], [`XlaEngine`] with `fwd_inc_b{B}` artifacts)
    /// override it and report `inc_lanes() > 0`; the scheduler only
    /// routes through `forward_inc` in that case, so engines without
    /// caches keep their exact one-launch-per-iteration batching.
    fn forward_inc(&self, specs: &[IncSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        let plain: Vec<ForwardSpec<'_>> = specs.iter().map(|s| s.spec).collect();
        self.forward_ord(&plain)
    }

    /// Number of cache lanes the engine's NATIVE incremental path serves
    /// (0 = no native path; `forward_inc` then falls back to
    /// `forward_ord`). Lane storage is allocated on first use, so this is
    /// a routing capability signal, not a memory commitment.
    fn inc_lanes(&self) -> usize {
        0
    }

    /// Retire a lane: release its cache blocks back to the pool AND, for
    /// engines with a prefix cache, seal the lane's committed rows so a
    /// later request with the same prompt prefix can be seeded from them
    /// (skipping prefill). The scheduler calls this whenever a batch slot
    /// is assigned to a new request or retired, so a freshly admitted
    /// slot can never observe a previous occupant's cache — sealed
    /// prefixes are re-entered only through a chain-hash match, which is
    /// bit-equivalent to recompute (see [`paged`]).
    fn reset_lane(&self, _lane: usize) {}

    /// Block-pool occupancy + prefix-cache counters for paged engines
    /// (None when the engine has no paged cache — e.g. the dense-only
    /// fallback or [`DensePath`]). The scheduler uses
    /// [`paged::KvStats::lane_budget`] for block-budget admission and
    /// forwards the counters into `/metrics` and `/replicas`.
    fn kv_stats(&self) -> Option<paged::KvStats> {
        None
    }

    /// Number of forward calls so far (NFE accounting — Theorem 1).
    fn nfe(&self) -> u64;

    /// Supported batch sizes, ascending (artifact variants).
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }
}

/// Reusable buffers for [`forward_ord_dense`]: this fallback IS the
/// serving hot path for pre-compact artifact sets, so it must not
/// allocate + zero O(B·N²) of masks per iteration (the deleted
/// scheduler-side buffers were reused for the same reason). Thread-local
/// because engines are pinned to one worker thread by construction, and
/// every cell is overwritten before the forward reads it, so stale
/// contents are harmless.
#[derive(Default)]
struct DenseScratch {
    toks: Vec<u32>,
    mh: Vec<f32>,
    mg: Vec<f32>,
}

thread_local! {
    static DENSE_SCRATCH: std::cell::RefCell<DenseScratch> =
        std::cell::RefCell::new(DenseScratch::default());
}

/// The dense fallback behind [`Engine::forward_ord`]: reconstruct the
/// masks host-side with the reference builders, run one dense batched
/// forward, and gather the requested rows. Used directly by engines
/// without compact artifacts and by [`DensePath`] for the
/// compact-vs-dense equivalence tests and the `perf_engine` ablation.
pub fn forward_ord_dense<E: Engine + ?Sized>(
    engine: &E,
    specs: &[ForwardSpec<'_>],
) -> EngineResult<Vec<Vec<f32>>> {
    if specs.is_empty() {
        return Ok(vec![]);
    }
    // Attribution tap: whatever routed here, the call is now paying the
    // dense O(N²) mask traffic — the weakest fallback rung (engines are
    // thread-pinned, so the scheduler drains this on the same thread).
    crate::obs::tap::note_rung(crate::obs::Rung::Dense);
    let n = engine.seq_len();
    let v = engine.vocab();
    let b = specs.len();
    DENSE_SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let s = &mut *scratch;
        // resize, don't re-allocate: same-shape iterations are free, and
        // every cell below is written before the engine reads it.
        s.toks.resize(b * n, 0);
        s.mh.resize(b * n * n, 0.0);
        s.mg.resize(b * n * n, 0.0);
        for (i, spec) in specs.iter().enumerate() {
            assert_eq!(spec.tokens.len(), n, "tokens shape");
            assert_eq!(spec.ord.n(), n, "ordering length");
            assert!(!spec.want.is_empty(), "empty row request");
            s.toks[i * n..(i + 1) * n].copy_from_slice(spec.tokens);
            draft_masks_into(
                spec.ord,
                spec.known,
                &mut s.mh[i * n * n..(i + 1) * n * n],
                &mut s.mg[i * n * n..(i + 1) * n * n],
            );
        }
        let logits = engine.forward(b, &s.toks, &s.mh, &s.mg)?;
        Ok(specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let mut rows = Vec::with_capacity(spec.want.len() * v);
                for &pos in spec.want {
                    assert!(pos < n, "wanted row {pos} out of range");
                    rows.extend_from_slice(
                        &logits[i * n * v + pos * v..i * n * v + (pos + 1) * v],
                    );
                }
                rows
            })
            .collect())
    })
}

/// Wrapper that pins the wrapped engine to the DENSE forward path:
/// `forward_ord` and `forward_inc` are deliberately not overridden (and
/// `inc_lanes` stays 0), so compact AND incremental requests both route
/// through [`forward_ord_dense`] even when the inner engine has native
/// implementations. This is the "before" side of the compact-vs-dense and
/// incremental-vs-compact ablations (`perf_engine`) and of the
/// bit-identity equivalence tests (decode/assd.rs, runtime/mock.rs).
pub struct DensePath<'e, E: Engine + ?Sized>(pub &'e E);

impl<E: Engine + ?Sized> Engine for DensePath<'_, E> {
    fn seq_len(&self) -> usize {
        self.0.seq_len()
    }

    fn vocab(&self) -> usize {
        self.0.vocab()
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        self.0.forward(batch, tokens, mask_h, mask_g)
    }

    fn nfe(&self) -> u64 {
        self.0.nfe()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.0.batch_sizes()
    }
}

/// Shared PJRT CPU client (one per process).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}

/// Load + compile an HLO text artifact on the given client.
pub fn compile_artifact(
    client: &xla::PjRtClient,
    path: impl AsRef<Path>,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = path.as_ref();
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}
