//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! request path. Wraps the `xla` crate (PJRT C API, CPU plugin).
//!
//! Everything the decoders and the trainer need is behind the [`Engine`]
//! trait so that the coordinator and the decode algorithms can be tested
//! hermetically against [`mock::MockEngine`] (an analytic log-linear model
//! with exact conditionals) without compiled artifacts.

pub mod engine;
pub mod mock;
pub mod pool;

use std::path::Path;

use anyhow::{Context, Result};

pub use engine::{TrainOutput, XlaEngine};
pub use pool::{EnginePool, PoolConfig};

/// The forward interface the decoders run against.
///
/// `tokens` is row-major [batch, N] (u32 ids); `mask_h` / `mask_g` are
/// row-major [batch, N, N] (1.0 = may-attend). Returns logits, row-major
/// [batch, N, V].
///
/// NOTE: deliberately NOT `Send` — the PJRT client is single-threaded
/// (`Rc` internally). Ownership transfer to a worker thread happens at
/// CONSTRUCTION time instead: a scheduler worker invokes an
/// [`pool::EnginePool`] factory (`Send + Sync`) on its own thread and owns
/// the resulting engine for its lifetime. The coordinator serves
/// concurrent requests to the worker(s) through the shared admission
/// queue (see coordinator/).
pub trait Engine {
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> Result<Vec<f32>>;

    /// Number of forward calls so far (NFE accounting — Theorem 1).
    fn nfe(&self) -> u64;

    /// Supported batch sizes, ascending (artifact variants).
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }
}

/// Shared PJRT CPU client (one per process).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}

/// Load + compile an HLO text artifact on the given client.
pub fn compile_artifact(
    client: &xla::PjRtClient,
    path: impl AsRef<Path>,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = path.as_ref();
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}
