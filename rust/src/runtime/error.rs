//! Typed engine-error taxonomy for the forward surface.
//!
//! The scheduler's fault-isolation ladder keys off the **class** of a
//! failure, not its message:
//!
//! - [`EngineError::Transient`] — the call failed but the engine is
//!   intact (allocator pressure, injected chaos, a flaky device step).
//!   Safe to retry: `DecodeMachine::forward_request` is idempotent
//!   between absorbs, so re-issuing the same spec reproduces the same
//!   logits bit-for-bit.
//! - [`EngineError::LaneCorrupt`] — one KV lane's cached state can no
//!   longer be trusted (invalidation raced a crash, chaos invalidated
//!   it). Recovery is `reset_lane(lane)` + re-route through
//!   `forward_ord`; the paged-KV chain-hash invariant makes the
//!   recomputed prefix bit-identical to the cached one.
//! - [`EngineError::Fatal`] — the engine itself is gone (device lost,
//!   poisoned state). The worker exits and the supervisor re-provisions
//!   the replica through the pool factory.
//!
//! Errors cross into `anyhow` freely (`EngineError` is a std error), and
//! [`EngineError::from_anyhow`] recovers the class on the way back by
//! downcasting — so helpers deep in an engine can keep returning
//! `anyhow::Result` without flattening the taxonomy.

use std::time::Duration;

/// Failure class — the retry ladder's routing key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorClass {
    Transient,
    LaneCorrupt,
    Fatal,
}

impl ErrorClass {
    /// Stable snake_case label used by the metrics counters
    /// (`engine_errors_total{class="..."}`).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorClass::Transient => "transient",
            ErrorClass::LaneCorrupt => "lane_corrupt",
            ErrorClass::Fatal => "fatal",
        }
    }
}

/// Typed error for the `Engine` forward surface
/// (`forward` / `forward_ord` / `forward_inc`).
#[derive(Debug, Clone, thiserror::Error)]
pub enum EngineError {
    /// The call failed but engine state is intact; a bit-identical retry
    /// is safe.
    #[error("transient engine error: {0}")]
    Transient(String),
    /// KV allocation stalled: the engine is sound but its paged-KV pool
    /// cannot grow the named lanes right now. Class-wise a transient — a
    /// retry after blocks free is bit-identical — but the scheduler keys
    /// on it specifically: instead of burning retry budget it PREEMPTS a
    /// victim slot (checkpoint + seal + release lane) to free blocks.
    #[error("kv pressure: {0}")]
    KvPressure(String),
    /// One lane's cached state is untrustworthy; reset the lane and
    /// recompute through the ordinary compact path.
    #[error("lane {lane} corrupt: {reason}")]
    LaneCorrupt { lane: usize, reason: String },
    /// The engine is unusable; the replica must be re-provisioned.
    #[error("fatal engine error: {0}")]
    Fatal(String),
}

/// Result alias for the typed forward surface.
pub type EngineResult<T> = Result<T, EngineError>;

impl EngineError {
    pub fn class(&self) -> ErrorClass {
        match self {
            EngineError::Transient(_) => ErrorClass::Transient,
            EngineError::KvPressure(_) => ErrorClass::Transient,
            EngineError::LaneCorrupt { .. } => ErrorClass::LaneCorrupt,
            EngineError::Fatal(_) => ErrorClass::Fatal,
        }
    }

    pub fn transient(msg: impl Into<String>) -> Self {
        EngineError::Transient(msg.into())
    }

    pub fn kv_pressure(msg: impl Into<String>) -> Self {
        EngineError::KvPressure(msg.into())
    }

    /// True for the allocation-stall subclass of transient failures — the
    /// scheduler's preemption trigger.
    pub fn is_kv_pressure(&self) -> bool {
        matches!(self, EngineError::KvPressure(_))
    }

    pub fn lane_corrupt(lane: usize, reason: impl Into<String>) -> Self {
        EngineError::LaneCorrupt {
            lane,
            reason: reason.into(),
        }
    }

    pub fn fatal(msg: impl Into<String>) -> Self {
        EngineError::Fatal(msg.into())
    }

    /// Convert an `anyhow` chain back into the taxonomy: if the chain
    /// bottoms out in an `EngineError` its class survives; anything
    /// else (device errors, I/O, panics stringified by callers) is
    /// conservatively `Fatal` — the worker cannot prove the engine is
    /// still sound, so the supervisor gets the call.
    pub fn from_anyhow(err: anyhow::Error) -> Self {
        match err.downcast::<EngineError>() {
            Ok(e) => e,
            Err(e) => EngineError::Fatal(format!("{e:#}")),
        }
    }
}

impl From<anyhow::Error> for EngineError {
    fn from(err: anyhow::Error) -> Self {
        EngineError::from_anyhow(err)
    }
}

/// The kind of fault a [`crate::runtime::chaos::ChaosEngine`] injects at
/// one forward call. Derived deterministically from the seeded schedule;
/// enumerated here so the taxonomy and the injector agree on coverage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the call with [`EngineError::Transient`].
    TransientFailure,
    /// Sleep, then serve the call normally (tests the latency path, not
    /// the error path — output must be unaffected).
    LatencySpike { delay: Duration },
    /// Invalidate the first lane named by the call, then fail with
    /// [`EngineError::LaneCorrupt`] (degrades to a transient failure on
    /// lane-less calls).
    LaneInvalidation,
    /// Fail with a transient allocation-exhaustion error (the pool is
    /// intact; a retry after batch-mates release blocks succeeds).
    AllocExhausted,
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Context;

    #[test]
    fn class_labels_are_stable() {
        assert_eq!(ErrorClass::Transient.as_str(), "transient");
        assert_eq!(ErrorClass::LaneCorrupt.as_str(), "lane_corrupt");
        assert_eq!(ErrorClass::Fatal.as_str(), "fatal");
        assert_eq!(
            EngineError::lane_corrupt(3, "x").class(),
            ErrorClass::LaneCorrupt
        );
    }

    #[test]
    fn kv_pressure_is_transient_class_but_detectable() {
        let e = EngineError::kv_pressure("pool exhausted: 0 free blocks");
        assert_eq!(e.class(), ErrorClass::Transient);
        assert!(e.is_kv_pressure());
        assert!(!EngineError::transient("flaky step").is_kv_pressure());
        // The subclass survives an anyhow round trip — the worker's
        // preemption arm downcasts after helpers bubble through anyhow.
        let any: anyhow::Error = e.into();
        assert!(EngineError::from_anyhow(any).is_kv_pressure());
    }

    #[test]
    fn roundtrip_through_anyhow_preserves_class() {
        let e = EngineError::transient("injected");
        let any: anyhow::Error = e.into();
        assert_eq!(EngineError::from_anyhow(any).class(), ErrorClass::Transient);
    }

    #[test]
    fn context_wrapped_chain_still_downcasts() {
        // `.context(...)` wraps but keeps the chain downcastable.
        let r: anyhow::Result<()> = Err(EngineError::lane_corrupt(7, "chaos").into());
        let wrapped = r.context("executing forward_inc").unwrap_err();
        match EngineError::from_anyhow(wrapped) {
            EngineError::LaneCorrupt { lane, .. } => assert_eq!(lane, 7),
            other => panic!("lost class: {other:?}"),
        }
    }

    #[test]
    fn foreign_errors_become_fatal() {
        let any = anyhow::anyhow!("device lost");
        assert_eq!(EngineError::from_anyhow(any).class(), ErrorClass::Fatal);
    }
}
