//! Analytic mock engine: a log-linear conditional model with EXACT,
//! enumerable conditionals.
//!
//! This is the hermetic test substrate for the decode algorithms (and the
//! coordinator): it honours the same (tokens, mask_h, mask_g) -> logits
//! interface as the XLA engine, but its conditionals are defined directly
//! from the query-stream mask:
//!
//! ```text
//! logits[a][t] = bias[a][t] + sum_{b != a, mask_g[a][b] = 1} W[a][b][tok_b][t]
//! ```
//!
//! i.e. position a's distribution depends on exactly the tokens its
//! query-stream row may attend to. This gives genuinely DEPENDENT chain
//! conditionals (so speculative rejections actually happen) while letting
//! tests compute exact joint distributions by enumeration — which is how we
//! verify Theorem 2 (ASSD output distribution == sequential distribution).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::mask::{g_allows, Ordering as GenOrdering};
use crate::tokenizer::MASK;

use super::error::{EngineError, EngineResult};
use super::paged::{chain_extend, chain_hashes, KvStats, PagedKv, PagedKvConfig, PrefixKey};
use super::{Engine, ForwardSpec, IncSpec};

/// One incremental cache lane of the mock: the committed ordering plus a
/// BLOCK TABLE into the shared paged pool. The mock is an analytic model
/// with no hidden states, so "the K/V of a committed row" degenerates to
/// its token value (one `u32` per order-row) — but the cache is REAL:
/// committed columns are read from the paged store, not from the live
/// request buffer, so a scheduler bug that crosses lanes or skips a
/// reset — or an allocator bug that hands two lanes the same block —
/// produces observably different logits (and trips the debug asserts
/// first).
struct MockLane {
    sigma: Vec<usize>,
    m: usize,
    /// Blocks holding order-rows `0..cached` (row j = order j's token).
    table: Vec<usize>,
    /// Per-order prefix chain hashes, `chain.len() == cached`.
    chain: Vec<PrefixKey>,
    /// orders `< cached` are in the cache
    cached: usize,
}

/// Pool + lane map behind ONE RefCell so the borrow is taken once per
/// forward (engines are thread-pinned; never contended).
struct MockKv {
    store: PagedKv<u32>,
    lanes: HashMap<usize, MockLane>,
}

pub struct MockEngine {
    pub n: usize,
    pub v: usize,
    /// Potentials are generated on the fly from a hash of (a, b, tok_b, t)
    /// — a dense [n][n][v][v] table would be O(N^2 V^2) memory (4 TB at
    /// N=128, V=258). splitmix64 gives i.i.d.-looking, deterministic
    /// values in O(1) space.
    seed: u64,
    /// sharpness multiplier: larger -> spikier conditionals
    temp: f32,
    nfe: AtomicU64,
    /// Paged cache: block pool + prefix cache + incremental lanes (see
    /// [`super::paged`]). Lane tables are allocated on first use.
    kv: RefCell<MockKv>,
    /// Modeled device compute, in "attention cells" (query-row × key-col
    /// pairs over both streams): the hardware-independent cost unit the
    /// `perf_engine` incremental-vs-compact ablation reports. Dense and
    /// compact forwards evaluate every row against every column
    /// (2·N² per sequence — the compact ABI saves traffic, not compute);
    /// the incremental path evaluates only the active rows against
    /// cache + active columns (2·A·(C+A)), plus one N² h-stream prefill
    /// per lane — a prefill a PREFIX-CACHE HIT SKIPS entirely, which is
    /// exactly the warm-TTFT win `perf_paged` measures.
    modeled_cells: AtomicU64,
}

impl MockEngine {
    pub fn new(seed: u64, n: usize, v: usize, temp: f32) -> MockEngine {
        MockEngine::with_pool(seed, n, v, temp, PagedKvConfig::for_seq_len(n))
    }

    /// Like [`MockEngine::new`] with explicit pool sizing — the substrate
    /// for the memory-pressure tests and the `perf_paged` bench (tiny
    /// pools force eviction; huge pools never evict).
    pub fn with_pool(seed: u64, n: usize, v: usize, temp: f32, pool: PagedKvConfig) -> MockEngine {
        let pool = pool.normalized(n);
        MockEngine {
            n,
            v,
            seed,
            temp,
            nfe: AtomicU64::new(0),
            kv: RefCell::new(MockKv {
                store: PagedKv::new(pool, 1),
                lanes: HashMap::new(),
            }),
            modeled_cells: AtomicU64::new(0),
        }
    }

    /// Modeled device compute so far, in attention cells (see field docs).
    pub fn modeled_cells(&self) -> u64 {
        self.modeled_cells.load(Ordering::Relaxed)
    }

    #[inline]
    fn hashed(&self, key: u64) -> f32 {
        let mut s = self.seed ^ key.wrapping_mul(0x9e3779b97f4a7c15);
        let x = crate::util::rng::splitmix64(&mut s);
        // uniform in [-1, 1]
        ((x >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32
    }

    #[inline]
    fn w_at(&self, a: usize, b: usize, tb: usize, t: usize) -> f32 {
        self.hashed((((a * self.n + b) * self.v + tb) * self.v + t) as u64 | 1 << 62)
    }

    #[inline]
    fn bias_at(&self, a: usize, t: usize) -> f32 {
        self.hashed((a * self.v + t) as u64 | 1 << 63)
    }

    /// Exact logits for one row given the g-mask row and token values.
    pub fn row_logits(&self, a: usize, tokens: &[u32], mask_g_row: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.v];
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.bias_at(a, t);
        }
        for b in 0..self.n {
            if b != a && mask_g_row[b] > 0.0 {
                let tb = (tokens[b] as usize).min(self.v - 1);
                for t in 0..self.v {
                    out[t] += self.w_at(a, b, tb, t);
                }
            }
        }
        for t in 0..self.v {
            out[t] *= self.temp;
        }
        out
    }

    /// Exact logits for one row under the `(order, m, known)` mask
    /// parameterization — the NATIVE compact path: no `[N, N]` mask is
    /// ever materialized; the [`g_allows`] predicate is evaluated per
    /// column instead, in the same `b = 0..n` accumulation order as
    /// [`MockEngine::row_logits`], so the two paths produce bit-identical
    /// f32 sums.
    pub fn row_logits_ord(
        &self,
        a: usize,
        tokens: &[u32],
        ord: &GenOrdering,
        known: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; self.v];
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.bias_at(a, t);
        }
        let oa = ord.order[a];
        for b in 0..self.n {
            if b != a && g_allows(oa, ord.order[b], ord.m, known) {
                let tb = (tokens[b] as usize).min(self.v - 1);
                for t in 0..self.v {
                    out[t] += self.w_at(a, b, tb, t);
                }
            }
        }
        for t in 0..self.v {
            out[t] *= self.temp;
        }
        out
    }

    /// Exact logits for one row on the INCREMENTAL path: same predicate
    /// and same `b = 0..n` accumulation order as [`row_logits_ord`]
    /// (bit-identical f32 sums), but committed columns read their token
    /// values from `cache_view` — a position-indexed view materialized
    /// from the lane's PAGED BLOCKS, never from the live buffer.
    ///
    /// [`row_logits_ord`]: MockEngine::row_logits_ord
    fn row_logits_inc(
        &self,
        a: usize,
        tokens: &[u32],
        ord: &GenOrdering,
        known: usize,
        cached: usize,
        cache_view: &[u32],
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; self.v];
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.bias_at(a, t);
        }
        let oa = ord.order[a];
        for b in 0..self.n {
            if b != a && g_allows(oa, ord.order[b], ord.m, known) {
                let tok = if ord.order[b] < cached {
                    debug_assert_eq!(
                        cache_view[b], tokens[b],
                        "lane cache diverged from the live buffer at position {b} \
                         (lane crossed, reset skipped, or prefix hash collided?)"
                    );
                    cache_view[b]
                } else {
                    tokens[b]
                };
                let tb = (tok as usize).min(self.v - 1);
                for t in 0..self.v {
                    out[t] += self.w_at(a, b, tb, t);
                }
            }
        }
        for t in 0..self.v {
            out[t] *= self.temp;
        }
        out
    }
}

impl Engine for MockEngine {
    fn seq_len(&self) -> usize {
        self.n
    }

    fn vocab(&self) -> usize {
        self.v
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        _mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        let (n, v) = (self.n, self.v);
        assert_eq!(tokens.len(), batch * n);
        assert_eq!(mask_g.len(), batch * n * n);
        let mut logits = vec![0.0f32; batch * n * v];
        for s in 0..batch {
            let toks = &tokens[s * n..(s + 1) * n];
            for a in 0..n {
                let row = &mask_g[s * n * n + a * n..s * n * n + (a + 1) * n];
                let lg = self.row_logits(a, toks, row);
                logits[s * n * v + a * v..s * n * v + (a + 1) * v].copy_from_slice(&lg);
            }
        }
        self.nfe.fetch_add(1, Ordering::Relaxed);
        self.modeled_cells
            .fetch_add((2 * batch * n * n) as u64, Ordering::Relaxed);
        Ok(logits)
    }

    /// Native compact path: compute ONLY the wanted rows, masks never
    /// materialized. One call = one NFE, same as the dense path, so the
    /// Theorem-1 accounting is path-independent.
    fn forward_ord(&self, specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        if specs.is_empty() {
            return Ok(vec![]);
        }
        // Attribution tap: the mock's compact rung, same contract as
        // XlaEngine's (the scheduler drains per batched call).
        crate::obs::tap::note_rung(crate::obs::Rung::Ord);
        let out = specs
            .iter()
            .map(|spec| {
                assert_eq!(spec.tokens.len(), self.n, "tokens shape");
                assert_eq!(spec.ord.n(), self.n, "ordering length");
                assert!(!spec.want.is_empty(), "empty row request");
                let mut rows = Vec::with_capacity(spec.want.len() * self.v);
                for &pos in spec.want {
                    rows.extend_from_slice(&self.row_logits_ord(
                        pos,
                        spec.tokens,
                        spec.ord,
                        spec.known,
                    ));
                }
                rows
            })
            .collect();
        self.nfe.fetch_add(1, Ordering::Relaxed);
        // The compiled compact graph still runs every row of both streams
        // against every column — the gather trims traffic, not compute.
        self.modeled_cells
            .fetch_add((2 * specs.len() * self.n * self.n) as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Native incremental path: per lane, append the newly-committed token
    /// values to the lane cache, then compute ONLY the wanted rows,
    /// reading committed columns from the CACHE. Bit-identical to the
    /// compact path (same predicate, same accumulation order, and —
    /// protocol held — the same token values), with the incremental cost
    /// model booked in [`MockEngine::modeled_cells`]. One call = one NFE,
    /// so Theorem-1 accounting stays path-independent (the mock needs no
    /// separate prefill launch; XlaEngine books its real ones).
    fn forward_inc(&self, specs: &[IncSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        if specs.is_empty() {
            return Ok(vec![]);
        }
        // Attribution tap: the incremental rung is serving this call.
        crate::obs::tap::note_rung(crate::obs::Rung::Inc);
        let kv = &mut *self.kv.borrow_mut();
        let (store, lanes) = (&mut kv.store, &mut kv.lanes);
        let mut cells = 0u64;
        let mut out = Vec::with_capacity(specs.len());
        for inc in specs {
            let spec = &inc.spec;
            assert_eq!(spec.tokens.len(), self.n, "tokens shape");
            assert_eq!(spec.ord.n(), self.n, "ordering length");
            assert!(!spec.want.is_empty(), "empty row request");
            assert!(
                spec.ord.m <= inc.committed && inc.committed <= spec.known,
                "committed out of range"
            );
            let lane = lanes.entry(inc.lane).or_insert_with(|| MockLane {
                sigma: vec![],
                m: 0,
                table: vec![],
                chain: vec![],
                cached: 0,
            });
            // Invalidation rule (same as XlaEngine): an ordering or
            // prompt-size change, or a committed count that moved
            // backwards, means a different request is in the lane —
            // release the stale blocks (unsealed: the lifecycle seam was
            // skipped, so the content is not trustworthy cache material)
            // and re-seed.
            if lane.cached > 0
                && (lane.sigma != spec.ord.sigma
                    || lane.m != spec.ord.m
                    || inc.committed < lane.cached)
            {
                store.release_table(&mut lane.table);
                lane.chain.clear();
                lane.cached = 0;
            }
            if lane.cached == 0 {
                lane.sigma = spec.ord.sigma.clone();
                lane.m = spec.ord.m;
                let chain = chain_hashes(spec.ord, spec.tokens, inc.committed);
                let looked = store.lookup(&chain, spec.ord.m, inc.committed);
                // Attribution tap: warm (hit) vs cold (prefill) lane
                // seeding, attributed to the request pinned here.
                crate::obs::tap::note_prefix_probe(inc.lane, looked.is_some());
                match looked {
                    Some((table, rows)) => {
                        // Prefix-cache hit: seed the lane from the sealed
                        // blocks — NO prefill. Rows `rows..committed`
                        // catch up through the ordinary append path
                        // below, exactly as on the XLA engine.
                        lane.table = table;
                        lane.cached = rows;
                        lane.chain = chain;
                    }
                    None => {
                        // Modeled prefill: one full h-stream pass seeds
                        // the cache (the bidirectional prompt block
                        // cannot be appended causally).
                        cells += (self.n * self.n) as u64;
                        lane.chain = chain;
                    }
                }
            }
            let appended = inc.committed - lane.cached;
            for j in lane.cached..inc.committed {
                let pos = lane.sigma[j];
                let tok = spec.tokens[pos];
                assert_ne!(tok, MASK, "appending an uncommitted (MASK) row");
                // Pool exhaustion is transient by contract: batch-mates
                // releasing blocks (or a lane reset) frees capacity, so a
                // retry can succeed — the taxonomy must not escalate it.
                // The KvPressure subclass lets the scheduler preempt a
                // victim slot (checkpoint + seal + release) instead of
                // spinning its retry budget against a full pool.
                store
                    .append_row(&mut lane.table, j)
                    .map_err(|e| EngineError::kv_pressure(format!("kv allocation: {e:#}")))?[0] =
                    tok;
                if j >= lane.chain.len() {
                    let prev = lane.chain[j - 1];
                    lane.chain.push(chain_extend(prev, pos, tok));
                }
            }
            lane.cached = inc.committed;
            // Incremental step cost: active rows (appends + wants)
            // against cache + active columns, both streams.
            let active = appended + spec.want.len();
            cells += (2 * active * (lane.cached + active)) as u64;
            // Materialize the position-indexed cache view from the paged
            // blocks (the mock's analogue of the device reading K/V
            // through the block table).
            let mut view = vec![MASK; self.n];
            for j in 0..lane.cached {
                view[lane.sigma[j]] = store.read_row(&lane.table, j)[0];
            }
            let mut rows = Vec::with_capacity(spec.want.len() * self.v);
            for &pos in spec.want {
                rows.extend_from_slice(&self.row_logits_inc(
                    pos,
                    spec.tokens,
                    spec.ord,
                    spec.known,
                    lane.cached,
                    &view,
                ));
            }
            out.push(rows);
        }
        self.nfe.fetch_add(1, Ordering::Relaxed);
        self.modeled_cells.fetch_add(cells, Ordering::Relaxed);
        Ok(out)
    }

    fn inc_lanes(&self) -> usize {
        usize::MAX
    }

    fn reset_lane(&self, lane: usize) {
        let kv = &mut *self.kv.borrow_mut();
        if let Some(mut l) = kv.lanes.remove(&lane) {
            // Retire = seal THEN release: the committed rows stay in the
            // prefix cache under their chain hashes (ref-counted), the
            // lane's own references return to the pool.
            kv.store.seal(&l.table, &l.chain, l.m, l.cached);
            kv.store.release_table(&mut l.table);
        }
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(self.kv.borrow().store.stats())
    }

    fn nfe(&self) -> u64 {
        self.nfe.load(Ordering::Relaxed)
    }

    fn batch_sizes(&self) -> Vec<usize> {
        vec![1, 4]
    }
}

/// A [`MockEngine`] whose forwards take a configurable wall-clock time:
/// the timing substrate for lifecycle tests and the streaming bench
/// (cancellation mid-decode, deadline expiry, queue-full shedding, TTFT
/// vs total latency) — the plain mock decodes too fast to observe any of
/// that deterministically. Semantics are bit-identical to the wrapped
/// mock; only latency is added.
pub struct SlowEngine {
    inner: MockEngine,
    delay: std::time::Duration,
}

impl SlowEngine {
    pub fn new(inner: MockEngine, delay: std::time::Duration) -> SlowEngine {
        SlowEngine { inner, delay }
    }
}

impl Engine for SlowEngine {
    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        std::thread::sleep(self.delay);
        self.inner.forward(batch, tokens, mask_h, mask_g)
    }

    fn forward_ord(&self, specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inner.forward_ord(specs)
    }

    fn forward_inc(&self, specs: &[IncSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inner.forward_inc(specs)
    }

    fn inc_lanes(&self) -> usize {
        self.inner.inc_lanes()
    }

    fn reset_lane(&self, lane: usize) {
        self.inner.reset_lane(lane)
    }

    fn kv_stats(&self) -> Option<KvStats> {
        self.inner.kv_stats()
    }

    fn max_gather_rows(&self) -> usize {
        self.inner.max_gather_rows()
    }

    fn nfe(&self) -> u64 {
        self.inner.nfe()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.inner.batch_sizes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mask::{draft_masks, verify_masks, Ordering as Ord};
    use crate::data::masking::lattice_sigma;

    #[test]
    fn deterministic_and_mask_sensitive() {
        let e = MockEngine::new(1, 4, 3, 1.0);
        let ord = Ord::new(lattice_sigma(&[0], 4), 1);
        let (h, g) = verify_masks(&ord);
        let toks = vec![1u32, 2, 0, 1];
        let a = e.forward(1, &toks, &h, &g).unwrap();
        let b = e.forward(1, &toks, &h, &g).unwrap();
        assert_eq!(a, b);
        // Changing an attended token changes dependent rows.
        let mut toks2 = toks.clone();
        toks2[0] = 2;
        let c = e.forward(1, &toks2, &h, &g).unwrap();
        assert_ne!(a, c);
        assert_eq!(e.nfe(), 3);
    }

    #[test]
    fn conditional_independence_under_draft_mask() {
        // Under draft masks, unknown rows must not depend on unknown tokens.
        let e = MockEngine::new(2, 5, 4, 1.0);
        let ord = Ord::new(lattice_sigma(&[1, 3], 5), 2);
        let (h, g) = draft_masks(&ord, 2);
        let mut t1 = vec![0u32; 5];
        let mut t2 = vec![0u32; 5];
        t1[1] = 2;
        t2[1] = 2;
        t1[3] = 1;
        t2[3] = 1;
        // differ at unknown positions
        t1[0] = 3;
        t2[0] = 1;
        t1[2] = 0;
        t2[2] = 3;
        let a = e.forward(1, &t1, &h, &g).unwrap();
        let b = e.forward(1, &t2, &h, &g).unwrap();
        let v = e.vocab();
        for pos in [0usize, 2, 4] {
            assert_eq!(
                a[pos * v..(pos + 1) * v],
                b[pos * v..(pos + 1) * v],
                "unknown row {pos} depended on unknown content"
            );
        }
    }

    /// The native compact path must be BIT-identical to the dense fallback
    /// (same masks, same accumulation order) over random (sigma, known,
    /// want) states — this is what makes the compact ABI a pure transport
    /// optimization.
    #[test]
    fn prop_compact_rows_bit_identical_to_dense_fallback() {
        use crate::data::masking::{sample_sigma, OrderProtocol};
        use crate::runtime::forward_ord_dense;
        use crate::util::{propcheck, rng::Rng};
        propcheck::check_no_shrink(
            31,
            60,
            |r: &mut Rng| {
                let n = r.range(3, 12);
                let m = r.range(1, n);
                (n, m, r.next_u64())
            },
            |&(n, m, seed)| {
                let e = MockEngine::new(seed ^ 9, n, 5, 1.0);
                let mut r = Rng::new(seed);
                let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                let ord = Ord::new(sigma, m);
                let known = r.range(m, n + 1);
                let tokens: Vec<u32> = (0..n).map(|_| r.below(5) as u32).collect();
                let n_want = r.range(1, n + 1);
                let want: Vec<usize> = (0..n_want).map(|_| r.below(n)).collect();
                let spec = ForwardSpec {
                    tokens: &tokens,
                    ord: &ord,
                    known,
                    want: &want,
                };
                let native = e.forward_ord(std::slice::from_ref(&spec)).unwrap();
                let dense = forward_ord_dense(&e, std::slice::from_ref(&spec)).unwrap();
                if native != dense {
                    return Err(format!("rows diverge (n={n} m={m} known={known})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn compact_counts_one_nfe_per_batched_call() {
        let e = MockEngine::new(4, 4, 3, 1.0);
        let ord = Ord::new(lattice_sigma(&[0], 4), 1);
        let toks = vec![1u32, 2, 0, 1];
        let want = [1usize, 2];
        let specs = [
            ForwardSpec {
                tokens: &toks,
                ord: &ord,
                known: 1,
                want: &want,
            },
            ForwardSpec {
                tokens: &toks,
                ord: &ord,
                known: 4,
                want: &want,
            },
        ];
        let rows = e.forward_ord(&specs).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), 2 * 3);
        assert_eq!(e.nfe(), 1, "one batched compact call = one NFE");
    }

    /// The native incremental path must be BIT-identical to the compact
    /// path (and hence to the dense fallback) across a whole simulated
    /// decode: random commit schedules, draft- and verify-state calls,
    /// committed columns served from the lane cache throughout.
    #[test]
    fn prop_incremental_rows_bit_identical_across_commit_schedules() {
        use crate::data::masking::{sample_sigma, OrderProtocol};
        use crate::util::{propcheck, rng::Rng};
        propcheck::check_no_shrink(
            37,
            40,
            |r: &mut Rng| {
                let n = r.range(4, 12);
                let m = r.range(1, n - 1);
                (n, m, r.next_u64())
            },
            |&(n, m, seed)| {
                let e = MockEngine::new(seed ^ 21, n, 5, 1.0);
                let e_ref = MockEngine::new(seed ^ 21, n, 5, 1.0);
                let mut r = Rng::new(seed);
                let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                let ord = Ord::new(sigma, m);
                let mut tokens = vec![MASK; n];
                for pos in 0..n {
                    if ord.is_prompt_pos(pos) {
                        tokens[pos] = r.below(5) as u32;
                    }
                }
                let lane = r.below(4);
                e.reset_lane(lane);
                let mut c = m; // committed orders
                while c < n {
                    let t = (c + 1 + r.below(3)).min(n);
                    let window: Vec<usize> = (c..t).map(|i| ord.sigma[i]).collect();
                    // draft-state call
                    let spec = ForwardSpec {
                        tokens: &tokens,
                        ord: &ord,
                        known: c,
                        want: &window,
                    };
                    let inc = e
                        .forward_inc(&[IncSpec {
                            spec,
                            committed: c,
                            lane,
                        }])
                        .unwrap();
                    let ord_rows = e_ref.forward_ord(std::slice::from_ref(&spec)).unwrap();
                    if inc != ord_rows {
                        return Err(format!("draft rows diverge at c={c} (n={n} m={m})"));
                    }
                    // fill drafts, verify-state call
                    for &pos in &window {
                        tokens[pos] = r.below(5) as u32;
                    }
                    let spec = ForwardSpec {
                        tokens: &tokens,
                        ord: &ord,
                        known: n,
                        want: &window,
                    };
                    let inc = e
                        .forward_inc(&[IncSpec {
                            spec,
                            committed: c,
                            lane,
                        }])
                        .unwrap();
                    let ord_rows = e_ref.forward_ord(std::slice::from_ref(&spec)).unwrap();
                    if inc != ord_rows {
                        return Err(format!("verify rows diverge at c={c} (n={n} m={m})"));
                    }
                    // commit an accepted prefix, roll the rest back
                    let a = 1 + r.below(t - c);
                    for i in (c + a)..t {
                        tokens[ord.sigma[i]] = MASK;
                    }
                    c += a;
                }
                Ok(())
            },
        );
    }

    /// A retired lane's cache is never observed by a newly admitted
    /// sequence: after reset_lane, a different request in the same lane
    /// gets exactly the rows a fresh engine would produce.
    #[test]
    fn lane_reset_isolates_successive_requests() {
        let n = 8;
        let e = MockEngine::new(9, n, 5, 1.0);
        let run = |e: &MockEngine, prompt_tok: u32, lane: usize| -> Vec<Vec<f32>> {
            let ord = Ord::new(lattice_sigma(&[0, 3], n), 2);
            let mut tokens = vec![MASK; n];
            tokens[0] = prompt_tok;
            tokens[3] = 2;
            e.reset_lane(lane);
            let want: Vec<usize> = (2..5).map(|i| ord.sigma[i]).collect();
            e.forward_inc(&[IncSpec {
                spec: ForwardSpec {
                    tokens: &tokens,
                    ord: &ord,
                    known: 2,
                    want: &want,
                },
                committed: 2,
                lane,
            }])
            .unwrap()
        };
        // same lane, same sigma/m, DIFFERENT prompt values: the reset
        // must make run 2 identical to a fresh engine's answer
        let first = run(&e, 1, 0);
        let second = run(&e, 4, 0);
        let fresh = MockEngine::new(9, n, 5, 1.0);
        assert_eq!(second, run(&fresh, 4, 0));
        assert_ne!(first, second, "prompt change must change the rows");
    }

    /// The engine-side invalidation rule: an ordering change or a
    /// committed count moving backwards in an un-reset lane drops the
    /// stale cache instead of serving from it.
    #[test]
    fn lane_auto_invalidates_on_order_change() {
        let n = 8;
        let e = MockEngine::new(11, n, 5, 1.0);
        let decode = |e: &MockEngine, vis: &[usize], lane: usize| -> Vec<Vec<f32>> {
            let ord = Ord::new(lattice_sigma(vis, n), vis.len());
            let mut tokens = vec![MASK; n];
            for &p in vis {
                tokens[p] = 3;
            }
            let want: Vec<usize> = (vis.len()..n).map(|i| ord.sigma[i]).collect();
            e.forward_inc(&[IncSpec {
                spec: ForwardSpec {
                    tokens: &tokens,
                    ord: &ord,
                    known: vis.len(),
                    want: &want,
                },
                committed: vis.len(),
                lane,
            }])
            .unwrap()
        };
        let _ = decode(&e, &[0, 3], 0);
        // NO reset: different ordering in the same lane must still answer
        // exactly like a fresh engine (stale cache dropped, not read)
        let got = decode(&e, &[1, 5, 6], 0);
        let fresh = MockEngine::new(11, n, 5, 1.0);
        assert_eq!(got, decode(&fresh, &[1, 5, 6], 0));
    }

    /// The modeled-compute accounting: after the one-time prefill, every
    /// incremental iteration books strictly fewer cells than a compact
    /// iteration (2·N² per sequence), and the cumulative totals cross
    /// before the second committed iteration at any realistic shape.
    #[test]
    fn incremental_modeled_compute_beats_compact_per_iteration() {
        let n = 64;
        let e = MockEngine::new(13, n, 5, 1.0);
        let ord = Ord::new(lattice_sigma(&[0, 9], n), 2);
        let mut tokens = vec![MASK; n];
        tokens[0] = 1;
        tokens[9] = 2;
        e.reset_lane(0);
        let compact_iter = (2 * n * n) as u64;
        let mut c = 2usize;
        let mut iter = 0;
        while c < n {
            let t = (c + 4).min(n);
            let window: Vec<usize> = (c..t).map(|i| ord.sigma[i]).collect();
            let before = e.modeled_cells();
            e.forward_inc(&[IncSpec {
                spec: ForwardSpec {
                    tokens: &tokens,
                    ord: &ord,
                    known: c,
                    want: &window,
                },
                committed: c,
                lane: 0,
            }])
            .unwrap();
            let step = e.modeled_cells() - before;
            if iter == 0 {
                // first call pays the N² prefill on top of its step
                assert!(step > (n * n) as u64);
                assert!(step < compact_iter + (n * n) as u64);
            } else {
                assert!(
                    step < compact_iter,
                    "iteration {iter}: inc step {step} >= compact {compact_iter}"
                );
            }
            for &pos in &window {
                tokens[pos] = 3;
            }
            c = t;
            iter += 1;
        }
        // cumulative: prefill amortizes by the second iteration
        assert!(e.modeled_cells() < compact_iter * iter);
    }

    /// Warm-prefix reuse: after a retire (reset_lane = seal + release), a
    /// new request with the SAME prompt is seeded from the prefix cache —
    /// prefill is skipped (no N² term in modeled cells) — and its rows
    /// are bit-identical to a cold engine's.
    #[test]
    fn prefix_hit_skips_prefill_and_stays_bit_identical() {
        let n = 16;
        let run = |e: &MockEngine, lane: usize| -> Vec<Vec<f32>> {
            let ord = Ord::new(lattice_sigma(&[0, 3, 7], n), 3);
            let mut tokens = vec![MASK; n];
            tokens[0] = 1;
            tokens[3] = 2;
            tokens[7] = 4;
            let want: Vec<usize> = (3..6).map(|i| ord.sigma[i]).collect();
            e.forward_inc(&[IncSpec {
                spec: ForwardSpec {
                    tokens: &tokens,
                    ord: &ord,
                    known: 3,
                    want: &want,
                },
                committed: 3,
                lane,
            }])
            .unwrap()
        };
        let e = MockEngine::new(17, n, 5, 1.0);
        let cold_cells_before = e.modeled_cells();
        let cold = run(&e, 0);
        let cold_cells = e.modeled_cells() - cold_cells_before;
        e.reset_lane(0); // retire: seals the committed prompt
        let warm_cells_before = e.modeled_cells();
        let warm = run(&e, 1); // different lane, same prompt
        let warm_cells = e.modeled_cells() - warm_cells_before;
        assert_eq!(warm, cold, "warm decode must be bit-identical to cold");
        let s = e.kv_stats().unwrap();
        assert_eq!((s.prefix_hits, s.prefix_misses), (1, 1));
        assert!(
            warm_cells + ((n * n) as u64) <= cold_cells,
            "hit must skip the N² prefill: warm {warm_cells} vs cold {cold_cells}"
        );
        // And against a fresh engine (no cache at all): still identical.
        let fresh = MockEngine::new(17, n, 5, 1.0);
        assert_eq!(run(&fresh, 0), warm);
    }

    /// The PR 5 seam: reset_lane must RELEASE blocks back to the pool,
    /// not merely invalidate the lane — a retire → admit cycle leaves no
    /// lane-held blocks (everything free or sealed+evictable) and the
    /// re-admitted slot cannot observe stale KV.
    #[test]
    fn retire_admit_cycle_releases_blocks_and_never_observes_stale_kv() {
        let n = 8;
        let e = MockEngine::new(23, n, 5, 1.0);
        let run = |e: &MockEngine, prompt_tok: u32| -> Vec<Vec<f32>> {
            let ord = Ord::new(lattice_sigma(&[0, 3], n), 2);
            let mut tokens = vec![MASK; n];
            tokens[0] = prompt_tok;
            tokens[3] = 2;
            let want: Vec<usize> = (2..5).map(|i| ord.sigma[i]).collect();
            e.forward_inc(&[IncSpec {
                spec: ForwardSpec {
                    tokens: &tokens,
                    ord: &ord,
                    known: 2,
                    want: &want,
                },
                committed: 2,
                lane: 0,
            }])
            .unwrap()
        };
        let total = e.kv_stats().unwrap().total_blocks;
        let first = run(&e, 1);
        let held = e.kv_stats().unwrap();
        assert!(held.free_blocks < total, "lane must hold blocks mid-request");
        e.reset_lane(0); // retire
        let s = e.kv_stats().unwrap();
        // No lane refs remain: every non-free block is sealed AND
        // evictable (its only references are cache entries).
        assert_eq!(s.free_blocks + s.cached_blocks, total);
        assert_eq!(s.evictable_blocks, s.cached_blocks);
        // Re-admit the same slot with a DIFFERENT prompt: stale KV would
        // change these rows; they must match a fresh engine exactly.
        let second = run(&e, 4);
        let fresh = MockEngine::new(23, n, 5, 1.0);
        assert_eq!(second, run(&fresh, 4));
        assert_ne!(first, second);
        e.reset_lane(0);
        let s = e.kv_stats().unwrap();
        assert_eq!(s.free_blocks + s.cached_blocks, total, "blocks leaked");
    }

    /// Memory pressure: a pool sized for ~one sequence forces LRU
    /// eviction of sealed prefixes on every churn cycle, yet every
    /// request's rows stay bit-identical to an unpressured engine's.
    #[test]
    fn eviction_under_pressure_never_changes_outputs() {
        let n = 16;
        let tiny = PagedKvConfig {
            block_rows: 4,
            total_blocks: 6, // 1.5 sequences' worth
        };
        let e = MockEngine::with_pool(29, n, 5, 1.0, tiny);
        let roomy = MockEngine::new(29, n, 5, 1.0);
        let run = |e: &MockEngine, prompt_tok: u32| -> Vec<Vec<f32>> {
            let ord = Ord::new(lattice_sigma(&[0, 5], n), 2);
            let mut tokens = vec![MASK; n];
            tokens[0] = prompt_tok;
            tokens[5] = 3;
            // Commit everything: the retire seals a full-sequence prefix.
            for i in 2..n {
                tokens[ord.sigma[i]] = (prompt_tok + i as u32) % 5;
            }
            let want = [ord.sigma[n - 1]];
            let rows = e
                .forward_inc(&[IncSpec {
                    spec: ForwardSpec {
                        tokens: &tokens,
                        ord: &ord,
                        known: n,
                        want: &want,
                    },
                    committed: n,
                    lane: 0,
                }])
                .unwrap();
            e.reset_lane(0);
            rows
        };
        for round in 0..6 {
            let tok = round % 3; // rotating prompts defeat the tiny cache
            assert_eq!(
                run(&e, tok),
                run(&roomy, tok),
                "round {round}: pressure changed outputs"
            );
        }
        let s = e.kv_stats().unwrap();
        assert!(s.evictions > 0, "tiny pool must have evicted");
        assert!(
            s.cached_blocks <= s.total_blocks,
            "cache exceeded the pool bound"
        );
    }

    #[test]
    fn batch_rows_independent() {
        let e = MockEngine::new(3, 4, 3, 1.0);
        let ord = Ord::new(lattice_sigma(&[0], 4), 1);
        let (h, g) = verify_masks(&ord);
        let t1 = vec![1u32, 2, 0, 1];
        let t2 = vec![0u32, 1, 2, 2];
        let single = e.forward(1, &t1, &h, &g).unwrap();
        let mut toks = t1.clone();
        toks.extend(&t2);
        let mut hh = h.clone();
        hh.extend(&h);
        let mut gg = g.clone();
        gg.extend(&g);
        let both = e.forward(2, &toks, &hh, &gg).unwrap();
        assert_eq!(&both[..single.len()], &single[..]);
    }
}
