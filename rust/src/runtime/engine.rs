//! XLA-backed engine: the production forward/train path.
//!
//! One compiled executable per (program, batch size). `forward` picks the
//! smallest compiled batch variant that fits and pads the remainder with
//! PAD-token rows + zero masks (padding rows cost compute but not
//! correctness; the batcher sizes batches to the variants). The compact
//! `forward_ord` path does the same over the `fwd_ord_b{B}` family, which
//! reconstructs the masks on device from `(order, m, known)` and gathers
//! only the requested logit rows before crossing back to the host (see
//! docs/ARCHITECTURE.md §Compact forward ABI). The incremental
//! `forward_inc` path adds per-lane persistent K/V caches over the
//! `fwd_inc_b{B}` + `fwd_inc_pre_b{B}` families, so the device computes
//! only newly-committed and wanted rows per iteration (see
//! docs/ARCHITECTURE.md §Incremental forward & KV cache).

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::error::{EngineError, EngineResult};
use super::paged::{chain_extend, chain_hashes, KvStats, PagedKv, PagedKvConfig, PrefixKey};
use super::{compile_artifact, forward_ord_dense, Engine, ForwardSpec, IncSpec};
use crate::model::ModelMeta;
use crate::tokenizer::PAD;

/// Reusable host-side packing buffers for the compact path: the per-call
/// i32 index vectors are tiny (O(B·N)), but re-zeroing fresh allocations
/// every scheduler iteration is pure waste. Behind a RefCell because
/// `forward_ord` takes `&self` (the engine is single-threaded by
/// construction — see the `Engine` trait docs — so the borrow can never
/// be contended).
#[derive(Default)]
struct OrdScratch {
    toks: Vec<i32>,
    order: Vec<i32>,
    m: Vec<i32>,
    known: Vec<i32>,
    want: Vec<i32>,
}

/// Packing buffers for the incremental path (the cache planes are the
/// big ones: [B, L, N, D] f32 per stream).
#[derive(Default)]
struct IncScratch {
    toks: Vec<i32>,
    order: Vec<i32>,
    m: Vec<i32>,
    known: Vec<i32>,
    cached: Vec<i32>,
    nrows: Vec<i32>,
    rows: Vec<i32>,
    cache_k: Vec<f32>,
    cache_v: Vec<f32>,
}

impl IncScratch {
    fn clear(&mut self) {
        self.toks.clear();
        self.order.clear();
        self.m.clear();
        self.known.clear();
        self.cached.clear();
        self.nrows.clear();
        self.rows.clear();
        self.cache_k.clear();
        self.cache_v.clear();
    }
}

/// One incremental cache lane: a BLOCK TABLE into the engine's paged K/V
/// pool plus the identity of the request it belongs to. Each block row
/// holds one committed order-row's K/V across all layers
/// (`[K: L·D | V: L·D]` f32s); the `[B, L, N, D]` device planes are
/// packed from the blocks at call time and extended from the
/// `k_new`/`v_new` rows the executable returns, so only O(L·R·D) of
/// cache ever crosses device→host per iteration (the one-time prefill
/// seeds it with a single full h-stream pass — unless a prefix-cache hit
/// seeds the lane from a retired request's sealed blocks, in which case
/// prefill is skipped entirely).
struct IncLane {
    /// blocks holding order-rows `0..cached`
    table: Vec<usize>,
    /// per-order prefix chain hashes (`>= cached` entries)
    chain: Vec<PrefixKey>,
    /// orders `< cached` are in the cache
    cached: usize,
    sigma: Vec<usize>,
    m: usize,
}

/// Pool + lane map behind ONE RefCell so the borrow is taken once per
/// forward (engines are thread-pinned; never contended).
struct XlaKv {
    store: PagedKv<f32>,
    lanes: HashMap<usize, IncLane>,
}

pub struct XlaEngine {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    /// batch size -> compiled dense forward executable
    fwd: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// batch size -> compiled COMPACT forward executable
    /// (`fwd_ord_b{B}.hlo.txt`: on-device mask construction + row gather;
    /// empty for pre-compact artifact sets, which serve via the dense
    /// fallback)
    fwd_ord: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// row-gather width R of the compact artifacts (0 iff `fwd_ord` empty)
    ord_rows: usize,
    /// batch size -> compiled INCREMENTAL forward executable
    /// (`fwd_inc_b{B}.hlo.txt`: active rows against the per-lane K/V
    /// cache; empty for pre-incremental artifact sets, which serve via
    /// the compact path)
    fwd_inc: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// batch size -> compiled incremental PREFILL executable
    /// (`fwd_inc_pre_b{B}.hlo.txt`: one h-stream pass seeding a lane)
    fwd_inc_pre: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// active-row width R of the incremental artifacts (0 iff `fwd_inc`
    /// empty)
    inc_rows: usize,
    /// paged K/V block pool + prefix cache + lane tables (see
    /// [`super::paged`]); a degenerate 1-block pool when the artifact set
    /// has no incremental family
    kv: RefCell<XlaKv>,
    scratch: RefCell<OrdScratch>,
    inc_scratch: RefCell<IncScratch>,
    /// current parameters (flat theta), host copy
    theta: Vec<f32>,
    /// device-resident theta — uploaded ONCE per set_params instead of per
    /// forward (§Perf: saves a 3.4 MB host->device literal per call)
    theta_buf: xla::PjRtBuffer,
    nfe: AtomicU64,
}

impl XlaEngine {
    /// Load the standard artifact set from a directory:
    /// model_meta.json, params file, fwd_b{B}.hlo.txt for each available B,
    /// and (when present) the compact fwd_ord_b{B}.hlo.txt family.
    ///
    /// Batch variants are DISCOVERED by scanning the directory for files
    /// matching the `fwd_b{B}.hlo.txt` / `fwd_ord_b{B}.hlo.txt` naming
    /// contracts (B a positive decimal integer; see docs/ARCHITECTURE.md
    /// §Artifact naming) rather than probing a hard-coded variant set, so
    /// the compile pipeline can emit any batch ladder without a rust-side
    /// change. Compact artifacts additionally require the `ord_rows` field
    /// in model_meta.json (the gather width R they were lowered with);
    /// a set missing it is served through the dense fallback.
    pub fn load(artifacts_dir: impl AsRef<Path>, params_path: Option<&Path>) -> Result<XlaEngine> {
        Self::load_with(artifacts_dir, params_path, None)
    }

    /// [`XlaEngine::load`] with explicit K/V pool sizing (the
    /// `--block-size` / `--cache-blocks` serving flags). `None` sizes the
    /// pool at [`PagedKvConfig::for_seq_len`] defaults.
    pub fn load_with(
        artifacts_dir: impl AsRef<Path>,
        params_path: Option<&Path>,
        kv_cfg: Option<PagedKvConfig>,
    ) -> Result<XlaEngine> {
        let dir = artifacts_dir.as_ref();
        let meta = ModelMeta::load(dir.join("model_meta.json"))?;
        meta.validate()?;
        let client = super::cpu_client()?;
        let mut fwd = BTreeMap::new();
        let mut fwd_ord = BTreeMap::new();
        let mut fwd_inc = BTreeMap::new();
        let mut fwd_inc_pre = BTreeMap::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading artifacts dir {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let (family, b) = if let Some(rest) = name.strip_prefix("fwd_inc_pre_b") {
                (&mut fwd_inc_pre, rest.strip_suffix(".hlo.txt"))
            } else if let Some(rest) = name.strip_prefix("fwd_inc_b") {
                (&mut fwd_inc, rest.strip_suffix(".hlo.txt"))
            } else if let Some(rest) = name.strip_prefix("fwd_ord_b") {
                (&mut fwd_ord, rest.strip_suffix(".hlo.txt"))
            } else if let Some(rest) = name.strip_prefix("fwd_b") {
                (&mut fwd, rest.strip_suffix(".hlo.txt"))
            } else {
                continue;
            };
            let Some(b) = b else { continue };
            let b: usize = match b.parse() {
                Ok(b) if b > 0 => b,
                // A stray near-miss (fwd_b4_old.hlo.txt, fwd_b4.copy.hlo.txt)
                // must not take down the load — warn and move on.
                _ => {
                    eprintln!(
                        "XlaEngine::load: ignoring '{name}' (batch variant is not a positive integer)"
                    );
                    continue;
                }
            };
            family.insert(b, compile_artifact(&client, entry.path())?);
        }
        if fwd.is_empty() {
            bail!("no fwd_b*.hlo.txt artifacts in {}", dir.display());
        }
        let ord_rows = match meta.ord_rows {
            Some(r) => r.min(meta.seq_len),
            None if !fwd_ord.is_empty() => {
                eprintln!(
                    "XlaEngine::load: fwd_ord_b* artifacts present but model_meta.json has no \
                     ord_rows field — serving through the dense fallback"
                );
                fwd_ord.clear();
                0
            }
            None => 0,
        };
        // ord_rows without artifacts (or vice versa) must not enable a
        // half-configured compact path.
        let ord_rows = if fwd_ord.is_empty() { 0 } else { ord_rows };
        // Incremental gating: the path needs the step executables, the
        // prefill executable, AND the inc_rows meta field; anything less
        // is half-configured and serves through the compact path instead.
        let inc_rows = match meta.inc_rows {
            Some(r) if !fwd_inc.is_empty() && !fwd_inc_pre.is_empty() => {
                r.clamp(2, meta.seq_len)
            }
            _ => {
                if !fwd_inc.is_empty() || !fwd_inc_pre.is_empty() {
                    eprintln!(
                        "XlaEngine::load: incomplete incremental artifact set (need \
                         fwd_inc_b*, fwd_inc_pre_b* and an inc_rows meta field) — \
                         serving through the compact path"
                    );
                }
                fwd_inc.clear();
                fwd_inc_pre.clear();
                0
            }
        };
        let params_path: PathBuf = params_path
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| dir.join("params_init.bin"));
        let theta = crate::model::load_params(&params_path, meta.n_params)
            .with_context(|| format!("loading params {}", params_path.display()))?;
        let theta_buf = client
            .buffer_from_host_buffer::<f32>(&theta, &[theta.len()], None)
            .context("uploading theta")?;
        // Pool rows are one committed order-row's K/V across all layers.
        // Without an incremental family the pool is never touched, so a
        // degenerate 1-block pool avoids allocating dead cache memory.
        let (pool_cfg, row_width) = if inc_rows > 0 {
            (
                kv_cfg.map_or_else(
                    || PagedKvConfig::for_seq_len(meta.seq_len),
                    |c| c.normalized(meta.seq_len),
                ),
                2 * meta.n_layers * meta.d_model,
            )
        } else {
            (
                PagedKvConfig {
                    block_rows: 1,
                    total_blocks: 1,
                },
                1,
            )
        };
        Ok(XlaEngine {
            meta,
            client,
            fwd,
            fwd_ord,
            ord_rows,
            fwd_inc,
            fwd_inc_pre,
            inc_rows,
            kv: RefCell::new(XlaKv {
                store: PagedKv::new(pool_cfg, row_width),
                lanes: HashMap::new(),
            }),
            scratch: RefCell::new(OrdScratch::default()),
            inc_scratch: RefCell::new(IncScratch::default()),
            theta,
            theta_buf,
            nfe: AtomicU64::new(0),
        })
    }

    pub fn set_params(&mut self, theta: Vec<f32>) -> Result<()> {
        if theta.len() != self.meta.n_params {
            bail!(
                "theta has {} params, expected {}",
                theta.len(),
                self.meta.n_params
            );
        }
        // Upload into a fresh buffer FIRST and only then replace engine
        // state, so a failed upload leaves the engine fully on the OLD
        // (theta, theta_buf) pair instead of stranding new host params
        // against a stale device buffer.
        let new_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&theta, &[theta.len()], None)
            .context("uploading theta")?;
        self.theta_buf = new_buf;
        self.theta = theta;
        // New parameters invalidate every cached K/V row: flush the
        // prefix cache and drop all live lane tables (their next call
        // re-prefills under the new theta).
        let kv = &mut *self.kv.borrow_mut();
        kv.store.clear_sealed();
        for lane in kv.lanes.values_mut() {
            kv.store.release_table(&mut lane.table);
            lane.chain.clear();
            lane.cached = 0;
        }
        Ok(())
    }

    pub fn params(&self) -> &[f32] {
        &self.theta
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Smallest compiled variant >= `want` (largest otherwise) — one
    /// policy shared by the dense and compact families.
    fn smallest_fitting(
        family: &BTreeMap<usize, xla::PjRtLoadedExecutable>,
        want: usize,
    ) -> usize {
        for (&b, _) in family.iter() {
            if b >= want {
                return b;
            }
        }
        *family.keys().last().unwrap()
    }

    fn pick_batch(&self, want: usize) -> usize {
        Self::smallest_fitting(&self.fwd, want)
    }

    fn pick_batch_ord(&self, want: usize) -> usize {
        Self::smallest_fitting(&self.fwd_ord, want)
    }

    fn pick_batch_inc(&self, want: usize) -> usize {
        Self::smallest_fitting(&self.fwd_inc, want)
    }

    /// One `fwd_inc_pre` launch: a full content-stream pass seeding
    /// `lane`'s K/V mirror for orders `0..committed`. Runs once per
    /// admitted sequence — the bidirectional prompt block cannot be
    /// appended in causal chunks, so its rows are computed together here
    /// and every later call only appends causal target rows.
    fn prefill_lane(
        &self,
        spec: &ForwardSpec<'_>,
        store: &mut PagedKv<f32>,
        lane: &mut IncLane,
        committed: usize,
    ) -> Result<()> {
        let n = self.meta.seq_len;
        let (nl, d) = (self.meta.n_layers, self.meta.d_model);
        let plane = self.meta.n_layers * n * self.meta.d_model;
        let b_exec = *self.fwd_inc_pre.keys().next().unwrap();
        let exe = &self.fwd_inc_pre[&b_exec];
        let mut toks: Vec<i32> = spec.tokens.iter().map(|&t| t as i32).collect();
        let mut order: Vec<i32> = spec.ord.order.iter().map(|&o| o as i32).collect();
        let mut sigma: Vec<i32> = spec.ord.sigma.iter().map(|&p| p as i32).collect();
        let mut m = vec![spec.ord.m as i32];
        let mut com = vec![committed as i32];
        for _ in 1..b_exec {
            toks.resize(toks.len() + n, PAD as i32);
            order.extend(0..n as i32);
            sigma.extend(0..n as i32);
            m.push(n as i32);
            com.push(0);
        }
        let buf_toks = self
            .client
            .buffer_from_host_buffer::<i32>(&toks, &[b_exec, n], None)?;
        let buf_order = self
            .client
            .buffer_from_host_buffer::<i32>(&order, &[b_exec, n], None)?;
        let buf_sigma = self
            .client
            .buffer_from_host_buffer::<i32>(&sigma, &[b_exec, n], None)?;
        let buf_m = self.client.buffer_from_host_buffer::<i32>(&m, &[b_exec], None)?;
        let buf_com = self
            .client
            .buffer_from_host_buffer::<i32>(&com, &[b_exec], None)?;
        let result = exe
            .execute_b(&[
                &self.theta_buf,
                &buf_toks,
                &buf_order,
                &buf_sigma,
                &buf_m,
                &buf_com,
            ])
            .context("executing fwd_inc_pre")?[0][0]
            .to_literal_sync()?;
        let (k, v) = result.to_tuple2()?;
        let k = k.to_vec::<f32>()?;
        let v = v.to_vec::<f32>()?;
        debug_assert!(k.len() >= plane && v.len() >= plane);
        // Scatter the committed rows ([L, N, D] order-major planes) into
        // paged blocks: row j = `[K: L·D | V: L·D]`.
        store.release_table(&mut lane.table);
        for j in 0..committed {
            let row = store.append_row(&mut lane.table, j)?;
            for l in 0..nl {
                let src = (l * n + j) * d;
                row[l * d..(l + 1) * d].copy_from_slice(&k[src..src + d]);
                row[(nl + l) * d..(nl + l + 1) * d].copy_from_slice(&v[src..src + d]);
            }
        }
        lane.cached = committed;
        self.nfe.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Bring `inc.lane` into a state the batched step can serve:
    /// (re)initialize on identity change, seed an empty lane — from the
    /// PREFIX CACHE when the committed prefix hashes to a sealed entry
    /// (skipping prefill), by a prefill launch otherwise — and catch up
    /// append backlogs in `inc_rows`-sized chunks (each a solo launch;
    /// reachable after a spec was temporarily routed off the incremental
    /// path, and on cache hits that cover the prompt but not every
    /// committed target row).
    fn prepare_lane(&self, inc: &IncSpec<'_>) -> Result<()> {
        let r = self.inc_rows;
        let spec = &inc.spec;
        assert!(
            spec.ord.m <= inc.committed && inc.committed <= spec.known,
            "committed out of range"
        );
        {
            let kv = &mut *self.kv.borrow_mut();
            let (store, lanes) = (&mut kv.store, &mut kv.lanes);
            let lane = lanes.entry(inc.lane).or_insert_with(|| IncLane {
                table: vec![],
                chain: vec![],
                cached: 0,
                sigma: vec![],
                m: 0,
            });
            // Invalidation rule: a different ordering or prompt size, or a
            // committed count that moved backwards, means a different
            // request occupies the lane — release the stale blocks,
            // unsealed (the lifecycle seam was skipped, so the content is
            // not trustworthy cache material). The scheduler also calls
            // reset_lane at every slot handoff; this is the engine-side
            // backstop.
            if lane.cached > 0
                && (lane.sigma != spec.ord.sigma
                    || lane.m != spec.ord.m
                    || inc.committed < lane.cached)
            {
                store.release_table(&mut lane.table);
                lane.chain.clear();
                lane.cached = 0;
            }
            if lane.cached == 0 {
                lane.sigma = spec.ord.sigma.clone();
                lane.m = spec.ord.m;
                if inc.committed > 0 {
                    let chain = chain_hashes(spec.ord, spec.tokens, inc.committed);
                    let looked = store.lookup(&chain, spec.ord.m, inc.committed);
                    // Attribution tap: the request pinned to this lane
                    // either seeded warm (hit) or pays prefill (miss).
                    crate::obs::tap::note_prefix_probe(inc.lane, looked.is_some());
                    match looked {
                        Some((table, rows)) => {
                            // Warm prefix: seed from the sealed blocks.
                            // Rows `rows..committed` are causal target
                            // rows and catch up through the ordinary
                            // append path below — NO prefill launch.
                            lane.table = table;
                            lane.cached = rows;
                            lane.chain = chain;
                        }
                        None => {
                            lane.chain = chain;
                            self.prefill_lane(spec, store, lane, inc.committed)?;
                        }
                    }
                }
            }
        }
        loop {
            let cached = self.kv.borrow().lanes[&inc.lane].cached;
            let free = r - spec.want.len().min(r);
            if inc.committed - cached <= free {
                return Ok(());
            }
            let chunk = (inc.committed - cached - free).min(r);
            let sub = IncSpec {
                spec: ForwardSpec { want: &[], ..*spec },
                committed: cached + chunk,
                lane: inc.lane,
            };
            self.exec_inc(std::slice::from_ref(&sub))?;
        }
    }

    /// One batched `fwd_inc` launch. Every lane must already be prepared
    /// so that `appends + want <= inc_rows`; `want` may be empty for
    /// internal catch-up chunks.
    fn exec_inc(&self, specs: &[IncSpec<'_>]) -> Result<Vec<Vec<f32>>> {
        let n = self.meta.seq_len;
        let v = self.meta.vocab;
        let nl = self.meta.n_layers;
        let d = self.meta.d_model;
        let r = self.inc_rows;
        let plane = nl * n * d;
        let b_exec = self.pick_batch_inc(specs.len());
        let exe = &self.fwd_inc[&b_exec];
        let kv = &mut *self.kv.borrow_mut();
        let (store, lanes) = (&mut kv.store, &mut kv.lanes);
        let mut scratch = self.inc_scratch.borrow_mut();
        let s = &mut *scratch;
        s.clear();
        let mut appended = Vec::with_capacity(specs.len());
        for inc in specs {
            let spec = &inc.spec;
            assert_eq!(spec.tokens.len(), n, "tokens shape");
            assert_eq!(spec.ord.n(), n, "ordering length");
            let lane = lanes.get(&inc.lane).expect("lane not prepared");
            let app = inc.committed - lane.cached;
            assert!(app + spec.want.len() <= r, "active rows exceed inc_rows");
            appended.push(app);
            s.toks.extend(spec.tokens.iter().map(|&t| t as i32));
            s.order.extend(spec.ord.order.iter().map(|&o| o as i32));
            s.m.push(spec.ord.m as i32);
            s.known.push(spec.known as i32);
            s.cached.push(lane.cached as i32);
            s.nrows.push((app + spec.want.len()) as i32);
            for j in lane.cached..inc.committed {
                s.rows.push(spec.ord.sigma[j] as i32);
            }
            for &pos in spec.want {
                assert!(pos < n, "wanted row {pos} out of range");
                s.rows.push(pos as i32);
            }
            s.rows.resize(s.rows.len() + (r - app - spec.want.len()), 0);
            // Gather the lane's [L, N, D] cache planes from its paged
            // blocks; columns >= cached are zero-filled (the kernel masks
            // them by `cached`, so their values are never read).
            for l in 0..nl {
                for j in 0..n {
                    if j < lane.cached {
                        let row = store.read_row(&lane.table, j);
                        s.cache_k.extend_from_slice(&row[l * d..(l + 1) * d]);
                        s.cache_v
                            .extend_from_slice(&row[(nl + l) * d..(nl + l + 1) * d]);
                    } else {
                        s.cache_k.resize(s.cache_k.len() + d, 0.0);
                        s.cache_v.resize(s.cache_v.len() + d, 0.0);
                    }
                }
            }
        }
        // Pad to the executable's batch: PAD tokens, empty row set, zero
        // cache — nrows = 0 masks every active column, so padding cannot
        // influence real lanes.
        for _ in specs.len()..b_exec {
            s.toks.resize(s.toks.len() + n, PAD as i32);
            s.order.extend(0..n as i32);
            s.m.push(n as i32);
            s.known.push(n as i32);
            s.cached.push(0);
            s.nrows.push(0);
            s.rows.resize(s.rows.len() + r, 0);
            s.cache_k.resize(s.cache_k.len() + plane, 0.0);
            s.cache_v.resize(s.cache_v.len() + plane, 0.0);
        }
        let c = &self.client;
        let buf_toks = c.buffer_from_host_buffer::<i32>(&s.toks, &[b_exec, n], None)?;
        let buf_order = c.buffer_from_host_buffer::<i32>(&s.order, &[b_exec, n], None)?;
        let buf_m = c.buffer_from_host_buffer::<i32>(&s.m, &[b_exec], None)?;
        let buf_known = c.buffer_from_host_buffer::<i32>(&s.known, &[b_exec], None)?;
        let buf_cached = c.buffer_from_host_buffer::<i32>(&s.cached, &[b_exec], None)?;
        let buf_nrows = c.buffer_from_host_buffer::<i32>(&s.nrows, &[b_exec], None)?;
        let buf_rows = c.buffer_from_host_buffer::<i32>(&s.rows, &[b_exec, r], None)?;
        let buf_ck = c.buffer_from_host_buffer::<f32>(&s.cache_k, &[b_exec, nl, n, d], None)?;
        let buf_cv = c.buffer_from_host_buffer::<f32>(&s.cache_v, &[b_exec, nl, n, d], None)?;
        let result = exe
            .execute_b(&[
                &self.theta_buf,
                &buf_toks,
                &buf_order,
                &buf_m,
                &buf_known,
                &buf_cached,
                &buf_nrows,
                &buf_rows,
                &buf_ck,
                &buf_cv,
            ])
            .context("executing forward_inc")?[0][0]
            .to_literal_sync()?;
        let (lg, kn, vn) = result.to_tuple3()?;
        let logits = lg.to_vec::<f32>()?;
        let k_new = kn.to_vec::<f32>()?;
        let v_new = vn.to_vec::<f32>()?;
        debug_assert_eq!(logits.len(), b_exec * r * v);
        self.nfe.fetch_add(1, Ordering::Relaxed);
        // Append the committed rows' K/V to the lanes' paged blocks
        // (copy-on-write protects blocks shared with sealed prefixes),
        // extend the prefix chains, then slice the wanted logit rows
        // (they follow the appends, in order).
        let mut out = Vec::with_capacity(specs.len());
        for (i, inc) in specs.iter().enumerate() {
            let app = appended[i];
            let lane = lanes.get_mut(&inc.lane).unwrap();
            for a in 0..app {
                let j = lane.cached + a;
                let pos = inc.spec.ord.sigma[j];
                let row = store.append_row(&mut lane.table, j)?;
                for l in 0..nl {
                    let src = ((i * nl + l) * r + a) * d;
                    row[l * d..(l + 1) * d].copy_from_slice(&k_new[src..src + d]);
                    row[(nl + l) * d..(nl + l + 1) * d].copy_from_slice(&v_new[src..src + d]);
                }
                if j >= lane.chain.len() {
                    let prev = lane.chain[j - 1];
                    lane.chain.push(chain_extend(prev, pos, inc.spec.tokens[pos]));
                }
            }
            lane.cached = inc.committed;
            let off = (i * r + app) * v;
            out.push(logits[off..off + inc.spec.want.len() * v].to_vec());
        }
        Ok(out)
    }

    /// The pre-optimization forward path (per-call theta LITERAL upload).
    /// Kept for the §Perf before/after ablation in `perf_engine`.
    pub fn forward_via_literals(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.meta.seq_len;
        let v = self.meta.vocab;
        let b_exec = self.pick_batch(batch);
        let exe = &self.fwd[&b_exec];
        let mut toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        toks_i32.resize(b_exec * n, PAD as i32);
        let mut mh = mask_h.to_vec();
        mh.resize(b_exec * n * n, 0.0);
        let mut mg = mask_g.to_vec();
        mg.resize(b_exec * n * n, 0.0);
        let lit_theta = xla::Literal::vec1(&self.theta);
        let lit_tokens = xla::Literal::vec1(&toks_i32).reshape(&[b_exec as i64, n as i64])?;
        let lit_mh = xla::Literal::vec1(&mh).reshape(&[b_exec as i64, n as i64, n as i64])?;
        let lit_mg = xla::Literal::vec1(&mg).reshape(&[b_exec as i64, n as i64, n as i64])?;
        let result = exe
            .execute::<xla::Literal>(&[lit_theta, lit_tokens, lit_mh, lit_mg])
            .context("executing forward (literal path)")?[0][0]
            .to_literal_sync()?;
        let mut logits = result.to_tuple1()?.to_vec::<f32>()?;
        logits.truncate(batch * n * v);
        self.nfe.fetch_add(1, Ordering::Relaxed);
        Ok(logits)
    }
    /// Dense forward body. XlaEngine's forward internals stay on
    /// `anyhow` (the xla crate's errors and `.context` chains convert
    /// freely); the [`Engine`] impl below maps to the typed
    /// [`EngineError`] taxonomy at the trait boundary, recovering the
    /// class of any `EngineError` buried in the chain by downcast.
    fn forward_impl(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.meta.seq_len;
        let v = self.meta.vocab;
        assert_eq!(tokens.len(), batch * n, "tokens shape");
        assert_eq!(mask_h.len(), batch * n * n, "mask_h shape");
        assert_eq!(mask_g.len(), batch * n * n, "mask_g shape");

        // Batches larger than the largest variant are split into chunks.
        let max_b = *self.fwd.keys().last().unwrap();
        if batch > max_b {
            let mut logits = Vec::with_capacity(batch * n * v);
            let mut off = 0;
            while off < batch {
                let take = (batch - off).min(max_b);
                let part = self.forward_impl(
                    take,
                    &tokens[off * n..(off + take) * n],
                    &mask_h[off * n * n..(off + take) * n * n],
                    &mask_g[off * n * n..(off + take) * n * n],
                )?;
                logits.extend_from_slice(&part);
                off += take;
            }
            return Ok(logits);
        }

        let b_exec = self.pick_batch(batch);
        let exe = &self.fwd[&b_exec];

        // Pad to the executable's batch size.
        let mut toks_i32: Vec<i32> = Vec::with_capacity(b_exec * n);
        toks_i32.extend(tokens.iter().map(|&t| t as i32));
        toks_i32.resize(b_exec * n, PAD as i32);
        let mut mh = Vec::with_capacity(b_exec * n * n);
        mh.extend_from_slice(mask_h);
        mh.resize(b_exec * n * n, 0.0);
        let mut mg = Vec::with_capacity(b_exec * n * n);
        mg.extend_from_slice(mask_g);
        mg.resize(b_exec * n * n, 0.0);

        // Device-buffer path: theta stays resident; only the (much
        // smaller) per-call inputs cross the host boundary.
        let buf_tokens = self
            .client
            .buffer_from_host_buffer::<i32>(&toks_i32, &[b_exec, n], None)?;
        let buf_mh = self
            .client
            .buffer_from_host_buffer::<f32>(&mh, &[b_exec, n, n], None)?;
        let buf_mg = self
            .client
            .buffer_from_host_buffer::<f32>(&mg, &[b_exec, n, n], None)?;
        let result = exe
            .execute_b(&[&self.theta_buf, &buf_tokens, &buf_mh, &buf_mg])
            .context("executing forward")?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let mut logits = out.to_vec::<f32>()?;
        logits.truncate(batch * n * v);
        self.nfe.fetch_add(1, Ordering::Relaxed);
        Ok(logits)
    }

    /// Compact path: ship `(tokens, order, m, known, want)` indices only —
    /// O(B·N) host→device — and read back just the gathered rows —
    /// O(B·R·V) device→host. The masks are rebuilt INSIDE the compiled
    /// graph from `(order, m, known)` (same semantics as
    /// `model::mask::g_allows`). Falls back to [`forward_ord_dense`] when
    /// the artifact set predates the compact family or a request wants
    /// more rows than the compiled gather width R.
    fn forward_ord_impl(&self, specs: &[ForwardSpec<'_>]) -> Result<Vec<Vec<f32>>> {
        if specs.is_empty() {
            return Ok(vec![]);
        }
        let r = self.ord_rows;
        if self.fwd_ord.is_empty() {
            return Ok(forward_ord_dense(self, specs)?);
        }
        // Attribution tap: the compact rung is serving (part of) this
        // call. A mixed batch that also routes rows to the dense
        // fallback tags Dense too, and the weakest rung wins.
        crate::obs::tap::note_rung(crate::obs::Rung::Ord);
        // Mixed batches: a request wanting more rows than the compiled
        // gather width (rare — deep diffusion steps) takes the dense
        // fallback ALONE; its batch-mates stay on the compact path
        // instead of the whole batch regressing to O(N²) mask traffic.
        if specs.iter().any(|s| s.want.len() > r) {
            let mut compact = Vec::new();
            let mut dense = Vec::new();
            // (routed-to-dense, index within that route's output)
            let mut route = Vec::with_capacity(specs.len());
            for s in specs {
                if s.want.len() > r {
                    route.push((true, dense.len()));
                    dense.push(*s);
                } else {
                    route.push((false, compact.len()));
                    compact.push(*s);
                }
            }
            let mut dense_out: Vec<Option<Vec<f32>>> =
                forward_ord_dense(self, &dense)?.into_iter().map(Some).collect();
            let mut compact_out: Vec<Option<Vec<f32>>> = if compact.is_empty() {
                vec![]
            } else {
                // No oversized entries remain, so this recursion takes the
                // compact path below.
                self.forward_ord_impl(&compact)?.into_iter().map(Some).collect()
            };
            return Ok(route
                .into_iter()
                .map(|(is_dense, i)| {
                    let slot = if is_dense {
                        &mut dense_out[i]
                    } else {
                        &mut compact_out[i]
                    };
                    slot.take().expect("route index duplicated")
                })
                .collect());
        }
        let n = self.meta.seq_len;
        let v = self.meta.vocab;
        // Batches larger than the largest compact variant split into chunks
        // (mirrors the dense path's policy).
        let max_b = *self.fwd_ord.keys().last().unwrap();
        if specs.len() > max_b {
            let mut out = Vec::with_capacity(specs.len());
            for chunk in specs.chunks(max_b) {
                out.extend(self.forward_ord_impl(chunk)?);
            }
            return Ok(out);
        }
        let b_exec = self.pick_batch_ord(specs.len());
        let exe = &self.fwd_ord[&b_exec];

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        s.toks.clear();
        s.order.clear();
        s.m.clear();
        s.known.clear();
        s.want.clear();
        for spec in specs {
            assert_eq!(spec.tokens.len(), n, "tokens shape");
            assert_eq!(spec.ord.n(), n, "ordering length");
            assert!(
                spec.ord.m <= spec.known && spec.known <= n,
                "known out of range"
            );
            s.toks.extend(spec.tokens.iter().map(|&t| t as i32));
            s.order.extend(spec.ord.order.iter().map(|&o| o as i32));
            s.m.push(spec.ord.m as i32);
            s.known.push(spec.known as i32);
            for &pos in spec.want {
                assert!(pos < n, "wanted row {pos} out of range");
                s.want.push(pos as i32);
            }
            // Pad the want vector with row 0 (harmless duplicate gather;
            // the surplus rows are sliced off below).
            s.want.resize(s.want.len() + (r - spec.want.len()), 0);
        }
        // Pad to the executable's batch: PAD tokens under an all-prompt
        // state (m = known = N) cost compute but cannot influence real
        // rows.
        for _ in specs.len()..b_exec {
            s.toks.resize(s.toks.len() + n, PAD as i32);
            s.order.extend(0..n as i32);
            s.m.push(n as i32);
            s.known.push(n as i32);
            s.want.resize(s.want.len() + r, 0);
        }

        let buf_tokens = self
            .client
            .buffer_from_host_buffer::<i32>(&s.toks, &[b_exec, n], None)?;
        let buf_order = self
            .client
            .buffer_from_host_buffer::<i32>(&s.order, &[b_exec, n], None)?;
        let buf_m = self
            .client
            .buffer_from_host_buffer::<i32>(&s.m, &[b_exec], None)?;
        let buf_known = self
            .client
            .buffer_from_host_buffer::<i32>(&s.known, &[b_exec], None)?;
        let buf_want = self
            .client
            .buffer_from_host_buffer::<i32>(&s.want, &[b_exec, r], None)?;
        let result = exe
            .execute_b(&[
                &self.theta_buf,
                &buf_tokens,
                &buf_order,
                &buf_m,
                &buf_known,
                &buf_want,
            ])
            .context("executing forward_ord")?[0][0]
            .to_literal_sync()?;
        let rows = result.to_tuple1()?.to_vec::<f32>()?;
        debug_assert_eq!(rows.len(), b_exec * r * v);
        self.nfe.fetch_add(1, Ordering::Relaxed);
        Ok(specs
            .iter()
            .enumerate()
            .map(|(i, spec)| rows[i * r * v..i * r * v + spec.want.len() * v].to_vec())
            .collect())
    }

    /// Incremental path: each sequence's newly-committed rows are appended
    /// to its lane's persistent K/V cache and only the active rows are
    /// computed on device — O(R·(C+R)·d) per iteration instead of
    /// O(N²·d). Falls back to [`Engine::forward_ord`] when the artifact
    /// set predates the incremental family; a request wanting more rows
    /// than the compiled width takes the compact path ALONE (its lane
    /// catches up on a later call — appends only need the committed token
    /// values, which stay in the buffer).
    fn forward_inc_impl(&self, specs: &[IncSpec<'_>]) -> Result<Vec<Vec<f32>>> {
        if specs.is_empty() {
            return Ok(vec![]);
        }
        if self.fwd_inc.is_empty() {
            let plain: Vec<ForwardSpec<'_>> = specs.iter().map(|s| s.spec).collect();
            return self.forward_ord_impl(&plain);
        }
        // Attribution tap: the incremental rung is serving (part of)
        // this call; oversized specs routed to the compact path tag Ord
        // themselves and the weakest rung wins.
        crate::obs::tap::note_rung(crate::obs::Rung::Inc);
        let r = self.inc_rows;
        if specs.iter().any(|s| s.spec.want.len() > r) {
            let mut small = Vec::new();
            let mut big = Vec::new();
            // (routed-to-big, index within that route's output)
            let mut route = Vec::with_capacity(specs.len());
            for s in specs {
                if s.spec.want.len() > r {
                    route.push((true, big.len()));
                    big.push(s.spec);
                } else {
                    route.push((false, small.len()));
                    small.push(*s);
                }
            }
            let mut big_out: Vec<Option<Vec<f32>>> =
                self.forward_ord_impl(&big)?.into_iter().map(Some).collect();
            let mut small_out: Vec<Option<Vec<f32>>> = if small.is_empty() {
                vec![]
            } else {
                self.forward_inc_impl(&small)?.into_iter().map(Some).collect()
            };
            return Ok(route
                .into_iter()
                .map(|(is_big, i)| {
                    let slot = if is_big {
                        &mut big_out[i]
                    } else {
                        &mut small_out[i]
                    };
                    slot.take().expect("route index duplicated")
                })
                .collect());
        }
        // Batches larger than the largest compiled variant split into
        // chunks (mirrors the dense and compact policies).
        let max_b = *self.fwd_inc.keys().last().unwrap();
        if specs.len() > max_b {
            let mut out = Vec::with_capacity(specs.len());
            for chunk in specs.chunks(max_b) {
                out.extend(self.forward_inc_impl(chunk)?);
            }
            return Ok(out);
        }
        for inc in specs {
            assert!(!inc.spec.want.is_empty(), "empty row request");
            self.prepare_lane(inc)?;
        }
        self.exec_inc(specs)
    }
}

impl Engine for XlaEngine {
    fn seq_len(&self) -> usize {
        self.meta.seq_len
    }

    fn vocab(&self) -> usize {
        self.meta.vocab
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.fwd.keys().copied().collect()
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> EngineResult<Vec<f32>> {
        self.forward_impl(batch, tokens, mask_h, mask_g)
            .map_err(EngineError::from_anyhow)
    }

    fn forward_ord(&self, specs: &[ForwardSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        self.forward_ord_impl(specs).map_err(EngineError::from_anyhow)
    }

    fn forward_inc(&self, specs: &[IncSpec<'_>]) -> EngineResult<Vec<Vec<f32>>> {
        self.forward_inc_impl(specs).map_err(EngineError::from_anyhow)
    }

    fn inc_lanes(&self) -> usize {
        if self.fwd_inc.is_empty() {
            0
        } else {
            usize::MAX
        }
    }

    fn reset_lane(&self, lane: usize) {
        let kv = &mut *self.kv.borrow_mut();
        if let Some(mut l) = kv.lanes.remove(&lane) {
            // Retire = seal THEN release: the committed rows stay in the
            // prefix cache under their chain hashes (ref-counted), the
            // lane's own references return to the pool.
            kv.store.seal(&l.table, &l.chain, l.m, l.cached);
            kv.store.release_table(&mut l.table);
        }
    }

    fn kv_stats(&self) -> Option<KvStats> {
        if self.inc_rows == 0 {
            return None; // no paged cache without the incremental family
        }
        Some(self.kv.borrow().store.stats())
    }

    fn max_gather_rows(&self) -> usize {
        let ord_cap = if self.fwd_ord.is_empty() {
            usize::MAX
        } else {
            self.ord_rows
        };
        // An incremental step carries up to a window of appends (last
        // iteration's commits) plus the window's want rows, so windows
        // are clamped to half the compiled active-row width — with the
        // default lowering (inc_rows = 2·ord_rows) this changes nothing.
        let inc_cap = if self.fwd_inc.is_empty() {
            usize::MAX
        } else {
            (self.inc_rows / 2).max(1)
        };
        ord_cap.min(inc_cap)
    }

    fn nfe(&self) -> u64 {
        self.nfe.load(Ordering::Relaxed)
    }
}

/// Output of one train step.
#[derive(Debug)]
pub struct TrainOutput {
    pub loss: f32,
}

/// Trainer-side executable wrapper: holds (theta, m, v) on the host and
/// steps them through the train_step artifact.
pub struct TrainRunner {
    pub meta: ModelMeta,
    /// kept alive for the executable's lifetime
    _client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub theta: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub step: u64,
}

impl TrainRunner {
    pub fn load(artifacts_dir: impl AsRef<Path>, batch: usize) -> Result<TrainRunner> {
        let dir = artifacts_dir.as_ref();
        let meta = ModelMeta::load(dir.join("model_meta.json"))?;
        meta.validate()?;
        let client = super::cpu_client()?;
        let exe = compile_artifact(&client, dir.join(format!("train_step_b{batch}.hlo.txt")))?;
        let theta = crate::model::load_params(dir.join("params_init.bin"), meta.n_params)?;
        let p = meta.n_params;
        Ok(TrainRunner {
            meta,
            _client: client,
            exe,
            batch,
            theta,
            adam_m: vec![0.0; p],
            adam_v: vec![0.0; p],
            step: 0,
        })
    }

    /// Reset optimizer state + parameters (ablation runs reuse the runner).
    pub fn reset(&mut self, theta: Vec<f32>) {
        assert_eq!(theta.len(), self.meta.n_params);
        self.theta = theta;
        self.adam_m.iter_mut().for_each(|x| *x = 0.0);
        self.adam_v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    /// One optimizer step on a [batch, N] token batch with verify-mode
    /// masks and loss weights.
    pub fn step(
        &mut self,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
        loss_w: &[f32],
        lr: f32,
    ) -> Result<TrainOutput> {
        let n = self.meta.seq_len;
        let b = self.batch;
        assert_eq!(tokens.len(), b * n);
        assert_eq!(mask_h.len(), b * n * n);
        assert_eq!(mask_g.len(), b * n * n);
        assert_eq!(loss_w.len(), b * n);
        self.step += 1;

        let toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let args = [
            xla::Literal::vec1(&self.theta),
            xla::Literal::vec1(&self.adam_m),
            xla::Literal::vec1(&self.adam_v),
            xla::Literal::scalar(self.step as f32),
            xla::Literal::vec1(&toks_i32).reshape(&[b as i64, n as i64])?,
            xla::Literal::vec1(mask_h).reshape(&[b as i64, n as i64, n as i64])?,
            xla::Literal::vec1(mask_g).reshape(&[b as i64, n as i64, n as i64])?,
            xla::Literal::vec1(loss_w).reshape(&[b as i64, n as i64])?,
            xla::Literal::scalar(lr),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .context("executing train_step")?[0][0]
            .to_literal_sync()?;
        let (t, m, v, loss) = result.to_tuple4()?;
        self.theta = t.to_vec::<f32>()?;
        self.adam_m = m.to_vec::<f32>()?;
        self.adam_v = v.to_vec::<f32>()?;
        let loss = loss.to_vec::<f32>()?[0];
        Ok(TrainOutput { loss })
    }
}
