//! XLA-backed engine: the production forward/train path.
//!
//! One compiled executable per (program, batch size). `forward` picks the
//! smallest compiled batch variant that fits and pads the remainder with
//! PAD-token rows + zero masks (padding rows cost compute but not
//! correctness; the batcher sizes batches to the variants).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::{compile_artifact, Engine};
use crate::model::ModelMeta;
use crate::tokenizer::PAD;

pub struct XlaEngine {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    /// batch size -> compiled forward executable
    fwd: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// current parameters (flat theta), host copy
    theta: Vec<f32>,
    /// device-resident theta — uploaded ONCE per set_params instead of per
    /// forward (§Perf: saves a 3.4 MB host->device literal per call)
    theta_buf: xla::PjRtBuffer,
    nfe: AtomicU64,
}

impl XlaEngine {
    /// Load the standard artifact set from a directory:
    /// model_meta.json, params file, fwd_b{B}.hlo.txt for each available B.
    ///
    /// Batch variants are DISCOVERED by scanning the directory for files
    /// matching the `fwd_b{B}.hlo.txt` naming contract (B a positive
    /// decimal integer; see docs/ARCHITECTURE.md §Artifact naming) rather
    /// than probing a hard-coded variant set, so the compile pipeline can
    /// emit any batch ladder without a rust-side change.
    pub fn load(artifacts_dir: impl AsRef<Path>, params_path: Option<&Path>) -> Result<XlaEngine> {
        let dir = artifacts_dir.as_ref();
        let meta = ModelMeta::load(dir.join("model_meta.json"))?;
        meta.validate()?;
        let client = super::cpu_client()?;
        let mut fwd = BTreeMap::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading artifacts dir {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(b) = name
                .strip_prefix("fwd_b")
                .and_then(|rest| rest.strip_suffix(".hlo.txt"))
            else {
                continue;
            };
            let b: usize = match b.parse() {
                Ok(b) if b > 0 => b,
                // A stray near-miss (fwd_b4_old.hlo.txt, fwd_b4.copy.hlo.txt)
                // must not take down the load — warn and move on.
                _ => {
                    eprintln!(
                        "XlaEngine::load: ignoring '{name}' (batch variant is not a positive integer)"
                    );
                    continue;
                }
            };
            fwd.insert(b, compile_artifact(&client, entry.path())?);
        }
        if fwd.is_empty() {
            bail!("no fwd_b*.hlo.txt artifacts in {}", dir.display());
        }
        let params_path: PathBuf = params_path
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| dir.join("params_init.bin"));
        let theta = crate::model::load_params(&params_path, meta.n_params)
            .with_context(|| format!("loading params {}", params_path.display()))?;
        let theta_buf = client
            .buffer_from_host_buffer::<f32>(&theta, &[theta.len()], None)
            .context("uploading theta")?;
        Ok(XlaEngine {
            meta,
            client,
            fwd,
            theta,
            theta_buf,
            nfe: AtomicU64::new(0),
        })
    }

    pub fn set_params(&mut self, theta: Vec<f32>) -> Result<()> {
        if theta.len() != self.meta.n_params {
            bail!(
                "theta has {} params, expected {}",
                theta.len(),
                self.meta.n_params
            );
        }
        self.theta_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&theta, &[theta.len()], None)
            .context("uploading theta")?;
        self.theta = theta;
        Ok(())
    }

    pub fn params(&self) -> &[f32] {
        &self.theta
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    fn pick_batch(&self, want: usize) -> usize {
        for (&b, _) in self.fwd.iter() {
            if b >= want {
                return b;
            }
        }
        *self.fwd.keys().last().unwrap()
    }

    /// The pre-optimization forward path (per-call theta LITERAL upload).
    /// Kept for the §Perf before/after ablation in `perf_engine`.
    pub fn forward_via_literals(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.meta.seq_len;
        let v = self.meta.vocab;
        let b_exec = self.pick_batch(batch);
        let exe = &self.fwd[&b_exec];
        let mut toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        toks_i32.resize(b_exec * n, PAD as i32);
        let mut mh = mask_h.to_vec();
        mh.resize(b_exec * n * n, 0.0);
        let mut mg = mask_g.to_vec();
        mg.resize(b_exec * n * n, 0.0);
        let lit_theta = xla::Literal::vec1(&self.theta);
        let lit_tokens = xla::Literal::vec1(&toks_i32).reshape(&[b_exec as i64, n as i64])?;
        let lit_mh = xla::Literal::vec1(&mh).reshape(&[b_exec as i64, n as i64, n as i64])?;
        let lit_mg = xla::Literal::vec1(&mg).reshape(&[b_exec as i64, n as i64, n as i64])?;
        let result = exe
            .execute::<xla::Literal>(&[lit_theta, lit_tokens, lit_mh, lit_mg])
            .context("executing forward (literal path)")?[0][0]
            .to_literal_sync()?;
        let mut logits = result.to_tuple1()?.to_vec::<f32>()?;
        logits.truncate(batch * n * v);
        self.nfe.fetch_add(1, Ordering::Relaxed);
        Ok(logits)
    }
}

impl Engine for XlaEngine {
    fn seq_len(&self) -> usize {
        self.meta.seq_len
    }

    fn vocab(&self) -> usize {
        self.meta.vocab
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.fwd.keys().copied().collect()
    }

    fn forward(
        &self,
        batch: usize,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.meta.seq_len;
        let v = self.meta.vocab;
        assert_eq!(tokens.len(), batch * n, "tokens shape");
        assert_eq!(mask_h.len(), batch * n * n, "mask_h shape");
        assert_eq!(mask_g.len(), batch * n * n, "mask_g shape");

        // Batches larger than the largest variant are split into chunks.
        let max_b = *self.fwd.keys().last().unwrap();
        if batch > max_b {
            let mut logits = Vec::with_capacity(batch * n * v);
            let mut off = 0;
            while off < batch {
                let take = (batch - off).min(max_b);
                let part = self.forward(
                    take,
                    &tokens[off * n..(off + take) * n],
                    &mask_h[off * n * n..(off + take) * n * n],
                    &mask_g[off * n * n..(off + take) * n * n],
                )?;
                logits.extend_from_slice(&part);
                off += take;
            }
            return Ok(logits);
        }

        let b_exec = self.pick_batch(batch);
        let exe = &self.fwd[&b_exec];

        // Pad to the executable's batch size.
        let mut toks_i32: Vec<i32> = Vec::with_capacity(b_exec * n);
        toks_i32.extend(tokens.iter().map(|&t| t as i32));
        toks_i32.resize(b_exec * n, PAD as i32);
        let mut mh = Vec::with_capacity(b_exec * n * n);
        mh.extend_from_slice(mask_h);
        mh.resize(b_exec * n * n, 0.0);
        let mut mg = Vec::with_capacity(b_exec * n * n);
        mg.extend_from_slice(mask_g);
        mg.resize(b_exec * n * n, 0.0);

        // Device-buffer path: theta stays resident; only the (much
        // smaller) per-call inputs cross the host boundary.
        let buf_tokens = self
            .client
            .buffer_from_host_buffer::<i32>(&toks_i32, &[b_exec, n], None)?;
        let buf_mh = self
            .client
            .buffer_from_host_buffer::<f32>(&mh, &[b_exec, n, n], None)?;
        let buf_mg = self
            .client
            .buffer_from_host_buffer::<f32>(&mg, &[b_exec, n, n], None)?;
        let result = exe
            .execute_b(&[&self.theta_buf, &buf_tokens, &buf_mh, &buf_mg])
            .context("executing forward")?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let mut logits = out.to_vec::<f32>()?;
        logits.truncate(batch * n * v);
        self.nfe.fetch_add(1, Ordering::Relaxed);
        Ok(logits)
    }

    fn nfe(&self) -> u64 {
        self.nfe.load(Ordering::Relaxed)
    }
}

/// Output of one train step.
#[derive(Debug)]
pub struct TrainOutput {
    pub loss: f32,
}

/// Trainer-side executable wrapper: holds (theta, m, v) on the host and
/// steps them through the train_step artifact.
pub struct TrainRunner {
    pub meta: ModelMeta,
    /// kept alive for the executable's lifetime
    _client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub theta: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub step: u64,
}

impl TrainRunner {
    pub fn load(artifacts_dir: impl AsRef<Path>, batch: usize) -> Result<TrainRunner> {
        let dir = artifacts_dir.as_ref();
        let meta = ModelMeta::load(dir.join("model_meta.json"))?;
        meta.validate()?;
        let client = super::cpu_client()?;
        let exe = compile_artifact(&client, dir.join(format!("train_step_b{batch}.hlo.txt")))?;
        let theta = crate::model::load_params(dir.join("params_init.bin"), meta.n_params)?;
        let p = meta.n_params;
        Ok(TrainRunner {
            meta,
            _client: client,
            exe,
            batch,
            theta,
            adam_m: vec![0.0; p],
            adam_v: vec![0.0; p],
            step: 0,
        })
    }

    /// Reset optimizer state + parameters (ablation runs reuse the runner).
    pub fn reset(&mut self, theta: Vec<f32>) {
        assert_eq!(theta.len(), self.meta.n_params);
        self.theta = theta;
        self.adam_m.iter_mut().for_each(|x| *x = 0.0);
        self.adam_v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    /// One optimizer step on a [batch, N] token batch with verify-mode
    /// masks and loss weights.
    pub fn step(
        &mut self,
        tokens: &[u32],
        mask_h: &[f32],
        mask_g: &[f32],
        loss_w: &[f32],
        lr: f32,
    ) -> Result<TrainOutput> {
        let n = self.meta.seq_len;
        let b = self.batch;
        assert_eq!(tokens.len(), b * n);
        assert_eq!(mask_h.len(), b * n * n);
        assert_eq!(mask_g.len(), b * n * n);
        assert_eq!(loss_w.len(), b * n);
        self.step += 1;

        let toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let args = [
            xla::Literal::vec1(&self.theta),
            xla::Literal::vec1(&self.adam_m),
            xla::Literal::vec1(&self.adam_v),
            xla::Literal::scalar(self.step as f32),
            xla::Literal::vec1(&toks_i32).reshape(&[b as i64, n as i64])?,
            xla::Literal::vec1(mask_h).reshape(&[b as i64, n as i64, n as i64])?,
            xla::Literal::vec1(mask_g).reshape(&[b as i64, n as i64, n as i64])?,
            xla::Literal::vec1(loss_w).reshape(&[b as i64, n as i64])?,
            xla::Literal::scalar(lr),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .context("executing train_step")?[0][0]
            .to_literal_sync()?;
        let (t, m, v, loss) = result.to_tuple4()?;
        self.theta = t.to_vec::<f32>()?;
        self.adam_m = m.to_vec::<f32>()?;
        self.adam_v = v.to_vec::<f32>()?;
        let loss = loss.to_vec::<f32>()?[0];
        Ok(TrainOutput { loss })
    }
}
