//! Statistics substrate: mean/stderr summaries, percentiles, histograms.
//!
//! Backs the metrics endpoint of the coordinator and the bench harness's
//! paper-style "mean ± stderr" table cells (no `criterion` offline).

/// Running summary over f64 samples (Welford's online algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Summary::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample variance (unbiased).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean — the paper's Table 1 "±" columns.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    /// "mean ± stderr" with sensible precision.
    pub fn fmt_pm(&self) -> String {
        format!("{:.2} ± {:.2}", self.mean(), self.stderr())
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Fixed-bucket latency histogram (log-spaced), cheap to update on the
/// request path.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket upper bounds in seconds
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Log-spaced buckets from `lo` to `hi` (seconds).
    pub fn latency() -> Self {
        let mut bounds = vec![];
        let mut b = 1e-5;
        while b < 100.0 {
            bounds.push(b);
            b *= 1.5;
        }
        Histogram::with_bounds(bounds)
    }

    /// Explicit strictly-ascending bucket upper bounds. One extra
    /// overflow bucket (samples above the last bound) is appended
    /// internally.
    pub fn with_bounds(bounds: Vec<f64>) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "bounds must be strictly ascending"
        );
        let n = bounds.len();
        Histogram {
            bounds,
            counts: vec![0; n + 1],
            total: 0,
            sum: 0.0,
        }
    }

    /// Linear buckets over [0, 1] in 0.05 steps — for rates (e.g. the
    /// per-request acceptance-rate histograms).
    pub fn unit() -> Self {
        Histogram::with_bounds((1..=20).map(|i| i as f64 * 0.05).collect())
    }

    /// Bucket upper bounds (the Prometheus `le` values, `+Inf` implied).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts; `counts()[bounds().len()]` is the overflow
    /// bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Sum of all recorded samples (the Prometheus `_sum`).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Fold `other` into `self` bucket-wise. Both histograms must share
    /// the same bounds (they are built by the same constructor in
    /// practice); merging mismatched layouts would silently misbucket,
    /// so it panics instead.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds, other.bounds,
            "merging histograms with different bucket layouts"
        );
        for (c, &o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
    }

    pub fn record(&mut self, x: f64) {
        let idx = match self
            .bounds
            .binary_search_by(|b| b.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(i) => i,
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap()
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_std() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.var() - 2.5).abs() < 1e-12);
        assert!((s.stderr() - (2.5f64).sqrt() / 5f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::from_slice(&[42.0]);
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.stderr(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert!((percentile(&xs, 95.0) - 95.0).abs() <= 1.0);
    }

    #[test]
    fn histogram_empty_quantile_is_zero() {
        let h = Histogram::latency();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_single_sample_quantiles_bracket_it() {
        let mut h = Histogram::latency();
        h.record(0.01);
        for q in [0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            // Every quantile lands on the sample's bucket bound: the
            // first bound at or above 0.01 under the 1.5x lattice.
            assert!(
                v >= 0.01 && v < 0.02,
                "q={q} gave {v}, expected the 0.01 sample's bucket"
            );
        }
        assert_eq!(h.mean(), 0.01);
    }

    #[test]
    fn histogram_all_samples_in_overflow_bucket() {
        let mut h = Histogram::with_bounds(vec![1.0, 2.0]);
        for _ in 0..5 {
            h.record(1e6);
        }
        assert_eq!(h.counts(), &[0, 0, 5]);
        // Quantiles clamp to the last finite bound — the histogram
        // cannot resolve beyond its lattice.
        assert_eq!(h.quantile(0.5), 2.0);
        assert_eq!(h.quantile(1.0), 2.0);
        assert_eq!(h.mean(), 1e6);
    }

    #[test]
    fn histogram_merge_matches_sequential_records() {
        let xs: Vec<f64> = (1..=50).map(|i| i as f64 * 1e-3).collect();
        let ys: Vec<f64> = (1..=50).map(|i| i as f64 * 1e-2).collect();
        let mut both = Histogram::latency();
        for &x in xs.iter().chain(ys.iter()) {
            both.record(x);
        }
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        for &x in &xs {
            a.record(x);
        }
        for &y in &ys {
            b.record(y);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.counts(), both.counts());
        assert!((a.sum() - both.sum()).abs() < 1e-12);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), both.quantile(q), "q={q}");
        }
    }

    #[test]
    #[should_panic(expected = "different bucket layouts")]
    fn histogram_merge_rejects_mismatched_bounds() {
        let mut a = Histogram::with_bounds(vec![1.0]);
        let b = Histogram::unit();
        a.merge(&b);
    }

    #[test]
    fn histogram_quantiles_bracket_data() {
        let mut h = Histogram::latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.02 && p50 < 0.1, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 > 0.05, "p99={p99}");
        assert!((h.mean() - 0.050_05).abs() < 0.001);
    }
}
