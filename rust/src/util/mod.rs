//! Substrate utilities built in-repo (the image is offline; no rand /
//! serde / clap / tokio / criterion / proptest / crossbeam).
//!
//! * [`args`] — CLI parsing (subcommands + `--key value` flags)
//! * [`bench`] — fixed-width result tables for the bench binaries
//! * [`json`] — RFC 8259 parser/serializer (protocol + metrics + artifacts)
//! * [`mpmc`] — multi-consumer channel (the pool's admission queue)
//! * [`propcheck`] — tiny property-testing harness
//! * [`rng`] — splitmix64/xoshiro-style deterministic RNG
//! * [`stats`] — histograms, percentiles, summaries
//! * [`threadpool`] — fixed worker pool (HTTP connections, load gen)

pub mod args;
pub mod bench;
pub mod json;
pub mod mpmc;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;
