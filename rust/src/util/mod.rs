//! Substrate utilities built in-repo (the image is offline; no rand /
//! serde / clap / tokio / criterion / proptest — see DESIGN.md §4).

pub mod args;
pub mod bench;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;
