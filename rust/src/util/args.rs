//! CLI argument parsing substrate (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, with
//! typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (e.g. `serve` in `asarm serve --replicas 4`).
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` pairs; bare `--flag` maps to "true".
    pub flags: BTreeMap<String, String>,
    /// Remaining non-flag tokens, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                out.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(
            self.flags.get(key).map(|s| s.as_str()),
            Some("true") | Some("1") | Some("yes")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --port 8080 --verbose --k=5 file.txt");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize("port", 0), 8080);
        assert!(a.bool("verbose"));
        assert_eq!(a.usize("k", 0), 5);
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.f64("lr", 1e-4), 1e-4);
        assert_eq!(a.str("out", "x"), "x");
        assert!(!a.bool("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b 3");
        assert!(a.bool("a"));
        assert_eq!(a.usize("b", 0), 3);
    }

    #[test]
    fn negative_number_value() {
        let a = parse("x --t -1.5");
        assert_eq!(a.f64("t", 0.0), -1.5);
    }
}
