//! Thread-pool substrate (no `tokio` offline).
//!
//! A fixed-size worker pool over an mpsc channel. The HTTP server uses it
//! to handle connections; the bench harness uses it for client load
//! generation. Jobs are boxed `FnOnce`s; shutdown drains the queue.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            workers,
            tx: Some(tx),
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run a batch of jobs and block until all complete. Panics (rather
    /// than deadlocking) if any job panicked: the panicking worker drops
    /// its completion sender without sending, and `done_tx` is dropped
    /// here after dispatch so `recv` can observe the hang-up.
    pub fn scoped_run<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        let (done_tx, done_rx) = mpsc::channel();
        let n = jobs.len();
        for job in jobs {
            let done = done_tx.clone();
            self.execute(move || {
                job();
                let _ = done.send(());
            });
        }
        drop(done_tx);
        for i in 0..n {
            done_rx
                .recv()
                .unwrap_or_else(|_| panic!("a pooled job panicked ({i}/{n} completed)"));
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit after drain
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.scoped_run(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_drains_queue() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..50 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for workers
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
