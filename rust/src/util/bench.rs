//! Bench harness substrate (no `criterion` offline).
//!
//! Provides warmup + timed iterations with mean ± stderr reporting, plus a
//! paper-style table printer used by the per-table bench binaries
//! (`cargo bench --bench table1_assd` etc.).

use std::time::Instant;

use crate::util::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` runs; returns per-run
/// seconds as a Summary.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// Simple fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts() {
        let mut n = 0;
        let s = time_it(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.count(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["Sampler", "NFE"]);
        t.row(&["Sequential".to_string(), "486.0 ± 0.0".to_string()]);
        t.row(&["ASSD".to_string(), "434.1".to_string()]);
        let s = t.to_string();
        assert!(s.contains("| Sampler    |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
