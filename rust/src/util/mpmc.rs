//! MPMC channel substrate (no `crossbeam` offline).
//!
//! `std::sync::mpsc` is multi-producer/**single**-consumer: its `Receiver`
//! cannot be shared, so a pool of scheduler workers cannot drain one queue
//! with it (short of serializing every `recv` behind a mutex held across
//! the blocking wait). This is a minimal multi-producer/multi-consumer
//! queue built on `Mutex<VecDeque>` + `Condvar`: the lock is held only to
//! push/pop, never while blocked waiting, so any worker can pick up the
//! next job the moment it is enqueued.
//!
//! Two flavours share the same `Sender`/`Receiver` types:
//!
//! * [`channel`] — unbounded, the original API (training/eval plumbing);
//! * [`bounded`] — capacity-limited: [`Sender::try_send`] reports
//!   [`TrySendError::Full`] instead of enqueueing, which is what the
//!   coordinator's load-shedding admission queue (HTTP 429) and the
//!   per-request event channels (slow-client backpressure) are built on.
//!   The blocking [`Sender::send`] waits for space instead.
//!
//! Close semantics mirror `mpsc` plus two additions the serving stack
//! needs:
//!
//! * dropping the last [`Sender`] closes the channel — receivers drain the
//!   remaining items and then see `Disconnected`;
//! * dropping the last [`Receiver`] ALSO closes it — subsequent `send`s
//!   fail, which is how a scheduler worker notices that the client behind
//!   a request's event channel has given up (see coordinator/lifecycle.rs);
//! * [`Receiver::close`] closes it from the consumer side — subsequent
//!   `send`s fail and the closer can drain what is left (used by the last
//!   scheduler worker on the way out so queued jobs fail fast instead of
//!   waiting forever).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// The send half. Cloneable; the channel closes when the last clone drops.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// The receive half. Cloneable — every clone drains the SAME queue (each
/// item is delivered to exactly one receiver).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    /// Wakes receivers blocked on an empty queue.
    cv: Condvar,
    /// Wakes senders blocked on a full bounded queue.
    cv_space: Condvar,
}

struct State<T> {
    queue: VecDeque<T>,
    senders: usize,
    receivers: usize,
    /// `None` = unbounded.
    capacity: Option<usize>,
    closed: bool,
    /// Set when the LAST receiver dropped (as opposed to an explicit
    /// consumer-side [`Receiver::close`] or all senders dropping): every
    /// worker that could have drained the queue is dead, so queued items
    /// are stranded until a producer reclaims them. The coordinator maps
    /// this to `SubmitError::ReplicaLost`.
    lost: bool,
}

/// Error returned by [`Sender::send`] on a closed channel; carries the
/// undelivered value back to the caller.
#[derive(Debug)]
pub struct SendError<T>(pub T);

/// Error returned by [`Sender::try_send`]; carries the undelivered value.
#[derive(Debug)]
pub enum TrySendError<T> {
    /// Bounded channel at capacity (still open) — the caller sheds or
    /// retries.
    Full(T),
    /// Channel closed (every receiver dropped, or closed explicitly).
    Closed(T),
}

/// Error returned by [`Receiver::try_recv`].
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    /// The queue is momentarily empty (the channel is still open).
    Empty,
    /// The channel is closed and fully drained.
    Disconnected,
}

/// Error returned by [`Receiver::recv_timeout`].
#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    /// No item arrived within the timeout (the channel is still open).
    Timeout,
    /// The channel is closed and fully drained.
    Disconnected,
}

/// Create an unbounded MPMC channel.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    with_capacity(None)
}

/// Create a bounded MPMC channel holding at most `capacity` items
/// (clamped to >= 1). [`Sender::try_send`] reports `Full` at capacity;
/// [`Sender::send`] blocks until space frees up.
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    with_capacity(Some(capacity.max(1)))
}

fn with_capacity<T>(capacity: Option<usize>) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            queue: VecDeque::new(),
            senders: 1,
            receivers: 1,
            capacity,
            closed: false,
            lost: false,
        }),
        cv: Condvar::new(),
        cv_space: Condvar::new(),
    });
    (
        Sender {
            shared: Arc::clone(&shared),
        },
        Receiver { shared },
    )
}

impl<T> State<T> {
    fn full(&self) -> bool {
        self.capacity.is_some_and(|c| self.queue.len() >= c)
    }
}

impl<T> Sender<T> {
    /// Enqueue `value`, waking one waiting receiver. On a bounded channel
    /// this blocks while the queue is at capacity. Fails (returning the
    /// value) iff the channel is closed.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        while st.full() && !st.closed {
            st = self.shared.cv_space.wait(st).unwrap();
        }
        if st.closed {
            return Err(SendError(value));
        }
        st.queue.push_back(value);
        drop(st);
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Non-blocking enqueue: `Full` on a bounded channel at capacity,
    /// `Closed` once every receiver is gone (or the channel was closed
    /// explicitly). The coordinator's shedding + backpressure primitive.
    pub fn try_send(&self, value: T) -> Result<(), TrySendError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(value));
        }
        if st.full() {
            return Err(TrySendError::Full(value));
        }
        st.queue.push_back(value);
        drop(st);
        self.shared.cv.notify_one();
        Ok(())
    }

    /// True once the channel can no longer deliver (every receiver
    /// dropped, or closed from the receiving side). The scheduler's
    /// retire-check uses this to spot abandoned requests without paying
    /// for a failed send.
    pub fn is_closed(&self) -> bool {
        self.shared.state.lock().unwrap().closed
    }

    /// Number of items currently queued (racy in general; exact for a
    /// sole sender, since concurrent receives only shrink it — the
    /// lifecycle emitter uses this to leave a slot free for its terminal
    /// event).
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// True iff the channel closed because the LAST receiver dropped
    /// (worker death) — as opposed to an orderly consumer-side
    /// [`Receiver::close`], which drains and answers the backlog itself.
    /// When true, anything still queued is stranded until a producer
    /// takes it back via [`Sender::reclaim`].
    pub fn is_lost(&self) -> bool {
        self.shared.state.lock().unwrap().lost
    }

    /// Drain every queued item back to the producer. Only meaningful on
    /// a closed channel (receivers may still pop on an open one); the
    /// coordinator uses this after [`Sender::is_lost`] to fail the
    /// stranded jobs' lifecycles instead of leaving their clients
    /// waiting forever.
    pub fn reclaim(&self) -> Vec<T> {
        let mut st = self.shared.state.lock().unwrap();
        st.queue.drain(..).collect()
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        Sender {
            shared: Arc::clone(&self.shared),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            drop(st);
            self.shared.cv.notify_all();
            self.shared.cv_space.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Pop the next item without blocking.
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut st = self.shared.state.lock().unwrap();
        match st.queue.pop_front() {
            Some(v) => {
                drop(st);
                self.shared.cv_space.notify_one();
                Ok(v)
            }
            None if st.closed => Err(TryRecvError::Disconnected),
            None => Err(TryRecvError::Empty),
        }
    }

    /// Block until the next item (or disconnection). Items still queued on
    /// a closed channel are delivered before `Disconnected` is reported.
    pub fn recv(&self) -> Result<T, RecvTimeoutError> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.shared.cv_space.notify_one();
                return Ok(v);
            }
            if st.closed {
                return Err(RecvTimeoutError::Disconnected);
            }
            st = self.shared.cv.wait(st).unwrap();
        }
    }

    /// Block up to `timeout` for the next item. Items still queued on a
    /// closed channel are delivered before `Disconnected` is reported.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.shared.cv_space.notify_one();
                return Ok(v);
            }
            if st.closed {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, _) = self.shared.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close the channel from the receiving side: all subsequent `send`s
    /// fail and all blocked receivers wake. Queued items remain available
    /// via [`Receiver::try_recv`] so the closer can drain-and-fail them.
    pub fn close(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.shared.cv.notify_all();
        self.shared.cv_space.notify_all();
    }

    /// True once the channel is closed from either side. Queued items may
    /// still be pending — combine with [`Receiver::is_empty`] to detect
    /// full shutdown (used by draining workers that must keep polling a
    /// side queue without blocking in `recv`).
    pub fn is_closed(&self) -> bool {
        self.shared.state.lock().unwrap().closed
    }

    /// Number of items currently queued (racy; diagnostics only).
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// True when no items are queued (racy; diagnostics only).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().receivers += 1;
        Receiver {
            shared: Arc::clone(&self.shared),
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            // Nobody can ever drain the queue again: close so senders see
            // an abandoned channel instead of enqueueing into the void,
            // and flag the loss so producers can reclaim whatever was
            // queued (an explicit `close()` does NOT set `lost` — that
            // path drains and answers the backlog itself).
            st.closed = true;
            st.lost = true;
            drop(st);
            self.shared.cv_space.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;

    #[test]
    fn delivers_each_item_exactly_once_across_consumers() {
        let (tx, rx) = channel::<usize>();
        let total = Arc::new(AtomicUsize::new(0));
        let count = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let rx = rx.clone();
                let total = Arc::clone(&total);
                let count = Arc::clone(&count);
                thread::spawn(move || loop {
                    match rx.recv_timeout(Duration::from_secs(5)) {
                        Ok(v) => {
                            total.fetch_add(v, Ordering::SeqCst);
                            count.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(RecvTimeoutError::Disconnected) => break,
                        Err(RecvTimeoutError::Timeout) => panic!("starved"),
                    }
                })
            })
            .collect();
        for i in 0..1000 {
            tx.send(i).unwrap();
        }
        drop(tx);
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(count.load(Ordering::SeqCst), 1000);
        assert_eq!(total.load(Ordering::SeqCst), 1000 * 999 / 2);
    }

    #[test]
    fn sender_drop_disconnects_after_drain() {
        let (tx, rx) = channel::<u32>();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.try_recv(), Ok(1));
        assert_eq!(rx.recv_timeout(Duration::from_millis(1)), Ok(2));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(1)),
            Err(RecvTimeoutError::Disconnected)
        );
    }

    #[test]
    fn receiver_close_fails_senders_and_leaves_queue_drainable() {
        let (tx, rx) = channel::<u32>();
        tx.send(7).unwrap();
        rx.close();
        assert!(tx.send(8).is_err());
        assert_eq!(rx.try_recv(), Ok(7));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }

    /// The scheduler-shutdown contract under contention: jobs carry reply
    /// channels; several workers drain the queue concurrently and exit
    /// after a bounded amount of work; the LAST worker out closes the
    /// queue and fails the backlog. Every accepted job must be answered —
    /// served or failed, never silently dropped.
    #[test]
    fn close_under_contention_fails_queued_jobs_instead_of_dropping() {
        use std::sync::mpsc;
        const WORKERS: usize = 4;
        const PER_WORKER: usize = 25;
        const JOBS: usize = 500;
        let (tx, rx) = channel::<(usize, mpsc::Sender<Result<usize, &'static str>>)>();
        // Queue the full backlog up front so the worker capacity
        // (WORKERS * PER_WORKER < JOBS) deterministically leaves a backlog
        // for the closer to fail.
        let mut replies = vec![];
        for i in 0..JOBS {
            let (rtx, rrx) = mpsc::channel();
            tx.send((i, rtx)).unwrap();
            replies.push(rrx);
        }
        let live = Arc::new(AtomicUsize::new(WORKERS));
        let workers: Vec<_> = (0..WORKERS)
            .map(|_| {
                let rx = rx.clone();
                let live = Arc::clone(&live);
                thread::spawn(move || {
                    for _ in 0..PER_WORKER {
                        match rx.recv_timeout(Duration::from_secs(5)) {
                            Ok((v, reply)) => {
                                let _ = reply.send(Ok(v));
                            }
                            Err(_) => break,
                        }
                    }
                    // last-one-out: close and fail whatever is left queued
                    if live.fetch_sub(1, Ordering::SeqCst) == 1 {
                        rx.close();
                        while let Ok((_, reply)) = rx.try_recv() {
                            let _ = reply.send(Err("shut down"));
                        }
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        // Every queued job got an answer (served or failed) — no reply
        // sender was dropped unanswered.
        let mut served = 0usize;
        let mut failed = 0usize;
        for rrx in replies {
            match rrx.recv() {
                Ok(Ok(_)) => served += 1,
                Ok(Err(_)) => failed += 1,
                Err(_) => panic!("queued job dropped without an answer"),
            }
        }
        assert_eq!(served + failed, JOBS);
        assert_eq!(served, WORKERS * PER_WORKER);
        assert_eq!(failed, JOBS - WORKERS * PER_WORKER);
        // and the channel stays closed for late senders
        let (rtx, _rrx) = mpsc::channel();
        assert!(tx.send((0, rtx)).is_err());
    }

    #[test]
    fn bounded_try_send_reports_full_until_drained() {
        let (tx, rx) = bounded::<u32>(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        match tx.try_send(3) {
            Err(TrySendError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        // popping one frees one slot
        assert_eq!(rx.try_recv(), Ok(1));
        tx.try_send(3).unwrap();
        assert_eq!(rx.try_recv(), Ok(2));
        assert_eq!(rx.try_recv(), Ok(3));
    }

    #[test]
    fn bounded_blocking_send_waits_for_space() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let t = {
            let tx = tx.clone();
            thread::spawn(move || tx.send(2).unwrap())
        };
        // The sender is blocked on the full queue until we drain.
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.try_recv(), Ok(1));
        t.join().unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)), Ok(2));
    }

    #[test]
    fn unbounded_try_send_never_full() {
        let (tx, rx) = channel::<u32>();
        for i in 0..10_000 {
            tx.try_send(i).unwrap();
        }
        assert_eq!(rx.len(), 10_000);
    }

    #[test]
    fn dropping_last_receiver_closes_channel() {
        let (tx, rx) = bounded::<u32>(4);
        let rx2 = rx.clone();
        assert!(!tx.is_closed());
        drop(rx);
        assert!(!tx.is_closed(), "one receiver still alive");
        drop(rx2);
        assert!(tx.is_closed());
        match tx.try_send(1) {
            Err(TrySendError::Closed(1)) => {}
            other => panic!("expected Closed(1), got {other:?}"),
        }
        assert!(tx.send(2).is_err());
    }

    /// Regression: the last receiver dying with items still queued used
    /// to strand them silently — the channel closed, but nothing could
    /// drain the backlog and producers had no way to tell worker-death
    /// from orderly shutdown. Now the loss is flagged and the producer
    /// reclaims the queued items to fail them explicitly.
    #[test]
    fn last_receiver_drop_with_backlog_is_reclaimable() {
        let (tx, rx) = bounded::<u32>(8);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        tx.try_send(3).unwrap();
        assert!(!tx.is_lost());
        drop(rx); // worker death: 3 items stranded
        assert!(tx.is_closed());
        assert!(tx.is_lost(), "last-receiver drop must flag the loss");
        assert_eq!(tx.reclaim(), vec![1, 2, 3]);
        assert_eq!(tx.len(), 0, "reclaim drains the backlog");
        // An orderly consumer-side close is NOT a loss: that path drains
        // and answers the backlog itself.
        let (tx2, rx2) = bounded::<u32>(8);
        tx2.try_send(9).unwrap();
        rx2.close();
        assert!(tx2.is_closed());
        assert!(!tx2.is_lost());
        assert_eq!(rx2.try_recv(), Ok(9));
    }

    #[test]
    fn receiver_drop_unblocks_full_sender() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let t = thread::spawn(move || tx.send(2));
        thread::sleep(Duration::from_millis(20));
        drop(rx); // closes; the blocked sender must wake with an error
        assert!(t.join().unwrap().is_err());
    }

    #[test]
    fn recv_blocks_until_item_or_disconnect() {
        let (tx, rx) = channel::<u32>();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(10));
            tx.send(7).unwrap();
        });
        assert_eq!(rx.recv(), Ok(7));
        t.join().unwrap();
        // all senders gone -> Disconnected
        assert_eq!(rx.recv(), Err(RecvTimeoutError::Disconnected));
    }

    #[test]
    fn recv_times_out_while_open() {
        let (tx, rx) = channel::<u32>();
        let t0 = Instant::now();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Timeout)
        );
        assert!(t0.elapsed() >= Duration::from_millis(10));
        drop(tx);
    }

    #[test]
    fn clone_keeps_channel_open() {
        let (tx, rx) = channel::<u32>();
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(5).unwrap();
        assert_eq!(rx.try_recv(), Ok(5));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        drop(tx2);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }
}
