//! JSON substrate: parser + serializer (no `serde` facade offline).
//!
//! Used for: model_meta.json / mask fixtures (artifacts), the HTTP request
//! protocol of the coordinator, and the metrics endpoint. Implements the
//! full JSON grammar (RFC 8259) with \u escapes; numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let h = self.hex4()?;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&h) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((h as u32 - 0xD800) << 10)
                                        + (lo as u32 - 0xDC00);
                                    out.push(
                                        char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(h as u32).ok_or_else(|| self.err("bad \\u"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u"));
        }
        let s = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#,
            r#"[[],{},"",0,-1]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}
