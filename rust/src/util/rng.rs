//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! xoshiro256** seeded via splitmix64 — the standard pairing recommended by
//! the xoshiro authors. Used everywhere randomness is needed: sampling
//! (categorical draws in the decoders), data generation, masking
//! distributions, and the property-testing harness.

/// splitmix64: used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-request / per-slot RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n), sorted ascending.
    pub fn choose_sorted(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        debug_assert!(total.is_finite());
        if total <= 0.0 {
            // Degenerate: fall back to uniform.
            return self.below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.1).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn choose_sorted_distinct_and_sorted() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let n = r.range(1, 50);
            let k = r.below(n + 1);
            let c = r.choose_sorted(n, k);
            assert_eq!(c.len(), k);
            for w in c.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &x in &c {
                assert!(x < n);
            }
        }
    }

    #[test]
    fn categorical_matches_weights() {
        let mut r = Rng::new(11);
        let w = [1.0f32, 3.0, 0.0, 6.0];
        let mut counts = [0usize; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[2], 0);
        let p1 = counts[1] as f64 / n as f64;
        let p3 = counts[3] as f64 / n as f64;
        assert!((p1 - 0.3).abs() < 0.01, "p1={p1}");
        assert!((p3 - 0.6).abs() < 0.01, "p3={p3}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
