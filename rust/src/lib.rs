//! # asarm — Any-Subset Autoregressive Models with ASSD
//!
//! Reproduction of *"Reviving Any-Subset Autoregressive Models with
//! Principled Parallel Sampling and Speculative Decoding"* (Guo & Ermon,
//! 2025) as a three-layer Rust + JAX + Pallas serving stack:
//!
//! * **Layer 1/2 (build-time python)** — `python/compile/`: Pallas masked
//!   two-stream attention + fused xent kernels, the XLNet-style AS-ARM
//!   model, AOT-lowered once to HLO text artifacts.
//! * **Layer 3 (this crate)** — the serving system: PJRT runtime with a
//!   multi-replica engine pool, mask construction, the ASSD decoder
//!   family with its pluggable draft subsystem (self / bigram /
//!   prompt-lookup drafters plus adaptive speculation control), a
//!   continuous-batching coordinator (bounded admission queue with load
//!   shedding, one worker per replica, per-request lifecycle: streamed
//!   token commits, cancellation, deadlines) with an HTTP + SSE front
//!   end, per-request tracing with Chrome-trace export and Prometheus
//!   exposition (`obs`), the rust training loop, and the
//!   evaluation/benchmark harness reproducing every table and figure of
//!   the paper.
//!
//! See README.md for how to run everything and docs/ARCHITECTURE.md for
//! the serving architecture (request lifecycle, engine pool, batching
//! invariants, NFE accounting).

pub mod coordinator;
pub mod data;
pub mod decode;
pub mod draft;
pub mod eval;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod tokenizer;
pub mod train;
pub mod util;
