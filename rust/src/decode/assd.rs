//! Any-Subset Speculative Decoding — the paper's Algorithm 1.
//!
//! Each loop iteration:
//!   1. DRAFT: speculate k tokens in parallel from the conditionally
//!      independent distribution p(. | x_sigma(<n)) (Fig. 1a masks). With
//!      self-drafting this is one forward of the AS-ARM; with the n-gram
//!      variant (Algorithm 2) it is a table lookup (aux NFE).
//!   2. If only one token remained, accept it outright (Lemma 1 shows its
//!      draft density equals the oracle density) — 1 NFE for the last token.
//!   3. VERIFY: one forward with the causal-like Fig. 1b masks yields the
//!      oracle densities q_i = p(x~_sigma(i) | x_sigma(<n), x~_sigma[n:i))
//!      for ALL speculated i simultaneously.
//!   4. Accept x~_i while r < min(1, q_i/p_i); on first rejection resample
//!      from (q - p)_+ (line 22) and continue from there.
//!
//! Theorem 1 (model NFE <= targets decoded) and Theorem 2 (output
//! distribution == sequential/oracle joint) are enforced by tests against
//! the analytic mock engine (tests below + rust/tests/).

use crate::model::mask::{advance_draft_masks, draft_masks, verify_masks, Ordering};
use crate::tokenizer::MASK;
use crate::util::rng::Rng;

use super::ngram::BigramDraft;
use super::sampling::{residual, sample_probs, softmax};
use super::{DecodeMachine, DecodeOutcome, ForwardRequest};

/// Which draft model speculates tokens.
pub enum DraftSource {
    /// The AS-ARM drafts for itself (Alg. 1; Lemma 1 applies).
    SelfModel,
    /// Context bigram table (Alg. 2; cheap but Lemma 1 does NOT apply, so
    /// even the last token is verified).
    NGram,
}

enum Phase {
    Draft,
    Verify,
    Done,
}

pub struct AssdMachine {
    ord: Ordering,
    vocab: usize,
    k: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    // draft-mode masks at state n (incrementally advanced)
    draft_h: Vec<f32>,
    draft_g: Vec<f32>,
    // verify-mode masks (fixed for the whole decode)
    ver_h: Vec<f32>,
    ver_g: Vec<f32>,
    n: usize,
    t: usize,
    phase: Phase,
    draft_source: DraftSource,
    ngram: Option<BigramDraft>,
    // scratch for the current iteration
    drafted: Vec<u32>,        // tokens for orders n..t
    draft_probs: Vec<Vec<f32>>, // full p(.|x_sigma(<n)) rows for orders n..t
    // stats
    model_nfe: u64,
    aux_nfe: u64,
    iterations: u64,
    accepted: u64,
    proposed: u64,
    /// Lemma 1 instrumentation: rejections of the FIRST speculated token
    /// (must stay 0 for SelfModel drafting).
    pub first_token_rejections: u64,
}

impl AssdMachine {
    pub fn new(
        ord: Ordering,
        tokens: Vec<u32>,
        vocab: usize,
        k: usize,
        temp: f32,
        rng: Rng,
        draft_source: DraftSource,
    ) -> Self {
        assert!(k >= 1);
        assert_eq!(tokens.len(), ord.n());
        for (pos, &t) in tokens.iter().enumerate() {
            if ord.is_prompt_pos(pos) {
                assert_ne!(t, MASK, "prompt position {pos} is MASK");
            } else {
                assert_eq!(t, MASK, "target position {pos} must start as MASK");
            }
        }
        let n = ord.m;
        let (draft_h, draft_g) = draft_masks(&ord, n);
        let (ver_h, ver_g) = verify_masks(&ord);
        let ngram = match draft_source {
            DraftSource::NGram => Some(BigramDraft::from_sequence(&tokens, vocab)),
            DraftSource::SelfModel => None,
        };
        let phase = if n >= ord.n() { Phase::Done } else { Phase::Draft };
        AssdMachine {
            ord,
            vocab,
            k,
            temp,
            rng,
            tokens,
            draft_h,
            draft_g,
            ver_h,
            ver_g,
            n,
            t: n,
            phase,
            draft_source,
            ngram,
            drafted: vec![],
            draft_probs: vec![],
            model_nfe: 0,
            aux_nfe: 0,
            iterations: 0,
            accepted: 0,
            proposed: 0,
            first_token_rejections: 0,
        }
    }

    /// N-gram drafting happens synchronously (no forward needed): fill the
    /// window, record p-rows from the bigram table, move to Verify.
    fn ngram_draft(&mut self) {
        let nseq = self.ord.n();
        self.t = (self.n + self.k).min(nseq);
        self.drafted.clear();
        self.draft_probs.clear();
        let ng = self.ngram.as_ref().expect("ngram table");
        let mut dists = Vec::with_capacity(self.t - self.n);
        {
            // Theorem 3: left neighbour of sigma(i) is known or drafted
            // earlier in this window (lattice keeps targets sorted).
            for i in self.n..self.t {
                let pos = self.ord.sigma[i];
                let prev = if pos == 0 {
                    None
                } else {
                    let left = self.tokens[pos - 1];
                    if left != MASK {
                        Some(left)
                    } else {
                        // drafted earlier in this window
                        debug_assert!(self.drafted.iter().len() > 0 || true);
                        let oi = self.ord.order[pos - 1];
                        if oi >= self.n && oi < i {
                            Some(self.drafted[oi - self.n])
                        } else {
                            None
                        }
                    }
                };
                let dist = ng.dist(prev);
                let tok = sample_probs(&mut self.rng, &dist) as u32;
                self.drafted.push(tok);
                dists.push(dist);
            }
        }
        self.draft_probs = dists;
        self.aux_nfe += 1;
        // fill drafts into the sequence for the verify pass
        for i in self.n..self.t {
            self.tokens[self.ord.sigma[i]] = self.drafted[i - self.n];
        }
        self.phase = Phase::Verify;
    }

    fn finish_iteration(&mut self, n_new: usize) {
        advance_draft_masks(&self.ord, self.n, n_new, &mut self.draft_h, &mut self.draft_g);
        // update the n-gram table with newly fixed tokens
        if self.ngram.is_some() {
            let mut obs: Vec<(Option<u32>, u32, Option<u32>)> = vec![];
            for i in self.n..n_new {
                let pos = self.ord.sigma[i];
                let tok = self.tokens[pos];
                let left = if pos > 0 { Some(self.tokens[pos - 1]) } else { None };
                let right = if pos + 1 < self.tokens.len() {
                    Some(self.tokens[pos + 1])
                } else {
                    None
                };
                obs.push((left, tok, right));
            }
            let ng = self.ngram.as_mut().unwrap();
            for (left, tok, right) in obs {
                ng.observe_unigram(tok);
                if let Some(l) = left {
                    if l != MASK {
                        ng.observe(l, tok);
                    }
                }
                if let Some(r) = right {
                    if r != MASK {
                        ng.observe(tok, r);
                    }
                }
            }
        }
        self.n = n_new;
        self.iterations += 1;
        self.phase = if self.n >= self.ord.n() {
            Phase::Done
        } else {
            Phase::Draft
        };
    }
}

impl DecodeMachine for AssdMachine {
    fn done(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    fn forward_request(&mut self) -> Option<ForwardRequest<'_>> {
        loop {
            match self.phase {
                Phase::Done => return None,
                Phase::Draft => match self.draft_source {
                    DraftSource::SelfModel => {
                        return Some(ForwardRequest {
                            tokens: &self.tokens,
                            mask_h: &self.draft_h,
                            mask_g: &self.draft_g,
                        })
                    }
                    DraftSource::NGram => {
                        self.ngram_draft();
                        continue; // now in Verify; fall through
                    }
                },
                Phase::Verify => {
                    return Some(ForwardRequest {
                        tokens: &self.tokens,
                        mask_h: &self.ver_h,
                        mask_g: &self.ver_g,
                    })
                }
            }
        }
    }

    fn absorb(&mut self, logits: &[f32]) {
        let v = self.vocab;
        debug_assert_eq!(logits.len(), self.ord.n() * v);
        match self.phase {
            Phase::Done => panic!("absorb on finished machine"),
            Phase::Draft => {
                // Self-draft forward: sample the window in parallel.
                self.model_nfe += 1;
                let nseq = self.ord.n();
                self.t = (self.n + self.k).min(nseq);
                self.drafted.clear();
                self.draft_probs.clear();
                for i in self.n..self.t {
                    let pos = self.ord.sigma[i];
                    let mut row = logits[pos * v..(pos + 1) * v].to_vec();
                    super::sampling::ban_ids(&mut row, &super::sampling::BANNED);
                    let probs = softmax(&row, self.temp);
                    let tok = sample_probs(&mut self.rng, &probs) as u32;
                    self.drafted.push(tok);
                    self.draft_probs.push(probs);
                }
                // Alg. 1 lines 9-12: if this was the final token, accept it
                // without verification (Lemma 1). Self-draft only.
                if self.n == nseq - 1 {
                    self.tokens[self.ord.sigma[self.n]] = self.drafted[0];
                    let n_new = self.n + 1;
                    self.finish_iteration(n_new);
                    return;
                }
                for i in self.n..self.t {
                    self.tokens[self.ord.sigma[i]] = self.drafted[i - self.n];
                }
                self.phase = Phase::Verify;
            }
            Phase::Verify => {
                self.model_nfe += 1;
                let mut n_new = self.t;
                for i in self.n..self.t {
                    let pos = self.ord.sigma[i];
                    // Same ban as the draft rows: p and q must share support.
                    let mut row = logits[pos * v..(pos + 1) * v].to_vec();
                    super::sampling::ban_ids(&mut row, &super::sampling::BANNED);
                    let q_probs = softmax(&row, self.temp);
                    let drafted = self.drafted[i - self.n] as usize;
                    let p_probs = &self.draft_probs[i - self.n];
                    let q_i = q_probs[drafted] as f64;
                    let p_i = (p_probs[drafted] as f64).max(1e-30);
                    let r = self.rng.f64();
                    self.proposed += 1;
                    if r < (q_i / p_i).min(1.0) {
                        self.accepted += 1;
                        continue;
                    }
                    // rejection: resample from (q - p)_+, clear later drafts
                    if i == self.n {
                        self.first_token_rejections += 1;
                    }
                    let new_tok = match residual(&q_probs, p_probs) {
                        Some(res) => sample_probs(&mut self.rng, &res) as u32,
                        // Residual numerically empty => q == p; sampling q
                        // is then distributionally identical.
                        None => sample_probs(&mut self.rng, &q_probs) as u32,
                    };
                    self.tokens[pos] = new_tok;
                    for j in (i + 1)..self.t {
                        self.tokens[self.ord.sigma[j]] = MASK;
                    }
                    n_new = i + 1;
                    break;
                }
                self.finish_iteration(n_new);
            }
        }
    }

    fn outcome(self: Box<Self>) -> DecodeOutcome {
        assert!(self.done());
        DecodeOutcome {
            tokens: self.tokens,
            model_nfe: self.model_nfe,
            aux_nfe: self.aux_nfe,
            iterations: self.iterations,
            accepted: self.accepted,
            proposed: self.proposed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::{lattice_sigma, sample_sigma, OrderProtocol};
    use crate::decode::{init_tokens, run_machine};
    use crate::runtime::mock::MockEngine;
    use crate::runtime::Engine;
    use crate::util::propcheck;

    fn decode_assd(
        e: &MockEngine,
        ord: &Ordering,
        toks: &[u32],
        k: usize,
        seed: u64,
        src: DraftSource,
    ) -> (DecodeOutcome, u64) {
        let m = AssdMachine::new(
            ord.clone(),
            toks.to_vec(),
            e.vocab(),
            k,
            1.0,
            Rng::new(seed),
            src,
        );
        let first_rej = std::cell::Cell::new(0u64);
        // run manually to read instrumentation before consuming
        let mut mach = Box::new(m);
        while !mach.done() {
            let (t, h, g) = {
                let r = mach.forward_request().unwrap();
                (r.tokens.to_vec(), r.mask_h.to_vec(), r.mask_g.to_vec())
            };
            let logits = e.forward(1, &t, &h, &g).unwrap();
            mach.absorb(&logits);
        }
        first_rej.set(mach.first_token_rejections);
        (mach.outcome(), first_rej.get())
    }

    #[test]
    fn completes_and_respects_prompt() {
        let e = MockEngine::new(1, 10, 6, 1.0);
        let ord = Ordering::new(lattice_sigma(&[2, 7], 10), 2);
        let toks = init_tokens(&ord, &[(2, 3), (7, 1)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 5, 9, DraftSource::SelfModel);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert_eq!(out.tokens[2], 3);
        assert_eq!(out.tokens[7], 1);
    }

    /// Theorem 1: model NFE never exceeds the number of target tokens.
    #[test]
    fn prop_theorem1_nfe_bound() {
        propcheck::check_no_shrink(
            21,
            60,
            |r: &mut Rng| {
                let n = r.range(2, 14);
                let m = r.range(1, n);
                let k = r.range(2, 7);
                let seed = r.next_u64();
                (n, m, k, seed)
            },
            |&(n, m, k, seed)| {
                let e = MockEngine::new(seed ^ 1, n, 4, 1.0);
                let mut r = Rng::new(seed);
                let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                let ord = Ordering::new(sigma, m);
                let prompt: Vec<(usize, u32)> = (0..n)
                    .filter(|&p| ord.is_prompt_pos(p))
                    .map(|p| (p, r.below(4) as u32))
                    .collect();
                let toks = init_tokens(&ord, &prompt);
                let (out, _) = decode_assd(&e, &ord, &toks, k, seed ^ 2, DraftSource::SelfModel);
                let targets = (n - m) as u64;
                if out.model_nfe > targets {
                    return Err(format!(
                        "NFE {} > targets {targets} (n={n} m={m} k={k})",
                        out.model_nfe
                    ));
                }
                if out.tokens.iter().any(|&t| t == MASK) {
                    return Err("MASK left in output".into());
                }
                Ok(())
            },
        );
    }

    /// Lemma 1: the first speculated token in each iteration is always
    /// accepted under self-drafting.
    #[test]
    fn prop_lemma1_first_token_always_accepted() {
        propcheck::check_no_shrink(
            22,
            60,
            |r: &mut Rng| (r.range(3, 14), r.range(2, 6), r.next_u64()),
            |&(n, k, seed)| {
                let m = 1 + (seed as usize % (n - 1));
                let e = MockEngine::new(seed ^ 3, n, 5, 1.5);
                let mut r = Rng::new(seed);
                let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                let ord = Ordering::new(sigma, m);
                let prompt: Vec<(usize, u32)> = (0..n)
                    .filter(|&p| ord.is_prompt_pos(p))
                    .map(|p| (p, r.below(5) as u32))
                    .collect();
                let toks = init_tokens(&ord, &prompt);
                let (_, first_rej) = decode_assd(&e, &ord, &toks, k, seed ^ 4, DraftSource::SelfModel);
                if first_rej > 0 {
                    return Err(format!("{first_rej} first-token rejections"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ngram_variant_completes() {
        let e = MockEngine::new(5, 12, 5, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 5, 11], 12), 3);
        let toks = init_tokens(&ord, &[(0, 2), (5, 4), (11, 0)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 4, 17, DraftSource::NGram);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert!(out.aux_nfe > 0);
        // model NFE for ngram = verify passes only
        assert!(out.model_nfe <= 12);
    }

    /// Theorem 2 (statistical): ASSD's output distribution equals
    /// sequential decoding's, measured by total-variation distance over the
    /// full support of a small case.
    #[test]
    fn theorem2_assd_matches_sequential_distribution() {
        let n = 4;
        let v = 3;
        let e = MockEngine::new(77, n, v, 1.2);
        let ord = Ordering::new(lattice_sigma(&[1], n), 1);
        let toks = init_tokens(&ord, &[(1, 2)]);
        let samples = 20_000;

        let enc = |t: &[u32]| -> usize {
            (t[0] as usize) * v * v + (t[2] as usize) * v + (t[3] as usize)
        };
        let mut seq_counts = vec![0f64; v * v * v];
        let mut assd_counts = vec![0f64; v * v * v];
        for s in 0..samples {
            let m = crate::decode::sequential::SequentialMachine::new(
                ord.clone(),
                toks.clone(),
                v,
                1.0,
                Rng::new(1000 + s),
            );
            let out = run_machine(&e, Box::new(m)).unwrap();
            seq_counts[enc(&out.tokens)] += 1.0;

            let (out2, _) = decode_assd(&e, &ord, &toks, 3, 500_000 + s, DraftSource::SelfModel);
            assd_counts[enc(&out2.tokens)] += 1.0;
        }
        let tv: f64 = seq_counts
            .iter()
            .zip(&assd_counts)
            .map(|(a, b)| (a / samples as f64 - b / samples as f64).abs())
            .sum::<f64>()
            / 2.0;
        // MC noise for 27 cells at 20k samples is well under 0.02.
        assert!(tv < 0.025, "TV distance {tv} too large — Theorem 2 violated?");
    }

    /// Theorem 2 holds for the n-gram draft too (speculative decoding is
    /// draft-agnostic).
    #[test]
    fn theorem2_ngram_matches_sequential_distribution() {
        let n = 4;
        let v = 3;
        let e = MockEngine::new(78, n, v, 1.2);
        let ord = Ordering::new(lattice_sigma(&[0], n), 1);
        let toks = init_tokens(&ord, &[(0, 1)]);
        let samples = 20_000;
        let enc = |t: &[u32]| -> usize {
            (t[1] as usize) * v * v + (t[2] as usize) * v + (t[3] as usize)
        };
        let mut seq_counts = vec![0f64; v * v * v];
        let mut ng_counts = vec![0f64; v * v * v];
        for s in 0..samples {
            let m = crate::decode::sequential::SequentialMachine::new(
                ord.clone(),
                toks.clone(),
                v,
                1.0,
                Rng::new(2000 + s),
            );
            let out = run_machine(&e, Box::new(m)).unwrap();
            seq_counts[enc(&out.tokens)] += 1.0;
            let (out2, _) = decode_assd(&e, &ord, &toks, 3, 700_000 + s, DraftSource::NGram);
            ng_counts[enc(&out2.tokens)] += 1.0;
        }
        let tv: f64 = seq_counts
            .iter()
            .zip(&ng_counts)
            .map(|(a, b)| (a / samples as f64 - b / samples as f64).abs())
            .sum::<f64>()
            / 2.0;
        assert!(tv < 0.025, "TV distance {tv} too large for n-gram ASSD");
    }

    #[test]
    fn k1_completes_but_violates_theorem1_bound() {
        // The paper instructs k >= 2: with k = 1 each iteration decodes ONE
        // token with TWO forwards, so the NFE bound of Theorem 1 does not
        // apply (its proof needs two tokens per iteration). Completion and
        // distribution correctness still hold.
        let e = MockEngine::new(9, 8, 4, 1.0);
        let ord = Ordering::new(lattice_sigma(&[3], 8), 1);
        let toks = init_tokens(&ord, &[(3, 2)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 1, 13, DraftSource::SelfModel);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        let targets = 7u64;
        assert!(out.model_nfe <= 2 * targets);
        assert!(out.model_nfe >= targets, "k=1 cannot beat sequential");
    }

    #[test]
    fn single_target_needs_one_nfe() {
        let e = MockEngine::new(10, 5, 4, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 1, 2, 3], 5), 4);
        let toks = init_tokens(&ord, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 5, 3, DraftSource::SelfModel);
        assert_eq!(out.model_nfe, 1, "final-token shortcut (Lemma 1) not taken");
        assert!(out.tokens[4] != MASK);
    }
}
