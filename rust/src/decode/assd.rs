//! Any-Subset Speculative Decoding — the paper's Algorithm 1, generalized
//! over pluggable draft sources ([`crate::draft`]).
//!
//! Each loop iteration:
//!   1. DRAFT: speculate up to k tokens for the window of orders n..t. With
//!      [`SelfDrafter`](crate::draft::SelfDrafter) this is one forward of
//!      the AS-ARM under the Fig. 1a draft masks (model NFE; Lemma 1
//!      applies); external drafters (bigram — Algorithm 2 —, prompt
//!      lookup) propose synchronously from the live sequence (aux NFE).
//!   2. If only one token remained and the drafter is Lemma-1 exact,
//!      accept it outright — 1 NFE for the last token.
//!   3. VERIFY: one forward with the causal-like Fig. 1b masks yields the
//!      oracle densities q_i = p(x~_sigma(i) | x_sigma(<n), x~_sigma[n:i))
//!      for ALL speculated i simultaneously.
//!   4. Accept x~_i while r < min(1, q_i/p_i); on first rejection resample
//!      from (q - p)_+ (line 22) and continue from there. The outcome is
//!      fed back to the drafter and to the [`AdaptiveSpeculation`]
//!      controller, which retunes the window length k.
//!
//! Theorem 1 (model NFE <= targets decoded, self-drafting with k >= 2) and
//! Theorem 2 (output distribution == sequential/oracle joint, for EVERY
//! drafter — speculative accept/resample is proposal-agnostic) are
//! enforced by tests against the analytic mock engine (tests below +
//! rust/tests/).

use crate::draft::{AdaptiveSpeculation, DraftContext, DraftKind, DraftOptions, Drafter};
use crate::model::mask::Ordering;
use crate::obs::flight;
use crate::tokenizer::MASK;
use crate::util::rng::Rng;

use super::sampling::{residual_into, sample_probs, softmax_into};
use super::{DecodeMachine, DecodeOutcome, ForwardRequest};

#[derive(Clone, Copy)]
enum Phase {
    Draft,
    Verify,
    Done,
}

/// Frozen [`AssdMachine`] state (see [`crate::decode::snapshot`]). The
/// phase/`t`/drafted-window fields are captured verbatim because a
/// checkpoint may land between the draft absorb and the verify forward —
/// the draft sampling already consumed RNG, so rolling back to re-draft
/// would diverge. Scratch buffers (`want`, the vocab-sized softmax /
/// residual rows) are recomputed on restore.
pub struct AssdSnapshot {
    ord: Ordering,
    vocab: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    n: usize,
    t: usize,
    phase: Phase,
    drafter: Box<dyn Drafter>,
    spec: AdaptiveSpeculation,
    drafted: Vec<u32>,
    draft_probs: Vec<Vec<f32>>,
    committed: Vec<(usize, u32)>,
    model_nfe: u64,
    aux_nfe: u64,
    iterations: u64,
    accepted: u64,
    proposed: u64,
    first_token_rejections: u64,
}

pub struct AssdMachine {
    ord: Ordering,
    vocab: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    // rows requested by the current phase (window positions sigma[n..t]):
    // the compact forward ABI carries (ord, known) instead of materialized
    // masks, so this is the only per-step buffer the machine maintains
    want: Vec<usize>,
    n: usize,
    t: usize,
    phase: Phase,
    drafter: Box<dyn Drafter>,
    spec: AdaptiveSpeculation,
    // scratch for the current iteration
    drafted: Vec<u32>,          // tokens for orders n..t
    draft_probs: Vec<Vec<f32>>, // full p(.|x_sigma(<n)) rows for orders n..t
    // tokens accepted since the last drain_commits (streaming hook):
    // exactly the accepted prefix of each speculation window plus the
    // resampled token — never unverified drafts
    committed: Vec<(usize, u32)>,
    // vocab-sized scratch reused across verify rows (one row copy, its
    // softmax, and the rejection residual per row — allocating these
    // fresh per row per iteration was the decode loop's dominant
    // allocator traffic)
    row_buf: Vec<f32>,
    q_buf: Vec<f32>,
    res_buf: Vec<f32>,
    // stats
    model_nfe: u64,
    aux_nfe: u64,
    iterations: u64,
    accepted: u64,
    proposed: u64,
    /// Lemma 1 instrumentation: rejections of the FIRST speculated token
    /// (must stay 0 for self-drafting).
    pub first_token_rejections: u64,
}

impl AssdMachine {
    /// Build a machine around an explicit drafter + speculation controller
    /// (the general form; the scheduler and benches construct these from a
    /// [`DraftOptions`]).
    pub fn new(
        ord: Ordering,
        tokens: Vec<u32>,
        vocab: usize,
        spec: AdaptiveSpeculation,
        temp: f32,
        rng: Rng,
        drafter: Box<dyn Drafter>,
    ) -> Self {
        assert!(spec.current() >= 1);
        assert_eq!(tokens.len(), ord.n());
        for (pos, &t) in tokens.iter().enumerate() {
            if ord.is_prompt_pos(pos) {
                assert_ne!(t, MASK, "prompt position {pos} is MASK");
            } else {
                assert_eq!(t, MASK, "target position {pos} must start as MASK");
            }
        }
        let mut spec = spec;
        // Shape clamp: a window can never exceed the target count (and the
        // scheduler additionally clamps to the engine's artifact window).
        spec.clamp_max(ord.n_targets().max(1));
        let n = ord.m;
        let phase = if n >= ord.n() { Phase::Done } else { Phase::Draft };
        AssdMachine {
            ord,
            vocab,
            temp,
            rng,
            tokens,
            want: vec![],
            n,
            t: n,
            phase,
            drafter,
            spec,
            drafted: vec![],
            draft_probs: vec![],
            committed: vec![],
            row_buf: vec![],
            q_buf: vec![],
            res_buf: vec![],
            model_nfe: 0,
            aux_nfe: 0,
            iterations: 0,
            accepted: 0,
            proposed: 0,
            first_token_rejections: 0,
        }
    }

    /// Build drafter + controller from a [`DraftOptions`] — the single
    /// construction path the scheduler and the eval harness share.
    /// `window_cap` is the engine's shape limit (its artifact sequence
    /// length); pass `usize::MAX` when no engine bound applies.
    pub fn from_options(
        ord: Ordering,
        tokens: Vec<u32>,
        vocab: usize,
        opts: DraftOptions,
        window_cap: usize,
        temp: f32,
        rng: Rng,
    ) -> Self {
        let mut spec = opts.speculation();
        // Shape clamp: the draft/verify passes reuse the engine's compiled
        // fwd_b{B} [B, N] executables, so a window can never exceed the
        // artifact sequence length.
        spec.clamp_max(window_cap);
        let drafter = opts.build(&tokens, vocab);
        AssdMachine::new(ord, tokens, vocab, spec, temp, rng, drafter)
    }

    /// Convenience: fixed draft length `k` with the named drafter kind.
    pub fn with_kind(
        ord: Ordering,
        tokens: Vec<u32>,
        vocab: usize,
        k: usize,
        temp: f32,
        rng: Rng,
        kind: DraftKind,
    ) -> Self {
        let opts = DraftOptions {
            kind,
            max_len: k,
            adaptive: false,
        };
        AssdMachine::from_options(ord, tokens, vocab, opts, usize::MAX, temp, rng)
    }

    /// Freeze this machine into an [`AssdSnapshot`] (the
    /// [`DecodeMachine::checkpoint`] payload). Pure clone of the
    /// serialized state; the machine keeps running unaffected.
    pub fn snapshot(&self) -> AssdSnapshot {
        AssdSnapshot {
            ord: self.ord.clone(),
            vocab: self.vocab,
            temp: self.temp,
            rng: self.rng.clone(),
            tokens: self.tokens.clone(),
            n: self.n,
            t: self.t,
            phase: self.phase,
            drafter: self.drafter.boxed_clone(),
            spec: self.spec,
            drafted: self.drafted.clone(),
            draft_probs: self.draft_probs.clone(),
            committed: self.committed.clone(),
            model_nfe: self.model_nfe,
            aux_nfe: self.aux_nfe,
            iterations: self.iterations,
            accepted: self.accepted,
            proposed: self.proposed,
            first_token_rejections: self.first_token_rejections,
        }
    }

    /// Thaw a snapshot back into a machine. Bypasses `new()`'s
    /// fresh-admission invariants (a mid-decode token buffer legitimately
    /// holds committed values and in-flight drafts at target positions);
    /// scratch buffers start empty and are rebuilt by the next
    /// `forward_request`/`absorb` pair.
    pub fn from_snapshot(s: AssdSnapshot) -> Self {
        AssdMachine {
            ord: s.ord,
            vocab: s.vocab,
            temp: s.temp,
            rng: s.rng,
            tokens: s.tokens,
            want: vec![],
            n: s.n,
            t: s.t,
            phase: s.phase,
            drafter: s.drafter,
            spec: s.spec,
            drafted: s.drafted,
            draft_probs: s.draft_probs,
            committed: s.committed,
            row_buf: vec![],
            q_buf: vec![],
            res_buf: vec![],
            model_nfe: s.model_nfe,
            aux_nfe: s.aux_nfe,
            iterations: s.iterations,
            accepted: s.accepted,
            proposed: s.proposed,
            first_token_rejections: s.first_token_rejections,
        }
    }

    /// External (aux-NFE) drafting: fill the window synchronously from the
    /// drafter and move to Verify. No engine forward involved.
    fn external_draft(&mut self) {
        let nseq = self.ord.n();
        self.t = (self.n + self.spec.current()).min(nseq);
        let ctx = DraftContext {
            tokens: &self.tokens,
            ord: &self.ord,
            n: self.n,
            t: self.t,
            temp: self.temp,
            vocab: self.vocab,
        };
        let prop = self.drafter.propose(&ctx, None, &mut self.rng);
        debug_assert_eq!(prop.tokens.len(), self.t - self.n);
        self.drafted = prop.tokens;
        self.draft_probs = prop.dists;
        self.aux_nfe += 1;
        // fill drafts into the sequence for the verify pass
        for i in self.n..self.t {
            self.tokens[self.ord.sigma[i]] = self.drafted[i - self.n];
        }
        self.phase = Phase::Verify;
    }

    /// Fill the wanted-rows buffer with the window positions sigma[n..t].
    fn fill_want(&mut self) {
        self.want.clear();
        self.want.extend_from_slice(&self.ord.sigma[self.n..self.t]);
    }

    fn finish_iteration(&mut self, n_new: usize) {
        // Orders n..n_new are final from here on (accepted prefix +
        // resampled token, or the Lemma-1 final token): record them for
        // the streaming drain — this is the single choke point both the
        // verify and shortcut paths funnel through.
        for i in self.n..n_new {
            let pos = self.ord.sigma[i];
            self.committed.push((pos, self.tokens[pos]));
        }
        // committed-token feedback (e.g. the bigram table learns from the
        // generated text)
        self.drafter
            .observe_commit(&self.tokens, &self.ord, self.n, n_new);
        self.n = n_new;
        self.iterations += 1;
        self.phase = if self.n >= self.ord.n() {
            Phase::Done
        } else {
            Phase::Draft
        };
    }
}

impl DecodeMachine for AssdMachine {
    fn done(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    fn forward_request(&mut self) -> Option<ForwardRequest<'_>> {
        loop {
            match self.phase {
                Phase::Done => return None,
                Phase::Draft => {
                    if self.drafter.needs_model_forward() {
                        // Commit to the window NOW (absorb reuses self.t):
                        // draft state n, rows = the speculation window.
                        self.t = (self.n + self.spec.current()).min(self.ord.n());
                        self.fill_want();
                        return Some(ForwardRequest {
                            tokens: &self.tokens,
                            ord: &self.ord,
                            known: self.n,
                            want: &self.want,
                        });
                    }
                    self.external_draft();
                    continue; // now in Verify; fall through
                }
                Phase::Verify => {
                    // Verify masks = draft masks at full knowledge.
                    self.fill_want();
                    return Some(ForwardRequest {
                        tokens: &self.tokens,
                        ord: &self.ord,
                        known: self.ord.n(),
                        want: &self.want,
                    });
                }
            }
        }
    }

    fn absorb(&mut self, logits: &[f32]) {
        let v = self.vocab;
        debug_assert_eq!(logits.len(), (self.t - self.n) * v, "gathered window rows");
        match self.phase {
            Phase::Done => panic!("absorb on finished machine"),
            Phase::Draft => {
                // Model-forward drafting: sample the window in parallel
                // from the gathered draft-phase rows (window committed in
                // forward_request).
                self.model_nfe += 1;
                let nseq = self.ord.n();
                let ctx = DraftContext {
                    tokens: &self.tokens,
                    ord: &self.ord,
                    n: self.n,
                    t: self.t,
                    temp: self.temp,
                    vocab: self.vocab,
                };
                let prop = self.drafter.propose(&ctx, Some(logits), &mut self.rng);
                debug_assert_eq!(prop.tokens.len(), self.t - self.n);
                self.drafted = prop.tokens;
                self.draft_probs = prop.dists;
                // Alg. 1 lines 9-12: if this was the final token, accept it
                // without verification (Lemma 1). Self-draft only.
                if self.drafter.lemma1_exact() && self.n == nseq - 1 {
                    self.tokens[self.ord.sigma[self.n]] = self.drafted[0];
                    if flight::enabled() {
                        // Lemma 1: the draft row IS the oracle conditional
                        // (p == q exactly), so this is a size-1 window
                        // accepted with probability 1. Pure read of the
                        // draft distribution — the RNG is never touched.
                        let h = flight::entropy(&self.draft_probs[0]);
                        flight::record(flight::FlightEvent::Window {
                            size: 1,
                            outcomes: vec![flight::PosOutcome {
                                outcome: flight::WindowOutcome::Accepted,
                                draft_entropy: h,
                                target_entropy: h,
                                accept_prob: 1.0,
                            }],
                        });
                    }
                    let n_new = self.n + 1;
                    self.finish_iteration(n_new);
                    return;
                }
                for i in self.n..self.t {
                    self.tokens[self.ord.sigma[i]] = self.drafted[i - self.n];
                }
                self.phase = Phase::Verify;
            }
            Phase::Verify => {
                self.model_nfe += 1;
                // Flight recording is pure observation: entropies are
                // computed from the p/q buffers the accept test already
                // built, gated so the off path does zero extra work, and
                // the RNG consumption below is identical either way.
                let flight_on = flight::enabled();
                let mut fl_outcomes: Vec<flight::PosOutcome> = Vec::new();
                let mut n_new = self.t;
                let mut acc_iter = 0usize;
                let mut prop_iter = 0usize;
                for i in self.n..self.t {
                    let pos = self.ord.sigma[i];
                    // Gathered rows are window-major: row i-n ↔ order i.
                    let off = (i - self.n) * v;
                    // Same ban as the draft rows: p and q must share support.
                    self.row_buf.clear();
                    self.row_buf.extend_from_slice(&logits[off..off + v]);
                    super::sampling::ban_ids(&mut self.row_buf, &super::sampling::BANNED);
                    softmax_into(&self.row_buf, self.temp, &mut self.q_buf);
                    let drafted = self.drafted[i - self.n] as usize;
                    let p_probs = &self.draft_probs[i - self.n];
                    let q_i = self.q_buf[drafted] as f64;
                    let p_i = (p_probs[drafted] as f64).max(1e-30);
                    let r = self.rng.f64();
                    prop_iter += 1;
                    let accept_p = (q_i / p_i).min(1.0);
                    if r < accept_p {
                        acc_iter += 1;
                        if flight_on {
                            fl_outcomes.push(flight::PosOutcome {
                                outcome: flight::WindowOutcome::Accepted,
                                draft_entropy: flight::entropy(p_probs),
                                target_entropy: flight::entropy(&self.q_buf),
                                accept_prob: accept_p as f32,
                            });
                        }
                        continue;
                    }
                    // rejection: resample from (q - p)_+, clear later drafts
                    if i == self.n {
                        self.first_token_rejections += 1;
                    }
                    let has_residual = residual_into(&self.q_buf, p_probs, &mut self.res_buf);
                    let new_tok = if has_residual {
                        sample_probs(&mut self.rng, &self.res_buf) as u32
                    } else {
                        // Residual numerically empty => q == p; sampling q
                        // is then distributionally identical.
                        sample_probs(&mut self.rng, &self.q_buf) as u32
                    };
                    if flight_on {
                        fl_outcomes.push(flight::PosOutcome {
                            outcome: if has_residual {
                                flight::WindowOutcome::RejectedResidual
                            } else {
                                flight::WindowOutcome::RejectedFull
                            },
                            draft_entropy: flight::entropy(p_probs),
                            target_entropy: flight::entropy(&self.q_buf),
                            accept_prob: accept_p as f32,
                        });
                    }
                    self.tokens[pos] = new_tok;
                    for j in (i + 1)..self.t {
                        self.tokens[self.ord.sigma[j]] = MASK;
                    }
                    n_new = i + 1;
                    break;
                }
                if flight_on {
                    flight::record(flight::FlightEvent::Window {
                        size: self.t - self.n,
                        outcomes: fl_outcomes,
                    });
                }
                self.proposed += prop_iter as u64;
                self.accepted += acc_iter as u64;
                // acceptance feedback: the controller retunes the window,
                // the drafter may adapt internally
                self.spec.record(acc_iter, prop_iter);
                self.drafter.observe_outcome(acc_iter, prop_iter);
                self.finish_iteration(n_new);
            }
        }
    }

    fn drain_commits(&mut self) -> Vec<(usize, u32)> {
        std::mem::take(&mut self.committed)
    }

    /// ASSD's ordering is fixed at admission and orders `< n` are final
    /// (accepted prefixes + resamples — drafts beyond `n` always roll
    /// back to MASK on rejection), so the engine may cache exactly those
    /// rows.
    fn incremental(&self) -> Option<usize> {
        Some(self.n)
    }

    fn phase(&self) -> super::IterPhase {
        match self.phase {
            Phase::Draft => super::IterPhase::Draft,
            Phase::Verify => super::IterPhase::Verify,
            Phase::Done => super::IterPhase::Decode,
        }
    }

    fn iter_stats(&self) -> super::IterStats {
        super::IterStats {
            model_nfe: self.model_nfe,
            aux_nfe: self.aux_nfe,
            iterations: self.iterations,
            proposed: self.proposed,
            accepted: self.accepted,
            draft_len: self.spec.current(),
        }
    }

    fn checkpoint(&self) -> Option<super::snapshot::DecodeSnapshot> {
        Some(super::snapshot::DecodeSnapshot::Assd(self.snapshot()))
    }

    fn outcome(self: Box<Self>) -> DecodeOutcome {
        assert!(self.done());
        DecodeOutcome {
            tokens: self.tokens,
            model_nfe: self.model_nfe,
            aux_nfe: self.aux_nfe,
            iterations: self.iterations,
            accepted: self.accepted,
            proposed: self.proposed,
            draft_kind: self.drafter.name().to_string(),
            final_draft_len: self.spec.current(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::{lattice_sigma, sample_sigma, OrderProtocol};
    use crate::decode::{init_tokens, run_machine};
    use crate::runtime::mock::MockEngine;
    use crate::runtime::Engine;
    use crate::util::propcheck;

    fn decode_assd(
        e: &MockEngine,
        ord: &Ordering,
        toks: &[u32],
        k: usize,
        seed: u64,
        kind: DraftKind,
    ) -> (DecodeOutcome, u64) {
        decode_assd_opts(
            e,
            ord,
            toks,
            DraftOptions {
                kind,
                max_len: k,
                adaptive: false,
            },
            seed,
        )
    }

    fn decode_assd_opts(
        e: &MockEngine,
        ord: &Ordering,
        toks: &[u32],
        opts: DraftOptions,
        seed: u64,
    ) -> (DecodeOutcome, u64) {
        let drafter = opts.build(toks, e.vocab());
        let m = AssdMachine::new(
            ord.clone(),
            toks.to_vec(),
            e.vocab(),
            opts.speculation(),
            1.0,
            Rng::new(seed),
            drafter,
        );
        // run manually to read instrumentation before consuming
        let mut mach = Box::new(m);
        while !mach.done() {
            let rows = {
                let r = mach.forward_request().unwrap();
                e.forward_ord(std::slice::from_ref(&r)).unwrap().pop().unwrap()
            };
            mach.absorb(&rows);
        }
        let first_rej = mach.first_token_rejections;
        (mach.outcome(), first_rej)
    }

    #[test]
    fn completes_and_respects_prompt() {
        let e = MockEngine::new(1, 10, 6, 1.0);
        let ord = Ordering::new(lattice_sigma(&[2, 7], 10), 2);
        let toks = init_tokens(&ord, &[(2, 3), (7, 1)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 5, 9, DraftKind::SelfModel);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert_eq!(out.tokens[2], 3);
        assert_eq!(out.tokens[7], 1);
        assert_eq!(out.draft_kind, "self");
    }

    /// Theorem 1: model NFE never exceeds the number of target tokens.
    #[test]
    fn prop_theorem1_nfe_bound() {
        propcheck::check_no_shrink(
            21,
            60,
            |r: &mut Rng| {
                let n = r.range(2, 14);
                let m = r.range(1, n);
                let k = r.range(2, 7);
                let seed = r.next_u64();
                (n, m, k, seed)
            },
            |&(n, m, k, seed)| {
                let e = MockEngine::new(seed ^ 1, n, 4, 1.0);
                let mut r = Rng::new(seed);
                let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                let ord = Ordering::new(sigma, m);
                let prompt: Vec<(usize, u32)> = (0..n)
                    .filter(|&p| ord.is_prompt_pos(p))
                    .map(|p| (p, r.below(4) as u32))
                    .collect();
                let toks = init_tokens(&ord, &prompt);
                let (out, _) = decode_assd(&e, &ord, &toks, k, seed ^ 2, DraftKind::SelfModel);
                let targets = (n - m) as u64;
                if out.model_nfe > targets {
                    return Err(format!(
                        "NFE {} > targets {targets} (n={n} m={m} k={k})",
                        out.model_nfe
                    ));
                }
                if out.tokens.iter().any(|&t| t == MASK) {
                    return Err("MASK left in output".into());
                }
                Ok(())
            },
        );
    }

    /// Theorem 1 survives adaptive speculation: the controller's floor of 2
    /// keeps every draft+verify iteration committing at least two tokens.
    #[test]
    fn prop_theorem1_nfe_bound_adaptive() {
        propcheck::check_no_shrink(
            23,
            60,
            |r: &mut Rng| {
                let n = r.range(3, 14);
                let m = r.range(1, n - 1);
                let seed = r.next_u64();
                (n, m, seed)
            },
            |&(n, m, seed)| {
                let e = MockEngine::new(seed ^ 5, n, 4, 1.0);
                let mut r = Rng::new(seed);
                let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                let ord = Ordering::new(sigma, m);
                let prompt: Vec<(usize, u32)> = (0..n)
                    .filter(|&p| ord.is_prompt_pos(p))
                    .map(|p| (p, r.below(4) as u32))
                    .collect();
                let toks = init_tokens(&ord, &prompt);
                let opts = DraftOptions {
                    kind: DraftKind::SelfModel,
                    max_len: 3,
                    adaptive: true,
                };
                let (out, _) = decode_assd_opts(&e, &ord, &toks, opts, seed ^ 6);
                let targets = (n - m) as u64;
                if out.model_nfe > targets {
                    return Err(format!(
                        "adaptive NFE {} > targets {targets} (n={n} m={m})",
                        out.model_nfe
                    ));
                }
                if out.tokens.iter().any(|&t| t == MASK) {
                    return Err("MASK left in output".into());
                }
                if out.final_draft_len < 2 {
                    return Err(format!("window shrank below 2: {}", out.final_draft_len));
                }
                Ok(())
            },
        );
    }

    /// Lemma 1: the first speculated token in each iteration is always
    /// accepted under self-drafting.
    #[test]
    fn prop_lemma1_first_token_always_accepted() {
        propcheck::check_no_shrink(
            22,
            60,
            |r: &mut Rng| (r.range(3, 14), r.range(2, 6), r.next_u64()),
            |&(n, k, seed)| {
                let m = 1 + (seed as usize % (n - 1));
                let e = MockEngine::new(seed ^ 3, n, 5, 1.5);
                let mut r = Rng::new(seed);
                let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                let ord = Ordering::new(sigma, m);
                let prompt: Vec<(usize, u32)> = (0..n)
                    .filter(|&p| ord.is_prompt_pos(p))
                    .map(|p| (p, r.below(5) as u32))
                    .collect();
                let toks = init_tokens(&ord, &prompt);
                let (_, first_rej) =
                    decode_assd(&e, &ord, &toks, k, seed ^ 4, DraftKind::SelfModel);
                if first_rej > 0 {
                    return Err(format!("{first_rej} first-token rejections"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bigram_variant_completes() {
        let e = MockEngine::new(5, 12, 5, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 5, 11], 12), 3);
        let toks = init_tokens(&ord, &[(0, 2), (5, 4), (11, 0)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 4, 17, DraftKind::Bigram);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert!(out.aux_nfe > 0);
        // model NFE for external drafting = verify passes only
        assert!(out.model_nfe <= 12);
        assert_eq!(out.draft_kind, "bigram");
    }

    #[test]
    fn lookup_variant_completes() {
        let e = MockEngine::new(6, 12, 5, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 5, 11], 12), 3);
        let toks = init_tokens(&ord, &[(0, 2), (5, 4), (11, 0)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 4, 19, DraftKind::Lookup);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert!(out.aux_nfe > 0);
        assert!(out.model_nfe <= 12);
        assert_eq!(out.draft_kind, "lookup");
    }

    /// Theorem 2 (statistical): ASSD's output distribution equals
    /// sequential decoding's, measured by total-variation distance over the
    /// full support of a small case.
    #[test]
    fn theorem2_assd_matches_sequential_distribution() {
        let n = 4;
        let v = 3;
        let e = MockEngine::new(77, n, v, 1.2);
        let ord = Ordering::new(lattice_sigma(&[1], n), 1);
        let toks = init_tokens(&ord, &[(1, 2)]);
        let samples = 20_000;

        let enc = |t: &[u32]| -> usize {
            (t[0] as usize) * v * v + (t[2] as usize) * v + (t[3] as usize)
        };
        let mut seq_counts = vec![0f64; v * v * v];
        let mut assd_counts = vec![0f64; v * v * v];
        for s in 0..samples {
            let m = crate::decode::sequential::SequentialMachine::new(
                ord.clone(),
                toks.clone(),
                v,
                1.0,
                Rng::new(1000 + s),
            );
            let out = run_machine(&e, Box::new(m)).unwrap();
            seq_counts[enc(&out.tokens)] += 1.0;

            let (out2, _) = decode_assd(&e, &ord, &toks, 3, 500_000 + s, DraftKind::SelfModel);
            assd_counts[enc(&out2.tokens)] += 1.0;
        }
        let tv: f64 = seq_counts
            .iter()
            .zip(&assd_counts)
            .map(|(a, b)| (a / samples as f64 - b / samples as f64).abs())
            .sum::<f64>()
            / 2.0;
        // MC noise for 27 cells at 20k samples is well under 0.02.
        assert!(tv < 0.025, "TV distance {tv} too large — Theorem 2 violated?");
    }

    /// Theorem 2 holds for EVERY drafter, fixed or adaptive: speculative
    /// accept/resample is proposal-agnostic, so swapping the draft source
    /// may change NFE but never the output law.
    #[test]
    fn theorem2_every_drafter_matches_sequential_distribution() {
        let n = 4;
        let v = 3;
        let e = MockEngine::new(78, n, v, 1.2);
        let ord = Ordering::new(lattice_sigma(&[0], n), 1);
        let toks = init_tokens(&ord, &[(0, 1)]);
        let samples = 12_000u64;
        let enc = |t: &[u32]| -> usize {
            (t[1] as usize) * v * v + (t[2] as usize) * v + (t[3] as usize)
        };
        let mut seq_counts = vec![0f64; v * v * v];
        for s in 0..samples {
            let m = crate::decode::sequential::SequentialMachine::new(
                ord.clone(),
                toks.clone(),
                v,
                1.0,
                Rng::new(2000 + s),
            );
            let out = run_machine(&e, Box::new(m)).unwrap();
            seq_counts[enc(&out.tokens)] += 1.0;
        }
        let configs = [
            (DraftKind::SelfModel, true),
            (DraftKind::Bigram, false),
            (DraftKind::Bigram, true),
            (DraftKind::Lookup, false),
        ];
        for (kind, adaptive) in configs {
            let opts = DraftOptions {
                kind,
                max_len: 3,
                adaptive,
            };
            let mut counts = vec![0f64; v * v * v];
            for s in 0..samples {
                let (out, _) = decode_assd_opts(&e, &ord, &toks, opts, 700_000 + s);
                counts[enc(&out.tokens)] += 1.0;
            }
            let tv: f64 = seq_counts
                .iter()
                .zip(&counts)
                .map(|(a, b)| (a / samples as f64 - b / samples as f64).abs())
                .sum::<f64>()
                / 2.0;
            assert!(
                tv < 0.035,
                "TV distance {tv} too large for drafter {:?} (adaptive={adaptive})",
                kind
            );
        }
    }

    /// Theorem-2 equivalence across FORWARD PATHS: a full ASSD decode
    /// driven through the compact `forward_ord` ABI must be bit-identical
    /// — token stream, model/aux NFE, iteration and acceptance counters,
    /// and engine-side NFE — to the same decode driven through the dense
    /// mask-materializing path, for every drafter, fixed and adaptive.
    /// (The compact ABI is a transport optimization; if it ever changed
    /// the sampled law, this catches it at the first diverging bit.)
    #[test]
    fn compact_and_dense_paths_bit_identical_for_every_drafter() {
        use crate::runtime::DensePath;
        for kind in DraftKind::ALL {
            for adaptive in [false, true] {
                for seed in [3u64, 17, 41] {
                    let n = 14;
                    let v = 6;
                    let mut r = Rng::new(seed);
                    let m = r.range(1, n - 1);
                    let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
                    let ord = Ordering::new(sigma, m);
                    let prompt: Vec<(usize, u32)> = (0..n)
                        .filter(|&p| ord.is_prompt_pos(p))
                        .map(|p| (p, r.below(v) as u32))
                        .collect();
                    let toks = init_tokens(&ord, &prompt);
                    let opts = DraftOptions {
                        kind,
                        max_len: 4,
                        adaptive,
                    };
                    let build = |rng_seed: u64| {
                        let drafter = opts.build(&toks, v);
                        AssdMachine::new(
                            ord.clone(),
                            toks.clone(),
                            v,
                            opts.speculation(),
                            1.2,
                            Rng::new(rng_seed),
                            drafter,
                        )
                    };
                    let e_compact = MockEngine::new(seed ^ 0xA5, n, v, 1.2);
                    let e_dense = MockEngine::new(seed ^ 0xA5, n, v, 1.2);
                    let e_inc = MockEngine::new(seed ^ 0xA5, n, v, 1.2);
                    let out_c = run_machine(&e_compact, Box::new(build(seed ^ 7))).unwrap();
                    let out_d =
                        run_machine(&DensePath(&e_dense), Box::new(build(seed ^ 7))).unwrap();
                    let out_i =
                        crate::decode::run_machine_inc(&e_inc, Box::new(build(seed ^ 7)), 3)
                            .unwrap();
                    let tag = format!("{kind:?} adaptive={adaptive} seed={seed}");
                    assert_eq!(out_c.tokens, out_d.tokens, "tokens diverge: {tag}");
                    assert_eq!(out_c.model_nfe, out_d.model_nfe, "model NFE: {tag}");
                    assert_eq!(out_c.aux_nfe, out_d.aux_nfe, "aux NFE: {tag}");
                    assert_eq!(out_c.iterations, out_d.iterations, "iterations: {tag}");
                    assert_eq!(out_c.proposed, out_d.proposed, "proposed: {tag}");
                    assert_eq!(out_c.accepted, out_d.accepted, "accepted: {tag}");
                    assert_eq!(
                        out_c.final_draft_len, out_d.final_draft_len,
                        "window: {tag}"
                    );
                    assert_eq!(e_compact.nfe(), e_dense.nfe(), "engine NFE: {tag}");
                    // The incremental path rides the same equivalence:
                    // tokens, NFE, and speculation counters all identical.
                    assert_eq!(out_i.tokens, out_d.tokens, "inc tokens diverge: {tag}");
                    assert_eq!(out_i.model_nfe, out_d.model_nfe, "inc model NFE: {tag}");
                    assert_eq!(out_i.aux_nfe, out_d.aux_nfe, "inc aux NFE: {tag}");
                    assert_eq!(out_i.iterations, out_d.iterations, "inc iterations: {tag}");
                    assert_eq!(out_i.proposed, out_d.proposed, "inc proposed: {tag}");
                    assert_eq!(out_i.accepted, out_d.accepted, "inc accepted: {tag}");
                    assert_eq!(e_inc.nfe(), e_dense.nfe(), "inc engine NFE: {tag}");
                }
            }
        }
    }

    /// The non-speculative machines ride the same compact ABI: sequential
    /// and diffusion decodes are bit-identical across paths too —
    /// including the incremental driver (sequential caches every sampled
    /// token; diffusion declines incrementality and falls through to the
    /// compact route inside `run_machine_inc`).
    #[test]
    fn compact_and_dense_paths_bit_identical_for_baseline_samplers() {
        use crate::decode::run_machine_inc;
        use crate::runtime::DensePath;
        let n = 12;
        let v = 5;
        let ord = Ordering::new(lattice_sigma(&[0, 6], n), 2);
        let toks = init_tokens(&ord, &[(0, 2), (6, 4)]);
        for seed in [5u64, 29] {
            let e_c = MockEngine::new(seed ^ 0x33, n, v, 1.0);
            let e_d = MockEngine::new(seed ^ 0x33, n, v, 1.0);
            let e_i = MockEngine::new(seed ^ 0x33, n, v, 1.0);
            let seq = |rng_seed: u64| {
                Box::new(crate::decode::sequential::SequentialMachine::new(
                    ord.clone(),
                    toks.clone(),
                    v,
                    1.0,
                    Rng::new(rng_seed),
                ))
            };
            let seq_c = run_machine(&e_c, seq(seed)).unwrap();
            let seq_d = run_machine(&DensePath(&e_d), seq(seed)).unwrap();
            let seq_i = run_machine_inc(&e_i, seq(seed), 0).unwrap();
            assert_eq!(seq_c.tokens, seq_d.tokens);
            assert_eq!(seq_c.model_nfe, seq_d.model_nfe);
            assert_eq!(seq_i.tokens, seq_d.tokens, "incremental sequential diverged");
            assert_eq!(seq_i.model_nfe, seq_d.model_nfe);
            assert_eq!(e_c.nfe(), e_d.nfe());
            assert_eq!(e_i.nfe(), e_d.nfe());
            let dif = |rng_seed: u64| {
                Box::new(crate::decode::diffusion::DiffusionMachine::new(
                    toks.clone(),
                    v,
                    4,
                    1.0,
                    Rng::new(rng_seed),
                ))
            };
            let dif_c = run_machine(&e_c, dif(seed)).unwrap();
            let dif_d = run_machine(&DensePath(&e_d), dif(seed)).unwrap();
            let dif_i = run_machine_inc(&e_i, dif(seed), 1).unwrap();
            assert_eq!(dif_c.tokens, dif_d.tokens);
            assert_eq!(dif_c.model_nfe, dif_d.model_nfe);
            assert_eq!(dif_i.tokens, dif_d.tokens, "incremental diffusion diverged");
            assert_eq!(dif_i.model_nfe, dif_d.model_nfe);
        }
    }

    /// PREFIX-CACHE exactness across every machine × drafter: the same
    /// request decoded twice on one engine — the second time seeded from
    /// the prefix cache that the first decode's retirement sealed, so the
    /// warm lane skips prefill entirely — must be bit-identical (tokens,
    /// model/aux NFE, iterations, speculation counters) to the cold run
    /// AND to the dense-path reference, for all three machines and every
    /// drafter config. Reorganizing K/V memory (paging, sealing,
    /// copy-on-write, cache seeding) is a transport optimization; if a
    /// cache hit ever changed a sampled bit, Theorem-2 exactness would be
    /// gone and this battery catches it at the first diverging field.
    #[test]
    fn warm_prefix_decode_bit_identical_to_cold_for_every_machine_and_drafter() {
        fn run_warm_cold(
            tag: &str,
            n: usize,
            v: usize,
            mk: &dyn Fn(u64) -> Box<dyn crate::decode::DecodeMachine>,
            expect_cache: bool,
        ) {
            use crate::decode::run_machine_inc;
            use crate::runtime::{DensePath, Engine as _};
            let e = MockEngine::new(0xE11, n, v, 1.2);
            let e_dense = MockEngine::new(0xE11, n, v, 1.2);
            let cold = run_machine_inc(&e, mk(77), 0).unwrap();
            let s0 = e.kv_stats().unwrap();
            let warm = run_machine_inc(&e, mk(77), 0).unwrap();
            let s1 = e.kv_stats().unwrap();
            let dense = run_machine(&DensePath(&e_dense), mk(77)).unwrap();
            assert_eq!(warm.tokens, cold.tokens, "{tag}: warm tokens diverge");
            assert_eq!(warm.model_nfe, cold.model_nfe, "{tag}: warm model NFE");
            assert_eq!(warm.aux_nfe, cold.aux_nfe, "{tag}: warm aux NFE");
            assert_eq!(warm.iterations, cold.iterations, "{tag}: warm iterations");
            assert_eq!(warm.proposed, cold.proposed, "{tag}: warm proposed");
            assert_eq!(warm.accepted, cold.accepted, "{tag}: warm accepted");
            assert_eq!(cold.tokens, dense.tokens, "{tag}: cold vs dense tokens");
            assert_eq!(cold.model_nfe, dense.model_nfe, "{tag}: dense model NFE");
            assert_eq!(cold.aux_nfe, dense.aux_nfe, "{tag}: dense aux NFE");
            assert_eq!(cold.iterations, dense.iterations, "{tag}: dense iterations");
            assert_eq!(cold.proposed, dense.proposed, "{tag}: dense proposed");
            assert_eq!(cold.accepted, dense.accepted, "{tag}: dense accepted");
            if expect_cache {
                assert!(s0.prefix_misses >= 1, "{tag}: cold run should miss");
                assert!(
                    s1.prefix_hits > s0.prefix_hits,
                    "{tag}: warm run never hit the prefix cache — the test \
                     exercised nothing"
                );
            } else {
                // Diffusion declines incrementality: no cache traffic.
                assert_eq!(s1.prefix_hits, s0.prefix_hits, "{tag}: phantom hit");
                assert_eq!(s1.prefix_misses, s0.prefix_misses, "{tag}: phantom miss");
            }
        }

        let n = 14;
        let v = 6;
        let mut r = Rng::new(0xC0FFEE);
        let m = 5;
        let sigma = sample_sigma(&mut r, n, m, OrderProtocol::Lattice);
        let ord = Ordering::new(sigma, m);
        let prompt: Vec<(usize, u32)> = (0..n)
            .filter(|&p| ord.is_prompt_pos(p))
            .map(|p| (p, r.below(v) as u32))
            .collect();
        let toks = init_tokens(&ord, &prompt);
        for kind in DraftKind::ALL {
            for adaptive in [false, true] {
                let opts = DraftOptions {
                    kind,
                    max_len: 4,
                    adaptive,
                };
                let mk = |rs: u64| -> Box<dyn crate::decode::DecodeMachine> {
                    let drafter = opts.build(&toks, v);
                    Box::new(AssdMachine::new(
                        ord.clone(),
                        toks.clone(),
                        v,
                        opts.speculation(),
                        1.2,
                        Rng::new(rs),
                        drafter,
                    ))
                };
                run_warm_cold(
                    &format!("assd {kind:?} adaptive={adaptive}"),
                    n,
                    v,
                    &mk,
                    true,
                );
            }
        }
        let mk_seq = |rs: u64| -> Box<dyn crate::decode::DecodeMachine> {
            Box::new(crate::decode::sequential::SequentialMachine::new(
                ord.clone(),
                toks.clone(),
                v,
                1.2,
                Rng::new(rs),
            ))
        };
        run_warm_cold("sequential", n, v, &mk_seq, true);
        let mk_dif = |rs: u64| -> Box<dyn crate::decode::DecodeMachine> {
            Box::new(crate::decode::diffusion::DiffusionMachine::new(
                toks.clone(),
                v,
                4,
                1.2,
                Rng::new(rs),
            ))
        };
        run_warm_cold("diffusion", n, v, &mk_dif, false);
    }

    /// The streaming hook: every drafter's drained commits are exactly
    /// the final target tokens — each target exactly once, never an
    /// unverified draft, values matching the outcome bit for bit.
    #[test]
    fn drain_commits_streams_exactly_the_accepted_tokens() {
        let e = MockEngine::new(31, 12, 5, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 6], 12), 2);
        let toks = init_tokens(&ord, &[(0, 2), (6, 1)]);
        for kind in DraftKind::ALL {
            let opts = DraftOptions {
                kind,
                max_len: 4,
                adaptive: false,
            };
            let drafter = opts.build(&toks, e.vocab());
            let mut mach = Box::new(AssdMachine::new(
                ord.clone(),
                toks.clone(),
                e.vocab(),
                opts.speculation(),
                1.0,
                Rng::new(77),
                drafter,
            ));
            let mut commits: Vec<(usize, u32)> = vec![];
            let mut chunks = 0u64;
            while !mach.done() {
                let rows = {
                    let r = mach.forward_request().unwrap();
                    e.forward_ord(std::slice::from_ref(&r)).unwrap().pop().unwrap()
                };
                mach.absorb(&rows);
                commits.extend(mach.drain_commits());
                chunks += 1;
            }
            assert!(mach.drain_commits().is_empty(), "drain must not repeat");
            let out = mach.outcome();
            let mut positions: Vec<usize> = commits.iter().map(|c| c.0).collect();
            positions.sort_unstable();
            positions.dedup();
            assert_eq!(positions.len(), commits.len(), "double-committed position");
            assert_eq!(commits.len(), ord.n_targets(), "{kind:?}");
            assert!(chunks >= out.iterations, "commits arrive per iteration");
            for (pos, tok) in commits {
                assert!(!ord.is_prompt_pos(pos));
                assert_eq!(out.tokens[pos], tok, "{kind:?} pos {pos}");
            }
        }
    }

    #[test]
    fn k1_completes_but_violates_theorem1_bound() {
        // The paper instructs k >= 2: with k = 1 each iteration decodes ONE
        // token with TWO forwards, so the NFE bound of Theorem 1 does not
        // apply (its proof needs two tokens per iteration). Completion and
        // distribution correctness still hold.
        let e = MockEngine::new(9, 8, 4, 1.0);
        let ord = Ordering::new(lattice_sigma(&[3], 8), 1);
        let toks = init_tokens(&ord, &[(3, 2)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 1, 13, DraftKind::SelfModel);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        let targets = 7u64;
        assert!(out.model_nfe <= 2 * targets);
        assert!(out.model_nfe >= targets, "k=1 cannot beat sequential");
    }

    #[test]
    fn single_target_needs_one_nfe() {
        let e = MockEngine::new(10, 5, 4, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 1, 2, 3], 5), 4);
        let toks = init_tokens(&ord, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let (out, _) = decode_assd(&e, &ord, &toks, 5, 3, DraftKind::SelfModel);
        assert_eq!(out.model_nfe, 1, "final-token shortcut (Lemma 1) not taken");
        assert!(out.tokens[4] != MASK);
    }

    /// Adaptive speculation grows the window under high acceptance and
    /// then needs far fewer forwards than a short fixed window. A
    /// near-zero sharpness makes every conditional near-uniform, so draft
    /// and verify densities agree and acceptance is near-certain.
    #[test]
    fn adaptive_grows_windows_on_predictable_text() {
        let e = MockEngine::new(11, 24, 5, 0.001); // near-uniform conditionals
        let ord = Ordering::new(lattice_sigma(&[0], 24), 1);
        let toks = init_tokens(&ord, &[(0, 2)]);
        let fixed = DraftOptions {
            kind: DraftKind::SelfModel,
            max_len: 2,
            adaptive: false,
        };
        let adaptive = DraftOptions {
            kind: DraftKind::SelfModel,
            max_len: 2,
            adaptive: true,
        };
        let (out_f, _) = decode_assd_opts(&e, &ord, &toks, fixed, 99);
        let (out_a, _) = decode_assd_opts(&e, &ord, &toks, adaptive, 99);
        assert!(
            out_a.final_draft_len > 2,
            "adaptive window never grew: {}",
            out_a.final_draft_len
        );
        assert!(
            out_a.model_nfe <= out_f.model_nfe,
            "adaptive {} NFE > fixed {} NFE on predictable text",
            out_a.model_nfe,
            out_f.model_nfe
        );
    }
}
