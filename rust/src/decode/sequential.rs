//! Sequential decoding baseline: one forward per token, following the
//! factorization chain (paper "Sequential Sampling via Factorization").
//!
//! Each step requests the draft-mode state n with a single wanted row —
//! order n's position — whose conditional is exactly the oracle
//! p(x_sigma(n) | x_sigma(<n)) (the same fact that powers Lemma 1), so
//! sequential decoding samples the true joint. NFE = number of target
//! tokens. No mask is ever materialized machine-side: the compact
//! forward ABI carries (ordering, n) and the engine rebuilds the masks.

use crate::model::mask::Ordering;
use crate::tokenizer::MASK;
use crate::util::rng::Rng;

use super::sampling::{sample_probs, softmax_into};
use super::{DecodeMachine, DecodeOutcome, ForwardRequest};

pub struct SequentialMachine {
    ord: Ordering,
    vocab: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    n: usize,
    /// the single row requested this step (order n's position)
    want: [usize; 1],
    /// tokens sampled since the last drain_commits (streaming hook);
    /// sequential decoding commits every sampled token immediately
    committed: Vec<(usize, u32)>,
    /// vocab-sized scratch reused every step (banned row copy + softmax)
    row_buf: Vec<f32>,
    prob_buf: Vec<f32>,
    model_nfe: u64,
}

/// Frozen [`SequentialMachine`] state (see [`crate::decode::snapshot`]):
/// ordering, token buffer, decode state, RNG, undrained commits, and the
/// NFE counter. The single-row `want` and the vocab-sized scratch are
/// recomputed on restore.
pub struct SequentialSnapshot {
    ord: Ordering,
    vocab: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    n: usize,
    committed: Vec<(usize, u32)>,
    model_nfe: u64,
}

impl SequentialMachine {
    /// Freeze into a [`SequentialSnapshot`] (pure clone; the machine
    /// keeps running unaffected).
    pub fn snapshot(&self) -> SequentialSnapshot {
        SequentialSnapshot {
            ord: self.ord.clone(),
            vocab: self.vocab,
            temp: self.temp,
            rng: self.rng.clone(),
            tokens: self.tokens.clone(),
            n: self.n,
            committed: self.committed.clone(),
            model_nfe: self.model_nfe,
        }
    }

    /// Thaw a snapshot. Bypasses `new()`'s fresh-admission checks: a
    /// mid-decode buffer holds sampled values at already-decoded target
    /// positions, and `n` restarts from the frozen decode state rather
    /// than the prompt size.
    pub fn from_snapshot(s: SequentialSnapshot) -> Self {
        SequentialMachine {
            ord: s.ord,
            vocab: s.vocab,
            temp: s.temp,
            rng: s.rng,
            tokens: s.tokens,
            n: s.n,
            want: [0],
            committed: s.committed,
            row_buf: vec![],
            prob_buf: vec![],
            model_nfe: s.model_nfe,
        }
    }

    pub fn new(ord: Ordering, tokens: Vec<u32>, vocab: usize, temp: f32, rng: Rng) -> Self {
        assert_eq!(tokens.len(), ord.n());
        for (pos, &t) in tokens.iter().enumerate() {
            if ord.is_prompt_pos(pos) {
                assert_ne!(t, MASK, "prompt position {pos} is MASK");
            }
        }
        let n = ord.m;
        SequentialMachine {
            ord,
            vocab,
            temp,
            rng,
            tokens,
            n,
            want: [0],
            committed: vec![],
            row_buf: vec![],
            prob_buf: vec![],
            model_nfe: 0,
        }
    }
}

impl DecodeMachine for SequentialMachine {
    fn done(&self) -> bool {
        self.n >= self.ord.n()
    }

    fn forward_request(&mut self) -> Option<ForwardRequest<'_>> {
        if self.done() {
            return None;
        }
        self.want = [self.ord.sigma[self.n]];
        Some(ForwardRequest {
            tokens: &self.tokens,
            ord: &self.ord,
            known: self.n,
            want: &self.want,
        })
    }

    fn absorb(&mut self, logits: &[f32]) {
        debug_assert_eq!(logits.len(), self.vocab);
        self.model_nfe += 1;
        let pos = self.ord.sigma[self.n];
        self.row_buf.clear();
        self.row_buf.extend_from_slice(logits);
        super::sampling::ban_ids(&mut self.row_buf, &super::sampling::BANNED);
        softmax_into(&self.row_buf, self.temp, &mut self.prob_buf);
        let tok = sample_probs(&mut self.rng, &self.prob_buf);
        if crate::obs::flight::enabled() {
            // Pure read of the already-built sampling distribution —
            // never touches the RNG (bit-identity contract).
            crate::obs::flight::record(crate::obs::flight::FlightEvent::Decode {
                target_entropy: crate::obs::flight::entropy(&self.prob_buf),
            });
        }
        self.tokens[pos] = tok as u32;
        self.committed.push((pos, tok as u32));
        self.n += 1;
    }

    fn drain_commits(&mut self) -> Vec<(usize, u32)> {
        std::mem::take(&mut self.committed)
    }

    /// The chain's ordering is fixed and every sampled token is final
    /// immediately, so orders `< n` are always cacheable.
    fn incremental(&self) -> Option<usize> {
        Some(self.n)
    }

    fn iter_stats(&self) -> super::IterStats {
        super::IterStats {
            model_nfe: self.model_nfe,
            iterations: self.model_nfe,
            ..Default::default()
        }
    }

    fn checkpoint(&self) -> Option<super::snapshot::DecodeSnapshot> {
        Some(super::snapshot::DecodeSnapshot::Sequential(self.snapshot()))
    }

    fn outcome(self: Box<Self>) -> DecodeOutcome {
        assert!(self.done());
        DecodeOutcome {
            tokens: self.tokens,
            model_nfe: self.model_nfe,
            iterations: self.model_nfe,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::lattice_sigma;
    use crate::decode::{init_tokens, run_machine};
    use crate::runtime::mock::MockEngine;
    use crate::runtime::Engine;

    #[test]
    fn decodes_all_targets_with_one_nfe_each() {
        let e = MockEngine::new(1, 8, 5, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 4], 8), 2);
        let toks = init_tokens(&ord, &[(0, 1), (4, 2)]);
        let m = SequentialMachine::new(ord.clone(), toks, e.vocab(), 1.0, Rng::new(7));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert_eq!(out.model_nfe, 6);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert_eq!(out.tokens[0], 1);
        assert_eq!(out.tokens[4], 2);
    }

    #[test]
    fn fully_known_sequence_needs_no_forwards() {
        let e = MockEngine::new(1, 4, 3, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 1, 2, 3], 4), 4);
        let toks = vec![0, 1, 2, 0];
        let m = SequentialMachine::new(ord, toks.clone(), e.vocab(), 1.0, Rng::new(1));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert_eq!(out.model_nfe, 0);
        assert_eq!(out.tokens, toks);
    }

    #[test]
    fn drain_commits_streams_one_token_per_step() {
        let e = MockEngine::new(4, 8, 5, 1.0);
        let ord = Ordering::new(lattice_sigma(&[0, 4], 8), 2);
        let toks = init_tokens(&ord, &[(0, 1), (4, 2)]);
        let mut m = Box::new(SequentialMachine::new(
            ord.clone(),
            toks,
            e.vocab(),
            1.0,
            Rng::new(3),
        ));
        let mut commits = vec![];
        while !m.done() {
            let rows = {
                let r = m.forward_request().unwrap();
                e.forward_ord(std::slice::from_ref(&r)).unwrap().pop().unwrap()
            };
            m.absorb(&rows);
            let chunk = m.drain_commits();
            assert_eq!(chunk.len(), 1, "sequential commits one token per step");
            commits.extend(chunk);
        }
        let out = m.outcome();
        assert_eq!(commits.len(), 6);
        for (pos, tok) in commits {
            assert_eq!(out.tokens[pos], tok);
        }
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let e = MockEngine::new(2, 8, 5, 1.0);
        let ord = Ordering::new(lattice_sigma(&[3], 8), 1);
        let toks = init_tokens(&ord, &[(3, 4)]);
        let run = |seed| {
            let m = SequentialMachine::new(ord.clone(), toks.clone(), e.vocab(), 1.0, Rng::new(seed));
            run_machine(&e, Box::new(m)).unwrap().tokens
        };
        assert_eq!(run(5), run(5));
    }
}
