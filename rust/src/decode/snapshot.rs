//! Deterministic decode-state checkpointing.
//!
//! A decode machine is a pure function of (request, ordering, committed
//! tokens, RNG state, drafter/adaptive state) — the same determinism that
//! proves bit-identity through the retry ladder means an in-flight decode
//! can be FROZEN and RESUMED anywhere: on the same engine after a
//! preemption, on a fresh incarnation after an engine death, or on a
//! different replica entirely. [`DecodeSnapshot`] is that frozen form.
//!
//! What is serialized vs recomputed:
//!
//! * Serialized — everything whose value depends on PAST RNG draws or
//!   external feedback: the RNG itself, the token buffer (committed
//!   values + any in-flight draft window), decode state `n`, the ASSD
//!   phase/`t`/drafted window/draft distributions (a checkpoint may land
//!   BETWEEN a draft absorb and its verify forward, and rolling back to
//!   Draft would re-consume RNG), the [`AdaptiveSpeculation`] EWMA, the
//!   drafter (the bigram table has learned from committed text), the
//!   diffusion unmasking order (its constructor shuffle consumed RNG),
//!   undrained commits, and every NFE/speculation counter.
//! * Recomputed — pure scratch: `want` row lists, vocab-sized softmax /
//!   residual buffers, and diffusion's lattice ordering (re-derivable
//!   from the token buffer's known set).
//!
//! The property test below proves the contract: checkpointing at EVERY
//! iteration boundary and resuming from the snapshot reproduces the
//! uninterrupted run bit-for-bit — tokens, model/aux NFE, iterations,
//! and the proposed/accepted speculation counters.
//!
//! [`AdaptiveSpeculation`]: crate::draft::AdaptiveSpeculation

use super::assd::AssdSnapshot;
use super::diffusion::DiffusionSnapshot;
use super::sequential::SequentialSnapshot;
use super::DecodeMachine;

/// An owned, engine-independent freeze of one decode machine, taken
/// between absorbs via [`DecodeMachine::checkpoint`]. Opaque by design:
/// the scheduler moves these through its resume queue without looking
/// inside, and [`restore`] rebuilds the matching machine.
pub enum DecodeSnapshot {
    Assd(AssdSnapshot),
    Sequential(SequentialSnapshot),
    Diffusion(DiffusionSnapshot),
}

/// Rebuild the machine a snapshot was taken from. The restored machine
/// re-issues exactly the forward the original would have issued next
/// (`forward_request` is idempotent between absorbs, and all scratch is
/// recomputed), so driving it to completion yields bit-identical tokens
/// and counters.
pub fn restore(snap: DecodeSnapshot) -> Box<dyn DecodeMachine> {
    match snap {
        DecodeSnapshot::Assd(s) => Box::new(super::assd::AssdMachine::from_snapshot(s)),
        DecodeSnapshot::Sequential(s) => {
            Box::new(super::sequential::SequentialMachine::from_snapshot(s))
        }
        DecodeSnapshot::Diffusion(s) => {
            Box::new(super::diffusion::DiffusionMachine::from_snapshot(s))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::lattice_sigma;
    use crate::decode::assd::AssdMachine;
    use crate::decode::diffusion::DiffusionMachine;
    use crate::decode::sequential::SequentialMachine;
    use crate::decode::{init_tokens, DecodeOutcome};
    use crate::draft::{DraftKind, DraftOptions};
    use crate::model::mask::Ordering;
    use crate::runtime::mock::MockEngine;
    use crate::runtime::Engine;
    use crate::tokenizer::MASK;
    use crate::util::rng::Rng;

    fn ord_8() -> Ordering {
        Ordering::new(lattice_sigma(&[0, 1, 6, 7], 8), 4)
    }

    fn toks_8() -> Vec<u32> {
        init_tokens(&ord_8(), &[(0, 97), (1, 98), (6, 99), (7, 100)])
    }

    /// Drive a machine to completion, checkpoint-and-restoring at every
    /// iteration boundary when `interrupt` is set. Collects the streamed
    /// commits alongside the outcome so the test also proves the commit
    /// stream survives a mid-flight freeze (no token lost, duplicated,
    /// or reordered).
    fn drive(
        e: &MockEngine,
        mut m: Box<dyn DecodeMachine>,
        interrupt: bool,
    ) -> (DecodeOutcome, Vec<(usize, u32)>) {
        let mut commits = vec![];
        let mut guard = 0;
        while !m.done() {
            if interrupt {
                let snap = m
                    .checkpoint()
                    .expect("shipped machines must support checkpointing");
                m = restore(snap);
            }
            let rows = {
                let req = m.forward_request().expect("not done but no request");
                e.forward_ord(std::slice::from_ref(&req))
                    .unwrap()
                    .pop()
                    .unwrap()
            };
            m.absorb(&rows);
            commits.extend(m.drain_commits());
            guard += 1;
            assert!(guard < 1000, "decode did not terminate");
        }
        // A terminal checkpoint must also round-trip (drain-while-done).
        if interrupt {
            let snap = m.checkpoint().expect("done machine still snapshots");
            m = restore(snap);
            assert!(m.done());
        }
        (m.outcome(), commits)
    }

    fn assert_bit_identical(want: (DecodeOutcome, Vec<(usize, u32)>), got: (DecodeOutcome, Vec<(usize, u32)>), label: &str) {
        assert_eq!(got.0.tokens, want.0.tokens, "{label}: tokens diverged");
        assert_eq!(got.0.model_nfe, want.0.model_nfe, "{label}: model NFE");
        assert_eq!(got.0.aux_nfe, want.0.aux_nfe, "{label}: aux NFE");
        assert_eq!(got.0.iterations, want.0.iterations, "{label}: iterations");
        assert_eq!(got.0.proposed, want.0.proposed, "{label}: proposed");
        assert_eq!(got.0.accepted, want.0.accepted, "{label}: accepted");
        assert_eq!(got.1, want.1, "{label}: commit stream");
    }

    /// The tentpole property: checkpoint-at-every-iteration + restore ==
    /// the uninterrupted run, bit for bit, across ASSD x every drafter x
    /// fixed/adaptive speculation.
    #[test]
    fn assd_checkpoint_every_iteration_is_bit_identical() {
        let e = MockEngine::new(11, 8, 32, 1.0);
        for kind in DraftKind::ALL {
            for adaptive in [false, true] {
                for seed in [1u64, 2, 3] {
                    let build = || {
                        let opts = DraftOptions {
                            kind,
                            max_len: 3,
                            adaptive,
                        };
                        Box::new(AssdMachine::from_options(
                            ord_8(),
                            toks_8(),
                            e.vocab(),
                            opts,
                            8,
                            1.0,
                            Rng::new(seed),
                        )) as Box<dyn DecodeMachine>
                    };
                    let want = drive(&e, build(), false);
                    let got = drive(&e, build(), true);
                    let label =
                        format!("assd/{}/adaptive={adaptive}/seed={seed}", kind.name());
                    assert!(
                        want.0.tokens.iter().all(|&t| t != MASK),
                        "{label}: run incomplete"
                    );
                    assert_bit_identical(want, got, &label);
                }
            }
        }
    }

    #[test]
    fn sequential_checkpoint_every_iteration_is_bit_identical() {
        let e = MockEngine::new(12, 8, 32, 1.0);
        for seed in [1u64, 2, 3] {
            let build = || {
                Box::new(SequentialMachine::new(
                    ord_8(),
                    toks_8(),
                    e.vocab(),
                    1.0,
                    Rng::new(seed),
                )) as Box<dyn DecodeMachine>
            };
            let want = drive(&e, build(), false);
            let got = drive(&e, build(), true);
            assert_bit_identical(want, got, &format!("sequential/seed={seed}"));
        }
    }

    #[test]
    fn diffusion_checkpoint_every_iteration_is_bit_identical() {
        let e = MockEngine::new(13, 8, 32, 1.0);
        for steps in [1usize, 3, 8] {
            for seed in [1u64, 2, 3] {
                let build = || {
                    Box::new(DiffusionMachine::new(
                        toks_8(),
                        e.vocab(),
                        steps,
                        1.0,
                        Rng::new(seed),
                    )) as Box<dyn DecodeMachine>
                };
                let want = drive(&e, build(), false);
                let got = drive(&e, build(), true);
                assert_bit_identical(want, got, &format!("diffusion/steps={steps}/seed={seed}"));
            }
        }
    }

    /// A checkpoint taken with undrained commits must carry them: the
    /// restored machine's next `drain_commits` returns exactly the
    /// pending chunk (the scheduler relies on this so a preempted slot
    /// never loses or re-emits a token).
    #[test]
    fn pending_commits_ride_the_snapshot() {
        let e = MockEngine::new(14, 8, 32, 1.0);
        let mut m: Box<dyn DecodeMachine> = Box::new(SequentialMachine::new(
            ord_8(),
            toks_8(),
            e.vocab(),
            1.0,
            Rng::new(5),
        ));
        let rows = {
            let req = m.forward_request().unwrap();
            e.forward_ord(std::slice::from_ref(&req))
                .unwrap()
                .pop()
                .unwrap()
        };
        m.absorb(&rows);
        // Do NOT drain: freeze with the commit pending.
        let mut restored = restore(m.checkpoint().unwrap());
        let pending = restored.drain_commits();
        assert_eq!(pending.len(), 1, "pending commit lost in the snapshot");
        // And it is not duplicated on the next drain.
        assert!(restored.drain_commits().is_empty());
    }
}
