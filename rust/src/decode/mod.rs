//! The decoder family: sequential (baseline), ASSD (Algorithm 1) over the
//! pluggable draft subsystem ([`crate::draft`]: self-drafting, context
//! bigram — Algorithm 2 —, prompt lookup, adaptive speculation control), a
//! masked-diffusion baseline, and a left-to-right AR mode.
//!
//! Decoders are implemented as per-sequence STATE MACHINES that expose the
//! forward request they need next and absorb the resulting logits. A
//! single-sequence driver ([`run_machine`]) serves the simple API; the
//! coordinator drives many machines through shared batched forwards
//! (continuous batching) — the machines are agnostic to how their forwards
//! are satisfied, or on which engine replica they run (see
//! docs/ARCHITECTURE.md §Continuous-batching invariants).

pub mod assd;
pub mod diffusion;
pub mod sampling;
pub mod sequential;
pub mod snapshot;

use anyhow::Result;

use crate::model::mask::Ordering;
use crate::runtime::Engine;
use crate::tokenizer::MASK;

/// Statistics + result of one decode.
#[derive(Clone, Debug, Default)]
pub struct DecodeOutcome {
    pub tokens: Vec<u32>,
    /// forward passes of the AS-ARM (paper "Model NFE")
    pub model_nfe: u64,
    /// draft-model calls that are NOT the AS-ARM (paper "Aux NFE")
    pub aux_nfe: u64,
    /// ASSD while-loop iterations
    pub iterations: u64,
    /// accepted / proposed speculative tokens
    pub accepted: u64,
    pub proposed: u64,
    /// Draft implementation that served this decode ("" for samplers that
    /// do not speculate).
    pub draft_kind: String,
    /// Speculation window length when the decode finished (moves under
    /// adaptive control; equals the configured k otherwise).
    pub final_draft_len: usize,
}

impl DecodeOutcome {
    /// Accepted / proposed speculative tokens. 0.0 when nothing was
    /// proposed (non-speculative samplers) — the same convention the
    /// metrics endpoints use, so the per-request and pool-level rates
    /// agree for identical traffic.
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// Tokens generated per loop iteration (paper reports 2.24 for ASSD-self).
    pub fn tokens_per_iteration(&self, n_targets: usize) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            n_targets as f64 / self.iterations as f64
        }
    }
}

/// Which stage a machine is about to run — the observability label for
/// the NEXT `forward_request`/`absorb` pair. `Draft`/`Verify` are ASSD's
/// two passes (Algorithm 1); non-speculative machines report `Decode`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterPhase {
    Draft,
    Verify,
    Decode,
}

/// Live counter snapshot of a machine, readable mid-decode — the tracing
/// hook at the `absorb`/`finish_iteration` choke points. The scheduler
/// samples this before and after each absorb and records the DELTAS as
/// span args, so the machines stay pure (tracing never branches inside
/// the sampling path — bit-identity by construction).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IterStats {
    pub model_nfe: u64,
    pub aux_nfe: u64,
    pub iterations: u64,
    pub proposed: u64,
    pub accepted: u64,
    /// Current speculation window (0 for non-speculative machines) —
    /// sampled per iteration, this is the adaptive controller's
    /// trajectory.
    pub draft_len: usize,
}

/// A decoder state machine. Drive with:
/// `while !done() { if let Some(req)=forward_request() { absorb(rows) } }`
pub trait DecodeMachine {
    /// True when the sequence is fully decoded.
    fn done(&self) -> bool;

    /// The COMPACT forward the machine needs next: the token buffer, the
    /// generation ordering + decode state the engine rebuilds the masks
    /// from, and the logit rows the machine will read in `absorb`. Returns
    /// None iff `done()`. Must be idempotent between absorbs (the driver
    /// may call it more than once per iteration).
    fn forward_request(&mut self) -> Option<ForwardRequest<'_>>;

    /// Feed the GATHERED logit rows for the last request:
    /// `[want.len(), V]` row-major, rows in the exact order of the
    /// request's `want` list (NOT the full `[N, V]` grid — machines never
    /// see rows they did not ask for).
    fn absorb(&mut self, logits: &[f32]);

    /// Tokens ACCEPTED since the last call, as `(position, token)` pairs
    /// in commit order — the streaming hook. A token is committed only
    /// once it can never be rolled back: for ASSD that is the accepted
    /// prefix of each speculation window plus the resampled token (never
    /// unverified drafts), for sequential/diffusion every sampled token.
    /// The scheduler drains this after each `absorb` and streams the
    /// chunk as an `Event::Committed`; drivers that do not stream may
    /// simply never call it.
    fn drain_commits(&mut self) -> Vec<(usize, u32)> {
        Vec::new()
    }

    /// Incremental-forward eligibility: `Some(c)` guarantees that the
    /// machine's generation ordering is FIXED for its lifetime and that
    /// orders `< c` hold token values that will never change again — the
    /// engine may persist exactly those rows' per-layer K/V in the
    /// request's cache lane ([`crate::runtime::IncSpec`]). Must be
    /// read BEFORE `forward_request` each iteration (it describes the
    /// state the request is issued from). `None` (the default) routes
    /// the machine through the compact path — correct for machines whose
    /// ordering or committed set can move (diffusion re-derives its
    /// ordering every step).
    fn incremental(&self) -> Option<usize> {
        None
    }

    /// The stage the next `forward_request`/`absorb` pair serves — the
    /// span label the scheduler's tracer uses. Defaults to the generic
    /// `Decode` (correct for non-speculative machines).
    fn phase(&self) -> IterPhase {
        IterPhase::Decode
    }

    /// Live counter snapshot (see [`IterStats`]). Defaults to zeros so
    /// ad-hoc machines stay trivially implementable; the three shipped
    /// machines report their real counters.
    fn iter_stats(&self) -> IterStats {
        IterStats::default()
    }

    /// Freeze the machine into an owned, engine-independent
    /// [`snapshot::DecodeSnapshot`] that [`snapshot::restore`] turns back
    /// into an equivalent machine — the scheduler's preemption /
    /// migration / drain primitive. Must be called between absorbs (any
    /// point where `forward_request` would be legal); the restored
    /// machine re-issues the same forward and continues bit-identically,
    /// and undrained commits ride along. `None` (the default) marks the
    /// machine non-checkpointable: the scheduler then falls back to
    /// failing the request instead of re-queueing it.
    fn checkpoint(&self) -> Option<snapshot::DecodeSnapshot> {
        None
    }

    /// Consume the machine and return the outcome (panics if !done()).
    fn outcome(self: Box<Self>) -> DecodeOutcome;
}

/// Borrowed compact forward inputs for one sequence — the same type the
/// engines consume ([`crate::runtime::ForwardSpec`]), so the scheduler
/// passes machine requests to [`Engine::forward_ord`] without repacking.
pub use crate::runtime::ForwardSpec as ForwardRequest;

/// Drive a machine to completion against an engine (batch = 1), through
/// the COMPACT forward path.
pub fn run_machine(engine: &dyn Engine, mut machine: Box<dyn DecodeMachine>) -> Result<DecodeOutcome> {
    while !machine.done() {
        let rows = {
            let req = machine
                .forward_request()
                .expect("machine not done but no request");
            let mut out = engine.forward_ord(std::slice::from_ref(&req))?;
            out.pop().expect("engine returned no row batch")
        };
        machine.absorb(&rows);
    }
    Ok(machine.outcome())
}

/// Drive a machine to completion through the INCREMENTAL forward path,
/// pinned to cache lane `lane` (batch = 1; the scheduler's lane-pinned
/// batching is the many-machine form of this loop). Machines that do not
/// vouch for incrementality ([`DecodeMachine::incremental`] = None) fall
/// through to the compact path per request, exactly as the scheduler
/// routes them. The lane is reset around the decode, so callers may reuse
/// lane ids freely.
pub fn run_machine_inc(
    engine: &dyn Engine,
    mut machine: Box<dyn DecodeMachine>,
    lane: usize,
) -> Result<DecodeOutcome> {
    engine.reset_lane(lane);
    while !machine.done() {
        // `incremental` describes the state the request is issued from,
        // so read it before borrowing the request.
        let committed = machine.incremental();
        let rows = {
            let req = machine
                .forward_request()
                .expect("machine not done but no request");
            let mut out = match committed {
                Some(committed) => engine.forward_inc(&[crate::runtime::IncSpec {
                    spec: req,
                    committed,
                    lane,
                }])?,
                None => engine.forward_ord(std::slice::from_ref(&req))?,
            };
            out.pop().expect("engine returned no row batch")
        };
        machine.absorb(&rows);
    }
    engine.reset_lane(lane);
    Ok(machine.outcome())
}

/// Build the initial full-sequence token buffer: prompt values at visible
/// positions, MASK elsewhere.
pub fn init_tokens(ord: &Ordering, prompt_values: &[(usize, u32)]) -> Vec<u32> {
    let mut toks = vec![MASK; ord.n()];
    for &(pos, val) in prompt_values {
        assert!(ord.is_prompt_pos(pos), "value at non-prompt position {pos}");
        toks[pos] = val;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::lattice_sigma;

    #[test]
    fn init_tokens_masks_targets() {
        let ord = Ordering::new(lattice_sigma(&[1, 3], 5), 2);
        let toks = init_tokens(&ord, &[(1, 42), (3, 7)]);
        assert_eq!(toks, vec![MASK, 42, MASK, 7, MASK]);
    }

    #[test]
    #[should_panic(expected = "non-prompt position")]
    fn init_tokens_rejects_target_value() {
        let ord = Ordering::new(lattice_sigma(&[1], 3), 1);
        init_tokens(&ord, &[(0, 5)]);
    }
}
