//! Probability utilities for the decoders: stable softmax, categorical
//! sampling, and the speculative-decoding residual distribution
//! (q - p)_+ / sum (paper Alg. 1 line 22).

use crate::util::rng::Rng;

/// Numerically stable softmax with temperature, into a caller-provided
/// buffer (cleared first). The `_into` variants exist because the decode
/// machines call these once per ROW per iteration — a fresh vocab-sized
/// allocation each time is the serving hot path's dominant allocator
/// traffic; per-machine scratch buffers make the steady state
/// allocation-free.
pub fn softmax_into(logits: &[f32], temp: f32, out: &mut Vec<f32>) {
    assert!(temp > 0.0);
    let inv = 1.0 / temp;
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    out.clear();
    out.extend(logits.iter().map(|&l| ((l - mx) * inv).exp()));
    let sum: f32 = out.iter().sum();
    if sum > 0.0 {
        out.iter_mut().for_each(|x| *x /= sum);
    } else {
        let u = 1.0 / out.len() as f32;
        out.iter_mut().for_each(|x| *x = u);
    }
}

/// Numerically stable softmax with temperature, into a fresh Vec.
pub fn softmax(logits: &[f32], temp: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(logits.len());
    softmax_into(logits, temp, &mut out);
    out
}

/// Log-softmax into a caller-provided buffer (cleared first).
pub fn log_softmax_into(logits: &[f32], temp: f32, out: &mut Vec<f32>) {
    let inv = 1.0 / temp;
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse: f32 = logits
        .iter()
        .map(|&l| ((l - mx) * inv).exp())
        .sum::<f32>()
        .ln();
    out.clear();
    out.extend(logits.iter().map(|&l| (l - mx) * inv - lse));
}

/// Log-softmax (for density evaluation / perplexity).
pub fn log_softmax(logits: &[f32], temp: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(logits.len());
    log_softmax_into(logits, temp, &mut out);
    out
}

/// Sample from a probability vector.
pub fn sample_probs(rng: &mut Rng, probs: &[f32]) -> usize {
    rng.categorical(probs)
}

/// Ban token ids from a logits row (in place). Decoders ban the MASK/PAD
/// specials: a generator must never emit its own absorbing token. Applied
/// identically to draft and verify rows, so the restricted distribution is
/// the (well-defined) target distribution of every sampler.
pub fn ban_ids(logits: &mut [f32], ids: &[u32]) {
    for &id in ids {
        if (id as usize) < logits.len() {
            logits[id as usize] = NEG_INF;
        }
    }
}

/// The standard ban list.
pub const BANNED: [u32; 2] = [crate::tokenizer::MASK, crate::tokenizer::PAD];

const NEG_INF: f32 = -1e9;

/// Sample a token from logits at temperature; returns (token, prob).
pub fn sample_logits(rng: &mut Rng, logits: &[f32], temp: f32) -> (usize, f32) {
    let probs = softmax(logits, temp);
    let tok = sample_probs(rng, &probs);
    (tok, probs[tok])
}

/// The speculative-decoding residual distribution (q - p)_+, normalized,
/// into a caller-provided buffer (cleared first). Returns false if the
/// residual has (numerically) zero mass — callers fall back to sampling
/// from q (only reachable when q == p, in which case the proposal would
/// have been accepted anyway); the buffer contents are unspecified then.
pub fn residual_into(q: &[f32], p: &[f32], out: &mut Vec<f32>) -> bool {
    debug_assert_eq!(q.len(), p.len());
    out.clear();
    out.extend(q.iter().zip(p).map(|(&a, &b)| (a - b).max(0.0)));
    let sum: f32 = out.iter().sum();
    if sum <= 1e-12 {
        return false;
    }
    out.iter_mut().for_each(|x| *x /= sum);
    true
}

/// The speculative-decoding residual distribution (q - p)_+, normalized.
/// Returns None when the residual has (numerically) zero mass.
pub fn residual(q: &[f32], p: &[f32]) -> Option<Vec<f32>> {
    let mut out = Vec::with_capacity(q.len());
    if residual_into(q, p, &mut out) {
        Some(out)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_handles_extreme_logits() {
        let p = softmax(&[1e9, -1e9, 0.0], 1.0);
        assert!((p[0] - 1.0).abs() < 1e-6);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let logits = [0.3f32, -1.2, 2.0, 0.0];
        let p = softmax(&logits, 1.0);
        let lp = log_softmax(&logits, 1.0);
        for (a, b) in p.iter().zip(&lp) {
            assert!((a.ln() - b).abs() < 1e-5);
        }
    }

    #[test]
    fn temperature_sharpens() {
        let logits = [1.0f32, 2.0];
        let hot = softmax(&logits, 2.0);
        let cold = softmax(&logits, 0.5);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn residual_correctness() {
        let q = [0.5f32, 0.3, 0.2];
        let p = [0.2f32, 0.5, 0.3];
        let r = residual(&q, &p).unwrap();
        // only index 0 has positive residual 0.3
        assert!((r[0] - 1.0).abs() < 1e-6);
        assert_eq!(r[1], 0.0);
        assert_eq!(r[2], 0.0);
    }

    #[test]
    fn residual_none_when_equal() {
        let q = [0.25f32; 4];
        assert!(residual(&q, &q).is_none());
        let mut buf = vec![9.0f32; 2];
        assert!(!residual_into(&q, &q, &mut buf));
    }

    /// The `_into` scratch variants are bit-identical to the allocating
    /// wrappers (the machines' hot paths must not change a single sample).
    #[test]
    fn into_variants_match_allocating_variants() {
        let logits = [0.3f32, -1.2, 2.0, 0.0, 5.5];
        let p = [0.2f32, 0.4, 0.1, 0.2, 0.1];
        let mut buf = vec![7.0f32; 3]; // stale contents must not leak
        for temp in [0.5f32, 1.0, 2.0] {
            softmax_into(&logits, temp, &mut buf);
            assert_eq!(buf, softmax(&logits, temp));
            log_softmax_into(&logits, temp, &mut buf);
            assert_eq!(buf, log_softmax(&logits, temp));
        }
        let q = softmax(&logits, 1.0);
        assert!(residual_into(&q, &p, &mut buf));
        assert_eq!(buf, residual(&q, &p).unwrap());
    }

    /// Property: the speculative accept/resample rule reproduces q exactly.
    /// For random discrete (p, q), compute the output distribution
    /// analytically: P(x) = min(p_x, q_x) + P(reject) * residual(x) == q_x.
    #[test]
    fn prop_speculative_rule_recovers_target() {
        propcheck::check_no_shrink(
            11,
            300,
            |r| {
                let v = r.range(2, 8);
                let mut p: Vec<f32> = (0..v).map(|_| r.f32() + 1e-3).collect();
                let mut q: Vec<f32> = (0..v).map(|_| r.f32() + 1e-3).collect();
                let sp: f32 = p.iter().sum();
                let sq: f32 = q.iter().sum();
                p.iter_mut().for_each(|x| *x /= sp);
                q.iter_mut().for_each(|x| *x /= sq);
                (p, q)
            },
            |(p, q)| {
                let v = p.len();
                let accept_mass: f32 = (0..v).map(|x| p[x].min(q[x])).sum();
                let reject_prob = 1.0 - accept_mass;
                let out: Vec<f32> = match residual(q, p) {
                    Some(r) => (0..v)
                        .map(|x| p[x].min(q[x]) + reject_prob * r[x])
                        .collect(),
                    None => q.clone(),
                };
                for x in 0..v {
                    if (out[x] - q[x]).abs() > 1e-4 {
                        return Err(format!("P({x})={} != q={}", out[x], q[x]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sample_logits_statistics() {
        let mut rng = crate::util::rng::Rng::new(5);
        let logits = [0.0f32, 1.0, 2.0];
        let probs = softmax(&logits, 1.0);
        let mut counts = [0usize; 3];
        let n = 50_000;
        for _ in 0..n {
            let (t, p) = sample_logits(&mut rng, &logits, 1.0);
            assert!((p - probs[t]).abs() < 1e-6);
            counts[t] += 1;
        }
        for t in 0..3 {
            let emp = counts[t] as f32 / n as f32;
            assert!((emp - probs[t]).abs() < 0.01, "t={t} emp={emp} want={}", probs[t]);
        }
    }
}
