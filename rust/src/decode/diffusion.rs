//! Masked-diffusion baseline sampler (MDLM/SEDD-style, paper Table 2's
//! discrete-diffusion comparators).
//!
//! Absorbing-state reverse process discretized into `steps` steps: at each
//! step a scheduled number of still-masked positions is unmasked by
//! sampling INDEPENDENTLY from p(. | currently known tokens) — the
//! conditional-independence approximation the paper criticizes (Eq. 5).
//! NFE = `steps`, fixed, regardless of how many tokens are produced; the
//! output distribution only matches the true joint as steps -> #targets.

use crate::data::masking::lattice_sigma;
use crate::model::mask::Ordering;
use crate::tokenizer::MASK;
use crate::util::rng::Rng;

use super::sampling::{sample_probs, softmax_into};
use super::{DecodeMachine, DecodeOutcome, ForwardRequest};

pub struct DiffusionMachine {
    n: usize,
    vocab: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    /// positions still masked, in randomized unmasking order
    remaining: Vec<usize>,
    steps_left: usize,
    /// lattice ordering over the CURRENT known set: draft state `ord.m`
    /// gives "attend exactly the known set" rows for every unknown
    /// position — the engine rebuilds the masks from this, O(N) per step
    /// machine-side instead of O(N^2) mask materialization.
    ord: Ordering,
    /// positions to unmask this step (the requested logit rows)
    want: Vec<usize>,
    /// tokens unmasked since the last drain_commits (streaming hook);
    /// diffusion commits every position the moment it is unmasked
    committed: Vec<(usize, u32)>,
    /// vocab-sized scratch reused across rows (banned row copy + softmax)
    row_buf: Vec<f32>,
    prob_buf: Vec<f32>,
    model_nfe: u64,
    iterations: u64,
}

/// Frozen [`DiffusionMachine`] state (see [`crate::decode::snapshot`]).
/// `remaining` — the randomized unmasking order — MUST be serialized:
/// the constructor's shuffle already consumed RNG, so re-deriving it on
/// restore would replay draws the frozen RNG no longer has. The lattice
/// ordering is NOT serialized — it is a pure function of the token
/// buffer's known set and is re-derived, exactly as `absorb` does after
/// every step.
pub struct DiffusionSnapshot {
    vocab: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    remaining: Vec<usize>,
    steps_left: usize,
    committed: Vec<(usize, u32)>,
    model_nfe: u64,
    iterations: u64,
}

impl DiffusionMachine {
    /// Freeze into a [`DiffusionSnapshot`] (pure clone; the machine keeps
    /// running unaffected).
    pub fn snapshot(&self) -> DiffusionSnapshot {
        DiffusionSnapshot {
            vocab: self.vocab,
            temp: self.temp,
            rng: self.rng.clone(),
            tokens: self.tokens.clone(),
            remaining: self.remaining.clone(),
            steps_left: self.steps_left,
            committed: self.committed.clone(),
            model_nfe: self.model_nfe,
            iterations: self.iterations,
        }
    }

    /// Thaw a snapshot: the unmasking order resumes exactly where it was
    /// frozen (no re-shuffle — that RNG draw already happened), and the
    /// lattice ordering is re-derived from the current known set.
    pub fn from_snapshot(s: DiffusionSnapshot) -> Self {
        let ord = Self::known_ordering(&s.tokens);
        DiffusionMachine {
            n: s.tokens.len(),
            vocab: s.vocab,
            temp: s.temp,
            rng: s.rng,
            tokens: s.tokens,
            remaining: s.remaining,
            steps_left: s.steps_left,
            ord,
            want: vec![],
            committed: s.committed,
            row_buf: vec![],
            prob_buf: vec![],
            model_nfe: s.model_nfe,
            iterations: s.iterations,
        }
    }

    /// `tokens`: full sequence with MASK at target positions. `steps`: the
    /// discretization (paper's baselines use 32/64 for 1/3-sentence infill).
    pub fn new(tokens: Vec<u32>, vocab: usize, steps: usize, temp: f32, mut rng: Rng) -> Self {
        let n = tokens.len();
        assert!(steps >= 1);
        let mut remaining: Vec<usize> =
            (0..n).filter(|&p| tokens[p] == MASK).collect();
        // Random unmasking order (time-reversal of random absorption).
        rng.shuffle(&mut remaining);
        let steps_left = steps.min(remaining.len()).max(1);
        let ord = Self::known_ordering(&tokens);
        DiffusionMachine {
            n,
            vocab,
            temp,
            rng,
            tokens,
            remaining,
            steps_left,
            ord,
            want: vec![],
            committed: vec![],
            row_buf: vec![],
            prob_buf: vec![],
            model_nfe: 0,
            iterations: 0,
        }
    }

    fn known_ordering(tokens: &[u32]) -> Ordering {
        let n = tokens.len();
        let known: Vec<usize> = (0..n).filter(|&p| tokens[p] != MASK).collect();
        let m = known.len();
        Ordering::new(lattice_sigma(&known, n), m)
    }
}

impl DecodeMachine for DiffusionMachine {
    fn done(&self) -> bool {
        self.remaining.is_empty()
    }

    fn forward_request(&mut self) -> Option<ForwardRequest<'_>> {
        if self.done() {
            return None;
        }
        // Unmask ceil(remaining / steps_left) positions this step.
        let count = self.remaining.len().div_ceil(self.steps_left);
        self.want.clear();
        self.want.extend_from_slice(&self.remaining[..count]);
        Some(ForwardRequest {
            tokens: &self.tokens,
            ord: &self.ord,
            known: self.ord.m,
            want: &self.want,
        })
    }

    fn absorb(&mut self, logits: &[f32]) {
        debug_assert_eq!(logits.len(), self.want.len() * self.vocab);
        self.model_nfe += 1;
        self.iterations += 1;
        let count = self.want.len();
        for (i, &pos) in self.want.iter().enumerate() {
            self.row_buf.clear();
            self.row_buf
                .extend_from_slice(&logits[i * self.vocab..(i + 1) * self.vocab]);
            super::sampling::ban_ids(&mut self.row_buf, &super::sampling::BANNED);
            softmax_into(&self.row_buf, self.temp, &mut self.prob_buf);
            let tok = sample_probs(&mut self.rng, &self.prob_buf);
            if crate::obs::flight::enabled() {
                // Pure read of the sampling distribution (bit-identity
                // contract: the RNG is never touched).
                crate::obs::flight::record(crate::obs::flight::FlightEvent::Decode {
                    target_entropy: crate::obs::flight::entropy(&self.prob_buf),
                });
            }
            self.tokens[pos] = tok as u32;
            self.committed.push((pos, tok as u32));
        }
        self.remaining.drain(..count);
        self.steps_left = self.steps_left.saturating_sub(1).max(1);
        if !self.done() {
            self.ord = Self::known_ordering(&self.tokens);
        }
    }

    fn drain_commits(&mut self) -> Vec<(usize, u32)> {
        std::mem::take(&mut self.committed)
    }

    /// Deliberately NOT incremental (stays at the default `None`
    /// semantics, made explicit here): diffusion re-derives its lattice
    /// ordering from the current known set every step, and a "prompt"
    /// row's attention set grows with the known set — no committed row's
    /// content-stream state is ever stable, so there is nothing a K/V
    /// cache could legally persist. The scheduler keeps diffusion slots
    /// on the compact path.
    fn incremental(&self) -> Option<usize> {
        None
    }

    fn iter_stats(&self) -> super::IterStats {
        super::IterStats {
            model_nfe: self.model_nfe,
            iterations: self.iterations,
            ..Default::default()
        }
    }

    fn checkpoint(&self) -> Option<super::snapshot::DecodeSnapshot> {
        Some(super::snapshot::DecodeSnapshot::Diffusion(self.snapshot()))
    }

    fn outcome(self: Box<Self>) -> DecodeOutcome {
        assert!(self.done());
        DecodeOutcome {
            tokens: self.tokens,
            model_nfe: self.model_nfe,
            iterations: self.iterations,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::run_machine;
    use crate::runtime::mock::MockEngine;
    use crate::runtime::Engine;

    fn masked_input(n: usize, visible: &[(usize, u32)]) -> Vec<u32> {
        let mut t = vec![MASK; n];
        for &(p, v) in visible {
            t[p] = v;
        }
        t
    }

    #[test]
    fn nfe_equals_steps() {
        let e = MockEngine::new(1, 12, 5, 1.0);
        let toks = masked_input(12, &[(0, 1), (6, 2)]);
        let m = DiffusionMachine::new(toks, e.vocab(), 4, 1.0, Rng::new(3));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert_eq!(out.model_nfe, 4);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert_eq!(out.tokens[0], 1);
        assert_eq!(out.tokens[6], 2);
    }

    #[test]
    fn steps_capped_by_targets() {
        let e = MockEngine::new(2, 6, 4, 1.0);
        let toks = masked_input(6, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        // 2 targets but 64 steps requested -> at most 2 forwards
        let m = DiffusionMachine::new(toks, e.vocab(), 64, 1.0, Rng::new(4));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert!(out.model_nfe <= 2);
    }

    #[test]
    fn one_step_is_fully_parallel() {
        let e = MockEngine::new(3, 8, 4, 1.0);
        let toks = masked_input(8, &[(0, 1)]);
        let m = DiffusionMachine::new(toks, e.vocab(), 1, 1.0, Rng::new(5));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert_eq!(out.model_nfe, 1);
        assert!(out.tokens.iter().all(|&t| t != MASK));
    }

    #[test]
    fn drain_commits_covers_every_unmasked_position() {
        let e = MockEngine::new(6, 10, 4, 1.0);
        let toks = masked_input(10, &[(0, 1), (5, 2)]);
        let mut m = Box::new(DiffusionMachine::new(toks, e.vocab(), 3, 1.0, Rng::new(8)));
        let mut commits = vec![];
        while !m.done() {
            let rows = {
                let r = m.forward_request().unwrap();
                e.forward_ord(std::slice::from_ref(&r)).unwrap().pop().unwrap()
            };
            m.absorb(&rows);
            let chunk = m.drain_commits();
            assert!(!chunk.is_empty(), "every diffusion step unmasks something");
            commits.extend(chunk);
        }
        let out = m.outcome();
        let mut positions: Vec<usize> = commits.iter().map(|c| c.0).collect();
        positions.sort_unstable();
        positions.dedup();
        assert_eq!(positions.len(), commits.len());
        assert_eq!(commits.len(), 8);
        for (pos, tok) in commits {
            assert_eq!(out.tokens[pos], tok);
        }
    }

    #[test]
    fn later_steps_condition_on_earlier_tokens() {
        // With 2+ steps, the masks must grow: run twice with same seed but
        // different engine sharpness to sanity-check determinism of flow.
        let e = MockEngine::new(4, 8, 4, 1.0);
        let toks = masked_input(8, &[(2, 3)]);
        let run = |seed| {
            let m = DiffusionMachine::new(toks.clone(), e.vocab(), 3, 1.0, Rng::new(seed));
            run_machine(&e, Box::new(m)).unwrap().tokens
        };
        assert_eq!(run(9), run(9));
    }
}
