//! Masked-diffusion baseline sampler (MDLM/SEDD-style, paper Table 2's
//! discrete-diffusion comparators).
//!
//! Absorbing-state reverse process discretized into `steps` steps: at each
//! step a scheduled number of still-masked positions is unmasked by
//! sampling INDEPENDENTLY from p(. | currently known tokens) — the
//! conditional-independence approximation the paper criticizes (Eq. 5).
//! NFE = `steps`, fixed, regardless of how many tokens are produced; the
//! output distribution only matches the true joint as steps -> #targets.

use crate::data::masking::lattice_sigma;
use crate::model::mask::{draft_masks, Ordering};
use crate::tokenizer::MASK;
use crate::util::rng::Rng;

use super::sampling::sample_logits;
use super::{DecodeMachine, DecodeOutcome, ForwardRequest};

pub struct DiffusionMachine {
    n: usize,
    vocab: usize,
    temp: f32,
    rng: Rng,
    tokens: Vec<u32>,
    /// positions still masked, in randomized unmasking order
    remaining: Vec<usize>,
    steps_left: usize,
    mask_h: Vec<f32>,
    mask_g: Vec<f32>,
    model_nfe: u64,
    iterations: u64,
}

impl DiffusionMachine {
    /// `tokens`: full sequence with MASK at target positions. `steps`: the
    /// discretization (paper's baselines use 32/64 for 1/3-sentence infill).
    pub fn new(tokens: Vec<u32>, vocab: usize, steps: usize, temp: f32, mut rng: Rng) -> Self {
        let n = tokens.len();
        assert!(steps >= 1);
        let mut remaining: Vec<usize> =
            (0..n).filter(|&p| tokens[p] == MASK).collect();
        // Random unmasking order (time-reversal of random absorption).
        rng.shuffle(&mut remaining);
        let steps_left = steps.min(remaining.len()).max(1);
        let mut m = DiffusionMachine {
            n,
            vocab,
            temp,
            rng,
            tokens,
            remaining,
            steps_left,
            mask_h: vec![0.0; n * n],
            mask_g: vec![0.0; n * n],
            model_nfe: 0,
            iterations: 0,
        };
        m.rebuild_masks();
        m
    }

    fn rebuild_masks(&mut self) {
        // Known set = all non-MASK positions; draft-mode masks over the
        // lattice ordering of that set give "attend exactly the known set"
        // rows for every unknown position.
        let known: Vec<usize> = (0..self.n).filter(|&p| self.tokens[p] != MASK).collect();
        let m = known.len();
        let ord = Ordering::new(lattice_sigma(&known, self.n), m);
        draft_masks(&ord, m)
            .0
            .iter()
            .zip(self.mask_h.iter_mut())
            .for_each(|(&a, b)| *b = a);
        let (_, g) = draft_masks(&ord, m);
        self.mask_g.copy_from_slice(&g);
    }
}

impl DecodeMachine for DiffusionMachine {
    fn done(&self) -> bool {
        self.remaining.is_empty()
    }

    fn forward_request(&mut self) -> Option<ForwardRequest<'_>> {
        if self.done() {
            return None;
        }
        Some(ForwardRequest {
            tokens: &self.tokens,
            mask_h: &self.mask_h,
            mask_g: &self.mask_g,
        })
    }

    fn absorb(&mut self, logits: &[f32]) {
        debug_assert_eq!(logits.len(), self.n * self.vocab);
        self.model_nfe += 1;
        self.iterations += 1;
        // Unmask ceil(remaining / steps_left) positions this step.
        let count = self.remaining.len().div_ceil(self.steps_left);
        for _ in 0..count {
            let pos = self.remaining.remove(0);
            let mut row = logits[pos * self.vocab..(pos + 1) * self.vocab].to_vec();
            super::sampling::ban_ids(&mut row, &super::sampling::BANNED);
            let (tok, _) = sample_logits(&mut self.rng, &row, self.temp);
            self.tokens[pos] = tok as u32;
        }
        self.steps_left = self.steps_left.saturating_sub(1).max(1);
        if !self.done() {
            self.rebuild_masks();
        }
    }

    fn outcome(self: Box<Self>) -> DecodeOutcome {
        assert!(self.done());
        DecodeOutcome {
            tokens: self.tokens,
            model_nfe: self.model_nfe,
            iterations: self.iterations,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::run_machine;
    use crate::runtime::mock::MockEngine;
    use crate::runtime::Engine;

    fn masked_input(n: usize, visible: &[(usize, u32)]) -> Vec<u32> {
        let mut t = vec![MASK; n];
        for &(p, v) in visible {
            t[p] = v;
        }
        t
    }

    #[test]
    fn nfe_equals_steps() {
        let e = MockEngine::new(1, 12, 5, 1.0);
        let toks = masked_input(12, &[(0, 1), (6, 2)]);
        let m = DiffusionMachine::new(toks, e.vocab(), 4, 1.0, Rng::new(3));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert_eq!(out.model_nfe, 4);
        assert!(out.tokens.iter().all(|&t| t != MASK));
        assert_eq!(out.tokens[0], 1);
        assert_eq!(out.tokens[6], 2);
    }

    #[test]
    fn steps_capped_by_targets() {
        let e = MockEngine::new(2, 6, 4, 1.0);
        let toks = masked_input(6, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        // 2 targets but 64 steps requested -> at most 2 forwards
        let m = DiffusionMachine::new(toks, e.vocab(), 64, 1.0, Rng::new(4));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert!(out.model_nfe <= 2);
    }

    #[test]
    fn one_step_is_fully_parallel() {
        let e = MockEngine::new(3, 8, 4, 1.0);
        let toks = masked_input(8, &[(0, 1)]);
        let m = DiffusionMachine::new(toks, e.vocab(), 1, 1.0, Rng::new(5));
        let out = run_machine(&e, Box::new(m)).unwrap();
        assert_eq!(out.model_nfe, 1);
        assert!(out.tokens.iter().all(|&t| t != MASK));
    }

    #[test]
    fn later_steps_condition_on_earlier_tokens() {
        // With 2+ steps, the masks must grow: run twice with same seed but
        // different engine sharpness to sanity-check determinism of flow.
        let e = MockEngine::new(4, 8, 4, 1.0);
        let toks = masked_input(8, &[(2, 3)]);
        let run = |seed| {
            let m = DiffusionMachine::new(toks.clone(), e.vocab(), 3, 1.0, Rng::new(seed));
            run_machine(&e, Box::new(m)).unwrap().tokens
        };
        assert_eq!(run(9), run(9));
    }
}
