//! Context-derived n-gram draft model (paper Algorithm 2 / Eq. 23,
//! following [Ste+24]).
//!
//! A bigram table c(a | b) counted over the adjacent non-MASK pairs of the
//! partially decoded sequence, initialized from the prompt and updated as
//! tokens are accepted. Laplace-smoothed so proposals always have support.
//! Theorem 3 (paper App. D.5): under the Eq. 4 lattice ordering the left
//! neighbour of any drafted position is always available (either known or
//! drafted earlier in the same window).

use std::collections::HashMap;

use crate::tokenizer::MASK;

#[derive(Clone, Debug)]
pub struct BigramDraft {
    /// counts[(prev, next)]
    counts: HashMap<(u32, u32), u32>,
    /// row totals per prev
    totals: HashMap<u32, u32>,
    /// unigram counts (fallback for position 0 / unseen rows)
    unigram: HashMap<u32, u32>,
    uni_total: u32,
    vocab: usize,
    alpha: f32,
}

impl BigramDraft {
    /// Initialize by sweeping the current sequence (prompt tokens known,
    /// targets MASK).
    pub fn from_sequence(tokens: &[u32], vocab: usize) -> Self {
        let mut d = BigramDraft {
            counts: HashMap::new(),
            totals: HashMap::new(),
            unigram: HashMap::new(),
            uni_total: 0,
            vocab,
            alpha: 0.1,
        };
        for w in tokens.windows(2) {
            if w[0] != MASK && w[1] != MASK {
                d.observe(w[0], w[1]);
            }
        }
        for &t in tokens {
            if t != MASK {
                *d.unigram.entry(t).or_insert(0) += 1;
                d.uni_total += 1;
            }
        }
        d
    }

    /// Record a decoded bigram (prev -> next).
    pub fn observe(&mut self, prev: u32, next: u32) {
        *self.counts.entry((prev, next)).or_insert(0) += 1;
        *self.totals.entry(prev).or_insert(0) += 1;
    }

    pub fn observe_unigram(&mut self, t: u32) {
        *self.unigram.entry(t).or_insert(0) += 1;
        self.uni_total += 1;
    }

    /// Smoothed conditional distribution c(. | prev) as a dense vector.
    /// MASK/PAD specials carry no draft mass (they can never be verified).
    pub fn dist(&self, prev: Option<u32>) -> Vec<f32> {
        let v = self.vocab;
        let mut probs = vec![self.alpha; v];
        match prev {
            Some(p) if self.totals.get(&p).copied().unwrap_or(0) > 0 => {
                for ((a, b), &c) in self.counts.iter().map(|(k, v)| (k, v)) {
                    if *a == p {
                        probs[*b as usize] += c as f32;
                    }
                }
            }
            _ => {
                for (&t, &c) in &self.unigram {
                    probs[t as usize] += c as f32;
                }
            }
        }
        // Zero the specials AFTER counting (PAD pairs can occur in packed
        // prompts) and renormalize over the remaining support.
        for &sp in &[MASK, crate::tokenizer::PAD] {
            if (sp as usize) < v {
                probs[sp as usize] = 0.0;
            }
        }
        let total: f32 = probs.iter().sum();
        probs.iter_mut().for_each(|x| *x /= total.max(1e-30));
        probs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_prompt_bigrams() {
        // "abab" -> c(b|a) high
        let toks = vec![0u32, 1, 0, 1, MASK, MASK];
        let d = BigramDraft::from_sequence(&toks, 4);
        let dist = d.dist(Some(0));
        assert!(dist[1] > dist[0]);
        assert!(dist[1] > 0.5);
        let s: f32 = dist.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn mask_pairs_ignored() {
        let toks = vec![0u32, MASK, 1, MASK];
        let d = BigramDraft::from_sequence(&toks, 4);
        // no bigram was observable -> row 0 empty -> unigram fallback,
        // which saw tokens 0 and 1 once each.
        let dist = d.dist(Some(0));
        assert!((dist[0] - dist[1]).abs() < 1e-6);
        assert!(dist[0] > dist[2]);
        assert!(dist[2] > 0.0);
    }

    #[test]
    fn unigram_fallback_for_no_prev() {
        let toks = vec![2u32, 2, 2, 3, MASK];
        let d = BigramDraft::from_sequence(&toks, 5);
        let dist = d.dist(None);
        assert!(dist[2] > dist[3]);
        assert!(dist[3] > dist[0]);
    }

    #[test]
    fn observe_updates() {
        let mut d = BigramDraft::from_sequence(&[MASK, MASK], 3);
        for _ in 0..50 {
            d.observe(1, 2);
        }
        let dist = d.dist(Some(1));
        assert!(dist[2] > 0.9);
    }

    #[test]
    fn dist_always_positive_everywhere() {
        let d = BigramDraft::from_sequence(&[0, 1], 6);
        for prev in [None, Some(0), Some(5)] {
            let dist = d.dist(prev);
            assert!(dist.iter().all(|&x| x > 0.0));
        }
    }
}
