//! Self-drafting: the AS-ARM proposes from its own parallel marginals
//! (paper Algorithm 1).
//!
//! Under the draft-mode masks, every unknown position's head is exactly
//! p(. | x_sigma(<n)) — the conditionally independent parallel sampler of
//! Fig. 1a. The machine runs one draft-mode forward (model NFE) and hands
//! the GATHERED window rows here (compact ABI: `[t - n, V]`, row i ↔ order
//! n + i); this drafter just samples them. Lemma 1: the row at the first
//! unknown order equals the oracle conditional, so the first proposal of
//! every window survives verification and the final remaining token needs
//! no verify at all.

use crate::decode::sampling::{ban_ids, sample_probs, softmax_into, BANNED};
use crate::util::rng::Rng;

use super::{DraftContext, DraftProposal, Drafter};

/// The Algorithm-1 drafter. Semantically stateless — everything it needs
/// arrives with the draft-phase logits — but it keeps a vocab-sized
/// scratch row so the per-window ban+softmax never re-allocates (the
/// proposal DISTRIBUTIONS are still owned Vecs: the machine stores them
/// across the verify pass).
#[derive(Clone, Default)]
pub struct SelfDrafter {
    row_buf: Vec<f32>,
}

impl Drafter for SelfDrafter {
    fn name(&self) -> &'static str {
        "self"
    }

    fn needs_model_forward(&self) -> bool {
        true
    }

    fn boxed_clone(&self) -> Box<dyn Drafter> {
        Box::new(self.clone())
    }

    fn propose(
        &mut self,
        ctx: &DraftContext<'_>,
        logits: Option<&[f32]>,
        rng: &mut Rng,
    ) -> DraftProposal {
        let logits = logits.expect("self-drafting needs the draft-phase forward logits");
        let v = ctx.vocab;
        let w = ctx.t - ctx.n;
        debug_assert_eq!(logits.len(), w * v, "gathered window rows");
        let mut tokens = Vec::with_capacity(w);
        let mut dists = Vec::with_capacity(w);
        for i in 0..w {
            self.row_buf.clear();
            self.row_buf.extend_from_slice(&logits[i * v..(i + 1) * v]);
            ban_ids(&mut self.row_buf, &BANNED);
            let mut probs = Vec::with_capacity(v);
            softmax_into(&self.row_buf, ctx.temp, &mut probs);
            let tok = sample_probs(rng, &probs) as u32;
            tokens.push(tok);
            dists.push(probs);
        }
        DraftProposal { tokens, dists }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::lattice_sigma;
    use crate::model::mask::Ordering;

    #[test]
    fn samples_window_from_logit_rows() {
        let mut d = SelfDrafter::default();
        assert_eq!(d.name(), "self");
        assert!(d.needs_model_forward());
        assert!(d.lemma1_exact());
        let v = 4;
        let n = 3;
        let ord = Ordering::new(lattice_sigma(&[0], n), 1);
        let tokens = vec![1u32, crate::tokenizer::MASK, crate::tokenizer::MASK];
        // Gathered window rows (orders 1..3): row 0 strongly prefers
        // token 2; row 1 token 3.
        let mut logits = vec![0.0f32; 2 * v];
        logits[2] = 50.0;
        logits[v + 3] = 50.0;
        let ctx = DraftContext {
            tokens: &tokens,
            ord: &ord,
            n: 1,
            t: 3,
            temp: 1.0,
            vocab: v,
        };
        let mut rng = Rng::new(7);
        let prop = d.propose(&ctx, Some(&logits), &mut rng);
        assert_eq!(prop.tokens, vec![2, 3]);
        for dist in &prop.dists {
            let sum: f32 = dist.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }
}
