//! The draft subsystem: pluggable speculation sources for ASSD.
//!
//! ASSD's speedup is bounded by how good and how long the draft is
//! (Theorem 1 charges one verify forward per while-loop iteration, so
//! longer accepted prefixes mean fewer forwards per token). This module
//! makes the draft source a first-class, swappable component:
//!
//! * [`Drafter`] — the trait: propose `t - n` tokens *with their full
//!   per-token proposal distributions* for a window of orders, and receive
//!   accept/reject feedback after verification. Any proposal distribution
//!   is admissible — speculative accept/resample reproduces the target
//!   distribution exactly for arbitrary proposals (decode/sampling.rs's
//!   `prop_speculative_rule_recovers_target`) — so swapping drafters can
//!   change speed but never the output law (Theorem 2).
//! * [`SelfDrafter`] — the paper's Algorithm 1: the AS-ARM drafts for
//!   itself from its own parallel marginals (one draft-mode forward, model
//!   NFE; Lemma 1 makes the first proposal exact).
//! * [`BigramDrafter`] — the paper's Algorithm 2: a context bigram table
//!   (aux NFE only; Lemma 1 does not apply).
//! * [`PromptLookupDrafter`] — mistral.rs-style prompt-lookup decoding:
//!   match the longest recent context suffix against the prompt and the
//!   already-generated text and propose the continuation (aux NFE only).
//! * [`AdaptiveSpeculation`] — the per-request draft-length controller: an
//!   EWMA of observed acceptance rates grows the window under sustained
//!   acceptance and shrinks it on rejection streaks, clamped to the
//!   engine's compiled shape limits.
//!
//! [`AssdMachine`](crate::decode::assd::AssdMachine) drives the loop:
//! `propose` -> write window -> verify forward -> accept/reject ->
//! `observe_outcome` (feedback to the controller and the drafter) ->
//! `observe_commit` (committed tokens, e.g. to grow the bigram table).

pub mod adaptive;
pub mod bigram;
pub mod lookup;
pub mod selfmodel;

pub use adaptive::AdaptiveSpeculation;
pub use bigram::{BigramDraft, BigramDrafter};
pub use lookup::PromptLookupDrafter;
pub use selfmodel::SelfDrafter;

use anyhow::{bail, Result};

use crate::model::mask::Ordering;
use crate::util::rng::Rng;

/// Everything a drafter may condition on: the current full-sequence token
/// buffer (MASK at not-yet-committed positions), the generation ordering,
/// and the window of orders `n..t` to draft.
pub struct DraftContext<'a> {
    pub tokens: &'a [u32],
    pub ord: &'a Ordering,
    /// First order to draft (the current decode state).
    pub n: usize,
    /// One past the last order to draft (`t - n` proposals wanted).
    pub t: usize,
    pub temp: f32,
    pub vocab: usize,
}

/// A drafter's output: one token and one full proposal distribution per
/// order in `n..t` (the distributions are the `p` rows of the speculative
/// accept test `r < min(1, q/p)`).
pub struct DraftProposal {
    pub tokens: Vec<u32>,
    pub dists: Vec<Vec<f32>>,
}

/// A speculation source for ASSD.
///
/// Contract: `propose` must return exactly `ctx.t - ctx.n` tokens and
/// distributions; every distribution must be normalized with zero mass on
/// the MASK/PAD specials (the verify pass bans them, and a proposal the
/// model can never emit would be pure waste). Proposals sampled from the
/// returned distributions — the machine relies on `dists[i][tokens[i]] > 0`
/// for the acceptance ratio.
///
/// `Send` is a supertrait: drafters ride inside
/// [`crate::decode::snapshot::DecodeSnapshot`]s, which cross worker
/// threads through the scheduler's resume queue (preemption, migration,
/// drain). All shipped drafters are plain owned data.
pub trait Drafter: Send {
    /// Short stable identifier ("self" / "bigram" / "lookup"), reported in
    /// responses and metrics.
    fn name(&self) -> &'static str;

    /// True when proposals are read from the AS-ARM's own draft-phase
    /// forward: the machine runs one draft-mode forward (model NFE) and
    /// passes its logits to `propose`. External drafters return false and
    /// are booked as aux NFE instead.
    fn needs_model_forward(&self) -> bool {
        false
    }

    /// Lemma 1: the proposal density at the first unknown order equals the
    /// oracle density, so the final remaining token may be accepted without
    /// a verify forward. Exact only for self-drafting.
    fn lemma1_exact(&self) -> bool {
        self.needs_model_forward()
    }

    /// Propose tokens + proposal distributions for orders `ctx.n..ctx.t`.
    /// `logits` is `Some` iff [`Drafter::needs_model_forward`] returns
    /// true, and then holds the GATHERED draft-phase window rows
    /// (`[ctx.t - ctx.n, V]` row-major, row `i` ↔ order `ctx.n + i`) —
    /// the compact forward ABI returns only the rows the machine asked
    /// for, never the full `[N, V]` grid.
    fn propose(
        &mut self,
        ctx: &DraftContext<'_>,
        logits: Option<&[f32]>,
        rng: &mut Rng,
    ) -> DraftProposal;

    /// Verification feedback: of the `proposed` tokens examined this
    /// iteration, the first `accepted` were kept. Default: ignore.
    fn observe_outcome(&mut self, accepted: usize, proposed: usize) {
        let _ = (accepted, proposed);
    }

    /// Committed-token feedback: orders `n_old..n_new` of `ord` are now
    /// final in `tokens` (accepted or resampled). Table-based drafters use
    /// this to learn from the generated text. Default: ignore.
    fn observe_commit(&mut self, tokens: &[u32], ord: &Ordering, n_old: usize, n_new: usize) {
        let _ = (tokens, ord, n_old, n_new);
    }

    /// Deep-copy this drafter behind a fresh box — the checkpointing hook
    /// ([`crate::decode::snapshot`]). Learned state (the bigram table's
    /// counts) must be carried: a restored machine whose drafter forgot
    /// what it learned would propose differently and, while still
    /// distributionally exact (Theorem 2), break bit-identity with the
    /// uninterrupted run. Required (no default): every drafter must state
    /// its clone explicitly.
    fn boxed_clone(&self) -> Box<dyn Drafter>;
}

/// Which [`Drafter`] implementation serves a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DraftKind {
    /// The AS-ARM drafts for itself (Algorithm 1; model NFE, Lemma 1).
    SelfModel,
    /// Context bigram table (Algorithm 2; aux NFE).
    Bigram,
    /// Prompt-lookup / suffix matching against prompt + generated text.
    Lookup,
}

impl DraftKind {
    pub const ALL: [DraftKind; 3] = [DraftKind::SelfModel, DraftKind::Bigram, DraftKind::Lookup];

    /// Case-insensitive parse; the error lists the valid kinds.
    pub fn parse(s: &str) -> Result<DraftKind> {
        let lower = s.to_ascii_lowercase();
        for k in DraftKind::ALL {
            if k.name() == lower {
                return Ok(k);
            }
        }
        bail!(
            "unknown draft kind '{s}' (valid kinds: {})",
            DraftKind::ALL.map(|k| k.name()).join(", ")
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            DraftKind::SelfModel => "self",
            DraftKind::Bigram => "bigram",
            DraftKind::Lookup => "lookup",
        }
    }
}

/// Per-request draft configuration (the HTTP `"draft"` object and the
/// `--draft*` CLI flags).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DraftOptions {
    pub kind: DraftKind,
    /// Draft window length (Algorithm 1's k). Fixed length when `adaptive`
    /// is false; the initial length otherwise.
    pub max_len: usize,
    /// Let [`AdaptiveSpeculation`] retune the window from observed
    /// acceptance (grow past `max_len` up to the engine's shape limits,
    /// shrink on rejection streaks).
    pub adaptive: bool,
}

impl Default for DraftOptions {
    fn default() -> Self {
        DraftOptions {
            kind: DraftKind::SelfModel,
            max_len: 5,
            adaptive: false,
        }
    }
}

impl DraftOptions {
    /// Instantiate the drafter. `tokens` is the initial full-sequence
    /// buffer (prompt visible, targets MASK) used to seed table drafters.
    pub fn build(&self, tokens: &[u32], vocab: usize) -> Box<dyn Drafter> {
        match self.kind {
            DraftKind::SelfModel => Box::new(SelfDrafter::default()),
            DraftKind::Bigram => Box::new(BigramDrafter::from_sequence(tokens, vocab)),
            DraftKind::Lookup => Box::new(PromptLookupDrafter::new(vocab)),
        }
    }

    /// The matching speculation controller.
    pub fn speculation(&self) -> AdaptiveSpeculation {
        if self.adaptive {
            AdaptiveSpeculation::adaptive(self.max_len)
        } else {
            AdaptiveSpeculation::fixed(self.max_len)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrips_and_is_case_insensitive() {
        for k in DraftKind::ALL {
            assert_eq!(DraftKind::parse(k.name()).unwrap(), k);
            assert_eq!(DraftKind::parse(&k.name().to_uppercase()).unwrap(), k);
        }
        assert_eq!(DraftKind::parse("Self").unwrap(), DraftKind::SelfModel);
    }

    #[test]
    fn kind_parse_error_lists_valid_kinds() {
        let err = DraftKind::parse("bogus").unwrap_err().to_string();
        for k in DraftKind::ALL {
            assert!(err.contains(k.name()), "{err}");
        }
    }

    #[test]
    fn options_build_matches_kind() {
        let toks = [0u32, 1, 2];
        for kind in DraftKind::ALL {
            let opts = DraftOptions {
                kind,
                ..Default::default()
            };
            assert_eq!(opts.build(&toks, 8).name(), kind.name());
        }
    }

    #[test]
    fn options_speculation_mode() {
        let fixed = DraftOptions {
            max_len: 7,
            ..Default::default()
        };
        assert_eq!(fixed.speculation().current(), 7);
        assert!(!fixed.speculation().is_adaptive());
        let adaptive = DraftOptions {
            adaptive: true,
            max_len: 7,
            ..Default::default()
        };
        assert!(adaptive.speculation().is_adaptive());
        assert_eq!(adaptive.speculation().current(), 7);
    }
}
