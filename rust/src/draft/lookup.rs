//! Prompt-lookup drafting: suffix matching against the prompt and the
//! already-generated text (the mistral.rs / prompt-lookup-decoding family
//! of draft sources, adapted to any-subset orderings).
//!
//! To draft order i, take the longest run of already-known tokens
//! immediately left of position sigma(i) (up to `max_ngram`), scan the
//! rest of the sequence for an earlier occurrence of that run whose
//! continuation is also known, and propose that continuation. Natural
//! text repeats itself — prompts, names, subphrases — so the lookup is
//! right often enough to lengthen accepted prefixes at zero model cost
//! (aux NFE only).
//!
//! Correctness does not depend on the lookup being right: the proposal
//! distribution mixes the looked-up continuation with a smoothed unigram
//! background over the full non-special vocabulary, and speculative
//! accept/resample reproduces the target distribution for any full-support
//! proposal (see draft/mod.rs). A bad match only costs acceptance rate.

use crate::decode::sampling::sample_probs;
use crate::tokenizer::{MASK, PAD};
use crate::util::rng::Rng;

use super::{DraftContext, DraftProposal, Drafter};

/// Suffix-matching drafter over the live token buffer. Stateless between
/// iterations: every window re-reads the current prompt + generated text,
/// so accepted tokens immediately become lookup material.
#[derive(Clone)]
pub struct PromptLookupDrafter {
    vocab: usize,
    /// Longest context suffix tried (then backed off to shorter ones).
    max_ngram: usize,
    /// Probability mass placed on a lookup hit; the remainder is the
    /// smoothed unigram background.
    hit_mass: f32,
    /// Laplace smoothing for the background distribution.
    alpha: f32,
}

impl PromptLookupDrafter {
    pub fn new(vocab: usize) -> PromptLookupDrafter {
        PromptLookupDrafter {
            vocab,
            max_ngram: 3,
            hit_mass: 0.9,
            alpha: 0.1,
        }
    }

    fn is_special(&self, t: u32) -> bool {
        t == MASK || t == PAD || (t as usize) >= self.vocab
    }

    /// Find a continuation for position `pos` by matching the longest
    /// known suffix `work[pos-l..pos]` elsewhere in `work`. Returns the
    /// most recent (rightmost) match's continuation token.
    fn lookup(&self, work: &[u32], pos: usize) -> Option<u32> {
        for l in (1..=self.max_ngram).rev() {
            if pos < l {
                continue;
            }
            let key = &work[pos - l..pos];
            if key.iter().any(|&t| self.is_special(t)) {
                continue;
            }
            // right-to-left: the first hit IS the most recent match
            for j in (0..work.len().saturating_sub(l)).rev() {
                let cont = j + l;
                if cont == pos || self.is_special(work[cont]) {
                    continue;
                }
                if &work[j..cont] == key {
                    return Some(work[cont]);
                }
            }
        }
        None
    }

    /// Unigram counts of the known (non-special) tokens — built once per
    /// draft window and updated incrementally as the overlay fills, so a
    /// window costs O(N + k·vocab) instead of O(k·N·vocab).
    fn background_counts(&self, work: &[u32]) -> Vec<u32> {
        let mut counts = vec![0u32; self.vocab];
        for &t in work {
            if !self.is_special(t) {
                counts[t as usize] += 1;
            }
        }
        counts
    }

    /// Proposal distribution: smoothed unigram background over the known
    /// text, with `hit_mass` folded onto the lookup hit when there is one.
    fn dist_for(&self, counts: &[u32], hit: Option<u32>) -> Vec<f32> {
        let v = self.vocab;
        let mut probs = vec![self.alpha; v];
        for (t, &c) in counts.iter().enumerate() {
            probs[t] += c as f32;
        }
        for &sp in &[MASK, PAD] {
            if (sp as usize) < v {
                probs[sp as usize] = 0.0;
            }
        }
        let total: f32 = probs.iter().sum();
        probs.iter_mut().for_each(|x| *x /= total.max(1e-30));
        if let Some(h) = hit {
            debug_assert!(!self.is_special(h));
            probs.iter_mut().for_each(|x| *x *= 1.0 - self.hit_mass);
            probs[h as usize] += self.hit_mass;
        }
        probs
    }
}

impl Drafter for PromptLookupDrafter {
    fn name(&self) -> &'static str {
        "lookup"
    }

    fn boxed_clone(&self) -> Box<dyn Drafter> {
        Box::new(self.clone())
    }

    fn propose(
        &mut self,
        ctx: &DraftContext<'_>,
        _logits: Option<&[f32]>,
        rng: &mut Rng,
    ) -> DraftProposal {
        // Work on an overlay copy so tokens drafted earlier in this window
        // become context (and lookup material) for later ones.
        let mut work = ctx.tokens.to_vec();
        let mut counts = self.background_counts(&work);
        let mut tokens = Vec::with_capacity(ctx.t - ctx.n);
        let mut dists = Vec::with_capacity(ctx.t - ctx.n);
        for i in ctx.n..ctx.t {
            let pos = ctx.ord.sigma[i];
            let hit = self.lookup(&work, pos);
            let dist = self.dist_for(&counts, hit);
            let tok = sample_probs(rng, &dist) as u32;
            work[pos] = tok;
            counts[tok as usize] += 1;
            tokens.push(tok);
            dists.push(dist);
        }
        DraftProposal { tokens, dists }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::masking::lattice_sigma;
    use crate::model::mask::Ordering;

    #[test]
    fn lookup_finds_repeated_ngram_continuation() {
        // "abcX...abc_" — the suffix "abc" before the blank occurred
        // earlier followed by X, so X is the continuation.
        let d = PromptLookupDrafter::new(300);
        let a = b'a' as u32;
        let work = vec![a, a + 1, a + 2, 7, 9, a, a + 1, a + 2, MASK];
        assert_eq!(d.lookup(&work, 8), Some(7));
    }

    #[test]
    fn lookup_prefers_longest_suffix_then_most_recent() {
        let d = PromptLookupDrafter::new(300);
        // suffix "xy" matches at two sites with different continuations;
        // the most recent one (5) wins.
        let work = vec![1u32, 2, 3, 9, 1, 2, 5, 9, 1, 2, MASK];
        assert_eq!(d.lookup(&work, 10), Some(5));
    }

    #[test]
    fn lookup_none_when_left_context_unknown() {
        let d = PromptLookupDrafter::new(300);
        let work = vec![1u32, 2, MASK, MASK];
        assert_eq!(d.lookup(&work, 3), None);
        // position 0 has no left context at all
        let work0 = vec![MASK, 1, 2];
        assert_eq!(d.lookup(&work0, 0), None);
    }

    #[test]
    fn dist_is_normalized_with_full_support_and_spiked_on_hit() {
        let d = PromptLookupDrafter::new(260);
        let work = vec![1u32, 2, 1, 2, MASK];
        let counts = d.background_counts(&work);
        for hit in [None, Some(2u32)] {
            let dist = d.dist_for(&counts, hit);
            let sum: f32 = dist.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
            assert_eq!(dist[MASK as usize], 0.0);
            assert_eq!(dist[PAD as usize], 0.0);
            for (t, &p) in dist.iter().enumerate() {
                if t as u32 != MASK && t as u32 != PAD {
                    assert!(p > 0.0, "zero mass at {t}");
                }
            }
        }
        let spiked = d.dist_for(&counts, Some(2));
        assert!(spiked[2] > 0.9, "hit mass {}", spiked[2]);
    }

    #[test]
    fn propose_fills_window_and_uses_drafted_overlay() {
        let mut d = PromptLookupDrafter::new(300);
        // prompt "ababab__" under the lattice ordering
        let tokens = vec![10u32, 11, 10, 11, 10, 11, MASK, MASK];
        let visible = [0usize, 1, 2, 3, 4, 5];
        let ord = Ordering::new(lattice_sigma(&visible, 8), 6);
        let ctx = DraftContext {
            tokens: &tokens,
            ord: &ord,
            n: 6,
            t: 8,
            temp: 1.0,
            vocab: 300,
        };
        let mut rng = Rng::new(3);
        let prop = d.propose(&ctx, None, &mut rng);
        assert_eq!(prop.tokens.len(), 2);
        assert_eq!(prop.dists.len(), 2);
        // The period-2 pattern makes both lookups near-certain: position 6
        // continues "ab"->a... check the first proposal is the pattern
        // continuation with overwhelming probability mass.
        assert!(prop.dists[0][10] > 0.9);
        for dist in &prop.dists {
            let sum: f32 = dist.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }
}
