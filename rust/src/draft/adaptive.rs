//! Adaptive speculation control: tune the draft window length per request
//! from an EWMA of observed acceptance rates.
//!
//! Draft length is the main free knob in ASSD's NFE accounting: one verify
//! forward is spent per while-loop iteration regardless of window length,
//! so when drafts keep being accepted a longer window converts the same
//! forward into more tokens, and when rejections are frequent a long
//! window only wastes draft work (aux NFE for table drafters). The
//! controller is deliberately TCP-like: multiplicative growth under
//! sustained full acceptance, halving on rejection streaks, additive
//! shrink while the EWMA is poor — simple, monotone, and clamped.
//!
//! Clamping: the upper bound is a *shape* limit, not a tuning choice — the
//! draft and verify passes reuse the engine's compiled `fwd_b{B}` [B, N]
//! executables, so a window can never exceed the artifact sequence length
//! (and never usefully exceeds the remaining target count). The scheduler
//! clamps to the engine window at admission; the decode machine clamps to
//! the remaining targets every iteration.

/// EWMA smoothing factor for the per-iteration acceptance rate.
const EWMA_ALPHA: f64 = 0.3;
/// Grow the window when the EWMA is at least this (and the last iteration
/// was fully accepted).
const GROW_THRESHOLD: f64 = 0.75;
/// Shrink (additively) while the EWMA is below this.
const SHRINK_THRESHOLD: f64 = 0.35;
/// Halve the window after this many consecutive iterations with a
/// rejection.
const REJECT_STREAK_LIMIT: u32 = 2;

/// Per-request draft-length controller. Copy-able plain state; one
/// instance lives inside each ASSD decode machine.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveSpeculation {
    k: usize,
    k_min: usize,
    k_max: usize,
    adaptive: bool,
    /// EWMA of per-iteration acceptance rates, optimistic start.
    ewma: f64,
    reject_streak: u32,
}

impl AdaptiveSpeculation {
    /// Fixed-length speculation: `current()` is always `k`.
    pub fn fixed(k: usize) -> AdaptiveSpeculation {
        assert!(k >= 1, "draft length must be >= 1");
        AdaptiveSpeculation {
            k,
            k_min: k,
            k_max: k,
            adaptive: false,
            ewma: 1.0,
            reject_streak: 0,
        }
    }

    /// Adaptive speculation starting at `init`. The floor is 2 (the
    /// Theorem-1 bound needs windows of at least two; see
    /// decode/assd.rs's `k1_completes_but_violates_theorem1_bound`); the
    /// ceiling is unbounded until [`AdaptiveSpeculation::clamp_max`] is
    /// applied with the engine's shape limit.
    pub fn adaptive(init: usize) -> AdaptiveSpeculation {
        let k_min = 2;
        AdaptiveSpeculation {
            k: init.max(k_min),
            k_min,
            k_max: usize::MAX,
            adaptive: true,
            ewma: 1.0,
            reject_streak: 0,
        }
    }

    /// Apply a shape limit (engine sequence window / remaining targets):
    /// the window may never exceed `cap` from here on.
    pub fn clamp_max(&mut self, cap: usize) {
        let cap = cap.max(1);
        self.k_max = self.k_max.min(cap);
        self.k_min = self.k_min.min(self.k_max);
        self.k = self.k.clamp(self.k_min, self.k_max);
    }

    /// The draft length to use for the next iteration.
    pub fn current(&self) -> usize {
        self.k
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    /// Smoothed acceptance rate observed so far.
    pub fn accept_ewma(&self) -> f64 {
        self.ewma
    }

    /// Feed one iteration's verification outcome: `accepted` of the
    /// `proposed` examined tokens were kept. No-op for fixed mode.
    pub fn record(&mut self, accepted: usize, proposed: usize) {
        if !self.adaptive || proposed == 0 {
            return;
        }
        let rate = accepted as f64 / proposed as f64;
        self.ewma = EWMA_ALPHA * rate + (1.0 - EWMA_ALPHA) * self.ewma;
        if accepted < proposed {
            self.reject_streak += 1;
        } else {
            self.reject_streak = 0;
        }
        if self.reject_streak >= REJECT_STREAK_LIMIT {
            self.k = (self.k / 2).max(self.k_min);
            self.reject_streak = 0;
        } else if self.ewma >= GROW_THRESHOLD && accepted == proposed {
            self.k = self.k.saturating_mul(2).min(self.k_max);
        } else if self.ewma < SHRINK_THRESHOLD {
            self.k = (self.k - 1).max(self.k_min);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_moves() {
        let mut s = AdaptiveSpeculation::fixed(5);
        for _ in 0..10 {
            s.record(0, 5);
        }
        assert_eq!(s.current(), 5);
        assert!(!s.is_adaptive());
    }

    #[test]
    fn grows_under_full_acceptance_up_to_cap() {
        let mut s = AdaptiveSpeculation::adaptive(4);
        s.clamp_max(64);
        for _ in 0..10 {
            let k = s.current();
            s.record(k, k);
        }
        assert_eq!(s.current(), 64, "should have grown to the cap");
    }

    #[test]
    fn rejection_streak_halves() {
        let mut s = AdaptiveSpeculation::adaptive(16);
        s.clamp_max(16);
        // Two consecutive iterations with a rejection halve the window.
        s.record(15, 16);
        s.record(15, 16);
        assert_eq!(s.current(), 8);
    }

    #[test]
    fn poor_ewma_shrinks_to_floor_not_below() {
        let mut s = AdaptiveSpeculation::adaptive(6);
        s.clamp_max(6);
        for _ in 0..50 {
            s.record(0, s.current());
        }
        assert_eq!(s.current(), 2, "floor is 2 (Theorem 1 needs windows >= 2)");
    }

    #[test]
    fn stays_within_bounds_under_random_feedback() {
        let mut rng = crate::util::rng::Rng::new(9);
        let mut s = AdaptiveSpeculation::adaptive(5);
        s.clamp_max(32);
        for _ in 0..500 {
            let proposed = rng.range(1, 33);
            let accepted = rng.below(proposed + 1);
            s.record(accepted, proposed);
            assert!((2..=32).contains(&s.current()), "k={}", s.current());
        }
    }

    #[test]
    fn clamp_tightens_current() {
        let mut s = AdaptiveSpeculation::adaptive(20);
        s.clamp_max(8);
        assert_eq!(s.current(), 8);
        // fixed mode clamps too (window larger than the model's target set)
        let mut f = AdaptiveSpeculation::fixed(50);
        f.clamp_max(10);
        assert_eq!(f.current(), 10);
    }
}
