//! Context-derived bigram draft model (paper Algorithm 2 / Eq. 23,
//! following [Ste+24]), behind the [`Drafter`] trait.
//!
//! [`BigramDraft`] is the table: c(a | b) counted over the adjacent
//! non-MASK pairs of the partially decoded sequence, initialized from the
//! prompt and updated as tokens are accepted. Laplace-smoothed so
//! proposals always have support. [`BigramDrafter`] wraps it as a
//! [`Drafter`]: Theorem 3 (paper App. D.5) guarantees that under the
//! Eq. 4 lattice ordering the left neighbour of any drafted position is
//! always available (either known or drafted earlier in the same window).

use std::collections::HashMap;

use crate::decode::sampling::sample_probs;
use crate::model::mask::Ordering;
use crate::tokenizer::{MASK, PAD};
use crate::util::rng::Rng;

use super::{DraftContext, DraftProposal, Drafter};

#[derive(Clone, Debug)]
pub struct BigramDraft {
    /// counts[(prev, next)]
    counts: HashMap<(u32, u32), u32>,
    /// row totals per prev
    totals: HashMap<u32, u32>,
    /// unigram counts (fallback for position 0 / unseen rows)
    unigram: HashMap<u32, u32>,
    uni_total: u32,
    vocab: usize,
    alpha: f32,
}

impl BigramDraft {
    /// Initialize by sweeping the current sequence (prompt tokens known,
    /// targets MASK).
    pub fn from_sequence(tokens: &[u32], vocab: usize) -> Self {
        let mut d = BigramDraft {
            counts: HashMap::new(),
            totals: HashMap::new(),
            unigram: HashMap::new(),
            uni_total: 0,
            vocab,
            alpha: 0.1,
        };
        for w in tokens.windows(2) {
            if w[0] != MASK && w[1] != MASK {
                d.observe(w[0], w[1]);
            }
        }
        for &t in tokens {
            if t != MASK {
                *d.unigram.entry(t).or_insert(0) += 1;
                d.uni_total += 1;
            }
        }
        d
    }

    /// Record a decoded bigram (prev -> next).
    pub fn observe(&mut self, prev: u32, next: u32) {
        *self.counts.entry((prev, next)).or_insert(0) += 1;
        *self.totals.entry(prev).or_insert(0) += 1;
    }

    pub fn observe_unigram(&mut self, t: u32) {
        *self.unigram.entry(t).or_insert(0) += 1;
        self.uni_total += 1;
    }

    /// Smoothed conditional distribution c(. | prev) as a dense vector.
    /// MASK/PAD specials carry no draft mass (they can never be verified).
    pub fn dist(&self, prev: Option<u32>) -> Vec<f32> {
        let v = self.vocab;
        let mut probs = vec![self.alpha; v];
        match prev {
            Some(p) if self.totals.get(&p).copied().unwrap_or(0) > 0 => {
                for ((a, b), &c) in self.counts.iter().map(|(k, v)| (k, v)) {
                    if *a == p {
                        probs[*b as usize] += c as f32;
                    }
                }
            }
            _ => {
                for (&t, &c) in &self.unigram {
                    probs[t as usize] += c as f32;
                }
            }
        }
        // Zero the specials AFTER counting (PAD pairs can occur in packed
        // prompts) and renormalize over the remaining support.
        for &sp in &[MASK, PAD] {
            if (sp as usize) < v {
                probs[sp as usize] = 0.0;
            }
        }
        let total: f32 = probs.iter().sum();
        probs.iter_mut().for_each(|x| *x /= total.max(1e-30));
        probs
    }
}

/// [`BigramDraft`] as a pluggable [`Drafter`] (aux NFE; Lemma 1 does not
/// apply, so even the final token is verified).
#[derive(Clone)]
pub struct BigramDrafter {
    table: BigramDraft,
}

impl BigramDrafter {
    pub fn from_sequence(tokens: &[u32], vocab: usize) -> BigramDrafter {
        BigramDrafter {
            table: BigramDraft::from_sequence(tokens, vocab),
        }
    }
}

impl Drafter for BigramDrafter {
    fn name(&self) -> &'static str {
        "bigram"
    }

    fn boxed_clone(&self) -> Box<dyn Drafter> {
        Box::new(self.clone())
    }

    fn propose(
        &mut self,
        ctx: &DraftContext<'_>,
        _logits: Option<&[f32]>,
        rng: &mut Rng,
    ) -> DraftProposal {
        let mut tokens = Vec::with_capacity(ctx.t - ctx.n);
        let mut dists = Vec::with_capacity(ctx.t - ctx.n);
        for i in ctx.n..ctx.t {
            let pos = ctx.ord.sigma[i];
            // Theorem 3: the left neighbour of sigma(i) is known or drafted
            // earlier in this window (the lattice keeps targets sorted).
            let prev = if pos == 0 {
                None
            } else {
                let left = ctx.tokens[pos - 1];
                if left != MASK {
                    Some(left)
                } else {
                    let oi = ctx.ord.order[pos - 1];
                    if oi >= ctx.n && oi < i {
                        Some(tokens[oi - ctx.n])
                    } else {
                        None
                    }
                }
            };
            let dist = self.table.dist(prev);
            let tok = sample_probs(rng, &dist) as u32;
            tokens.push(tok);
            dists.push(dist);
        }
        DraftProposal { tokens, dists }
    }

    fn observe_commit(&mut self, tokens: &[u32], ord: &Ordering, n_old: usize, n_new: usize) {
        for i in n_old..n_new {
            let pos = ord.sigma[i];
            let tok = tokens[pos];
            self.table.observe_unigram(tok);
            if pos > 0 {
                let left = tokens[pos - 1];
                if left != MASK {
                    self.table.observe(left, tok);
                }
            }
            if pos + 1 < tokens.len() {
                let right = tokens[pos + 1];
                if right != MASK {
                    self.table.observe(tok, right);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn counts_prompt_bigrams() {
        // "abab" -> c(b|a) high
        let toks = vec![0u32, 1, 0, 1, MASK, MASK];
        let d = BigramDraft::from_sequence(&toks, 4);
        let dist = d.dist(Some(0));
        assert!(dist[1] > dist[0]);
        assert!(dist[1] > 0.5);
        let s: f32 = dist.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn mask_pairs_ignored() {
        let toks = vec![0u32, MASK, 1, MASK];
        let d = BigramDraft::from_sequence(&toks, 4);
        // no bigram was observable -> row 0 empty -> unigram fallback,
        // which saw tokens 0 and 1 once each.
        let dist = d.dist(Some(0));
        assert!((dist[0] - dist[1]).abs() < 1e-6);
        assert!(dist[0] > dist[2]);
        assert!(dist[2] > 0.0);
    }

    #[test]
    fn unigram_fallback_for_no_prev() {
        let toks = vec![2u32, 2, 2, 3, MASK];
        let d = BigramDraft::from_sequence(&toks, 5);
        let dist = d.dist(None);
        assert!(dist[2] > dist[3]);
        assert!(dist[3] > dist[0]);
    }

    #[test]
    fn observe_updates() {
        let mut d = BigramDraft::from_sequence(&[MASK, MASK], 3);
        for _ in 0..50 {
            d.observe(1, 2);
        }
        let dist = d.dist(Some(1));
        assert!(dist[2] > 0.9);
    }

    #[test]
    fn dist_always_positive_everywhere() {
        let d = BigramDraft::from_sequence(&[0, 1], 6);
        for prev in [None, Some(0), Some(5)] {
            let dist = d.dist(prev);
            assert!(dist.iter().all(|&x| x > 0.0));
        }
    }

    /// Property: after ANY mix of from_sequence / observe / observe_unigram
    /// updates, every dist row is a probability vector — sums to 1, zero
    /// exactly on in-range specials, and the Laplace smoothing never leaves
    /// a zero at a regular token.
    #[test]
    fn prop_dist_is_normalized_with_full_support() {
        propcheck::check_no_shrink(
            31,
            150,
            |r: &mut Rng| {
                let vocab = r.range(3, 300);
                let tok_max = vocab.min(256);
                let len = r.below(12);
                let seq: Vec<u32> = (0..len)
                    .map(|_| {
                        if r.below(4) == 0 {
                            MASK
                        } else {
                            r.below(tok_max) as u32
                        }
                    })
                    .collect();
                let obs: Vec<(u32, u32)> = (0..r.below(20))
                    .map(|_| (r.below(tok_max) as u32, r.below(tok_max) as u32))
                    .collect();
                let queries: Vec<Option<u32>> = (0..4)
                    .map(|q| {
                        if q == 0 {
                            None
                        } else {
                            Some(r.below(tok_max) as u32)
                        }
                    })
                    .collect();
                (vocab, seq, obs, queries)
            },
            |(vocab, seq, obs, queries)| {
                let v = *vocab;
                let mut d = BigramDraft::from_sequence(seq, v);
                for &(a, b) in obs {
                    d.observe(a, b);
                    d.observe_unigram(b);
                }
                for &prev in queries {
                    let dist = d.dist(prev);
                    if dist.len() != v {
                        return Err(format!("dist len {} != vocab {v}", dist.len()));
                    }
                    let sum: f32 = dist.iter().sum();
                    if (sum - 1.0).abs() > 1e-4 {
                        return Err(format!("dist sums to {sum}"));
                    }
                    for (t, &p) in dist.iter().enumerate() {
                        let special = t as u32 == MASK || t as u32 == PAD;
                        if special && p != 0.0 {
                            return Err(format!("special {t} has mass {p}"));
                        }
                        if !special && p <= 0.0 {
                            return Err(format!("smoothing left zero mass at token {t}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: observe(prev, next) strictly raises next's conditional
    /// mass given prev relative to every other token's.
    #[test]
    fn prop_observe_concentrates_mass() {
        propcheck::check_no_shrink(
            32,
            100,
            |r: &mut Rng| {
                let vocab = r.range(4, 40);
                let prev = r.below(vocab) as u32;
                let next = r.below(vocab) as u32;
                let reps = r.range(5, 60);
                (vocab, prev, next, reps)
            },
            |&(vocab, prev, next, reps)| {
                let mut d = BigramDraft::from_sequence(&[], vocab);
                for _ in 0..reps {
                    d.observe(prev, next);
                }
                let dist = d.dist(Some(prev));
                for (t, &p) in dist.iter().enumerate() {
                    if t as u32 != next && p >= dist[next as usize] {
                        return Err(format!(
                            "token {t} mass {p} >= observed next {} mass {}",
                            next, dist[next as usize]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn drafter_reports_name_and_books_no_model_forward() {
        let d = BigramDrafter::from_sequence(&[0, 1, MASK], 8);
        assert_eq!(d.name(), "bigram");
        assert!(!d.needs_model_forward());
        assert!(!d.lemma1_exact());
    }
}
