//! Data substrates: corpus generation, sequence packing, masking/ordering
//! distributions.
//!
//! * [`stories`] — deterministic synthetic story/prose corpora (the
//!   offline ROCStories substitute)
//! * [`masking`] — the training-time distributions of the paper: mask
//!   rate m ~ f(·), generation order sigma ~ s(·|m) under the lattice or
//!   permutation protocol, and prompt-length sampling
//!
//! Plus [`pack_chunks`]/[`split_chunks`]: document packing into
//! fixed-length token chunks and the deterministic train/val split.

pub mod masking;
pub mod stories;

use crate::tokenizer::{ByteTokenizer, PAD};
use crate::util::rng::Rng;

/// Pack documents into fixed-length token chunks with a separator byte
/// ('\n' = 10) delineating document starts (paper App. D.1's packing,
/// byte-level). Chunks shorter than `len` at the tail are PAD-filled.
pub fn pack_chunks(docs: &[String], len: usize) -> Vec<Vec<u32>> {
    let tok = ByteTokenizer::new();
    let mut stream: Vec<u32> = vec![];
    for d in docs {
        stream.extend(tok.encode(d));
        stream.push(10); // '\n' document separator
    }
    let mut out = vec![];
    for chunk in stream.chunks(len) {
        let mut c = chunk.to_vec();
        while c.len() < len {
            c.push(PAD);
        }
        out.push(c);
    }
    out
}

/// Train/validation split of packed chunks (deterministic shuffle).
pub fn split_chunks(
    mut chunks: Vec<Vec<u32>>,
    val_frac: f64,
    seed: u64,
) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut chunks);
    let n_val = ((chunks.len() as f64) * val_frac).round() as usize;
    let val = chunks.split_off(chunks.len() - n_val.min(chunks.len()));
    (chunks, val)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_covers_all_bytes() {
        let docs = vec!["hello".to_string(), "world!".to_string()];
        let chunks = pack_chunks(&docs, 8);
        let total: usize = docs.iter().map(|d| d.len() + 1).sum();
        assert_eq!(chunks.len(), total.div_ceil(8));
        for c in &chunks {
            assert_eq!(c.len(), 8);
        }
        // First chunk starts with 'h'
        assert_eq!(chunks[0][0], b'h' as u32);
    }

    #[test]
    fn packing_pads_tail() {
        let chunks = pack_chunks(&["ab".to_string()], 8);
        assert_eq!(chunks.len(), 1);
        assert_eq!(&chunks[0][..3], &[97, 98, 10]);
        assert!(chunks[0][3..].iter().all(|&t| t == PAD));
    }

    #[test]
    fn split_is_deterministic_and_partitions() {
        let chunks: Vec<Vec<u32>> = (0..100).map(|i| vec![i as u32; 4]).collect();
        let (tr1, va1) = split_chunks(chunks.clone(), 0.2, 5);
        let (tr2, va2) = split_chunks(chunks.clone(), 0.2, 5);
        assert_eq!(tr1, tr2);
        assert_eq!(va1, va2);
        assert_eq!(tr1.len(), 80);
        assert_eq!(va1.len(), 20);
        let mut all: Vec<_> = tr1.into_iter().chain(va1).collect();
        all.sort();
        let mut orig = chunks;
        orig.sort();
        assert_eq!(all, orig);
    }
}
