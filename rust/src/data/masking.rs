//! Masking distributions f(·) and ordering samplers s(·|m) (paper §6.2,
//! App. D.2), plus the binary-lattice decomposition (Eq. 4).
//!
//! These drive BOTH training (the rust trainer samples (m, sigma) per
//! sequence and hands verify-mode masks to the train_step artifact) and
//! evaluation workload generation (e.g. Table 1's "95% masked").

use crate::util::rng::Rng;

/// Prompt-length distribution f(·): prompt fraction uniform in
/// [lo_frac, hi_frac] of the sequence. The paper's main model uses
/// U[0.01, 0.10] ("wide masking", i.e. 90–99% masked); the OTS-style
/// model uses U[0.80, 0.85] prompts (≈15–20% masked, XLNet pretraining).
#[derive(Clone, Copy, Debug)]
pub struct PromptDist {
    pub lo_frac: f64,
    pub hi_frac: f64,
}

impl PromptDist {
    pub fn new(lo_frac: f64, hi_frac: f64) -> Self {
        assert!(0.0 <= lo_frac && lo_frac <= hi_frac && hi_frac <= 1.0);
        PromptDist { lo_frac, hi_frac }
    }

    /// Paper App. D.2: the finetuned ("FT") model, 1–10% prompt.
    pub fn narrow() -> Self {
        PromptDist::new(0.01, 0.10)
    }

    /// Fig. 4 ablation: 1–85% prompt ("wide").
    pub fn wide() -> Self {
        PromptDist::new(0.01, 0.85)
    }

    /// XLNet-pretraining-like (the "OTS" model): ~80–85% visible.
    pub fn ots() -> Self {
        PromptDist::new(0.80, 0.85)
    }

    /// Sample a prompt length m in [1, n-1] (always at least one prompt
    /// token and one target).
    pub fn sample(&self, rng: &mut Rng, n: usize) -> usize {
        let f = self.lo_frac + rng.f64() * (self.hi_frac - self.lo_frac);
        ((f * n as f64).round() as usize).clamp(1, n - 1)
    }

    /// Low-discrepancy in-batch sampling (paper App. D.2 / [Sah+24]):
    /// stratify the batch across the [lo, hi] range so each batch sees a
    /// spread of masking rates instead of i.i.d. clumps.
    pub fn sample_batch(&self, rng: &mut Rng, n: usize, batch: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(batch);
        let u0 = rng.f64();
        for i in 0..batch {
            // shifted stratified samples: (i + u0) / batch covers [0,1)
            let u = (i as f64 + u0) / batch as f64;
            let f = self.lo_frac + u * (self.hi_frac - self.lo_frac);
            out.push(((f * n as f64).round() as usize).clamp(1, n - 1));
        }
        // Shuffle so slot index doesn't correlate with masking rate.
        rng.shuffle(&mut out);
        out
    }
}

/// Ordering protocol s(·|m).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderProtocol {
    /// Binary-lattice decomposition (Eq. 4): sorted prompt positions, then
    /// sorted target positions. 2^N queries instead of N!.
    Lattice,
    /// Unrestricted permutation (the Fig. 3 ablation baseline).
    Permutation,
}

/// Sample (sigma, m): choose m ~ f, choose the visible set uniformly, then
/// order per the protocol. Returns sigma (order index -> position).
pub fn sample_sigma(
    rng: &mut Rng,
    n: usize,
    m: usize,
    protocol: OrderProtocol,
) -> Vec<usize> {
    match protocol {
        OrderProtocol::Lattice => {
            let vis = rng.choose_sorted(n, m);
            lattice_sigma(&vis, n)
        }
        OrderProtocol::Permutation => {
            let mut sigma: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut sigma);
            sigma
        }
    }
}

/// Binary-lattice sigma from a visible set: sorted(visible) ++ sorted(rest).
pub fn lattice_sigma(visible: &[usize], n: usize) -> Vec<usize> {
    debug_assert!(visible.windows(2).all(|w| w[0] < w[1]), "visible must be sorted");
    let mut in_vis = vec![false; n];
    for &p in visible {
        in_vis[p] = true;
    }
    let mut sigma = Vec::with_capacity(n);
    sigma.extend_from_slice(visible);
    sigma.extend((0..n).filter(|&p| !in_vis[p]));
    sigma
}

/// Inverse of sigma: position -> order index.
pub fn order_of(sigma: &[usize]) -> Vec<usize> {
    let mut order = vec![0usize; sigma.len()];
    for (i, &pos) in sigma.iter().enumerate() {
        order[pos] = i;
    }
    order
}

/// Masking-rate schedule for training (paper App. D.3: "start at 15%
/// masking, linearly increase the minimum to 90% and the maximum to 99%
/// over 5000 steps"). Expressed over prompt fractions: start with a high
/// prompt fraction and anneal down to [1-hi_mask, 1-lo_mask].
#[derive(Clone, Copy, Debug)]
pub struct MaskRateSchedule {
    pub start_prompt: f64,   // initial prompt fraction (e.g. 0.85 = 15% masked)
    pub final_lo: f64,       // final lo prompt fraction (e.g. 0.01 = 99% masked)
    pub final_hi: f64,       // final hi prompt fraction (e.g. 0.10 = 90% masked)
    pub warmup_steps: usize, // anneal duration
}

impl MaskRateSchedule {
    pub fn paper_default() -> Self {
        MaskRateSchedule {
            start_prompt: 0.85,
            final_lo: 0.01,
            final_hi: 0.10,
            warmup_steps: 500,
        }
    }

    /// The PromptDist at a given step.
    pub fn at(&self, step: usize) -> PromptDist {
        let t = (step as f64 / self.warmup_steps as f64).min(1.0);
        let lo = self.start_prompt + t * (self.final_lo - self.start_prompt);
        let hi = self.start_prompt + t * (self.final_hi - self.start_prompt);
        PromptDist::new(lo.min(hi), lo.max(hi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn prompt_dist_in_range() {
        let d = PromptDist::narrow();
        let mut rng = Rng::new(0);
        for _ in 0..1000 {
            let m = d.sample(&mut rng, 128);
            assert!((1..=13).contains(&m), "m={m}");
        }
    }

    #[test]
    fn low_discrepancy_covers_range() {
        let d = PromptDist::new(0.1, 0.9);
        let mut rng = Rng::new(1);
        let ms = d.sample_batch(&mut rng, 100, 8);
        assert_eq!(ms.len(), 8);
        let lo = *ms.iter().min().unwrap();
        let hi = *ms.iter().max().unwrap();
        // stratification guarantees spread
        assert!(lo < 30, "lo={lo}");
        assert!(hi > 70, "hi={hi}");
    }

    #[test]
    fn lattice_sigma_structure() {
        let sigma = lattice_sigma(&[2, 5, 7], 9);
        assert_eq!(sigma, vec![2, 5, 7, 0, 1, 3, 4, 6, 8]);
        let order = order_of(&sigma);
        assert_eq!(order[2], 0);
        assert_eq!(order[8], 8);
    }

    #[test]
    fn prop_sigma_is_bijection_lattice_sorted() {
        propcheck::check_no_shrink(
            42,
            200,
            |r: &mut Rng| {
                let n = r.range(2, 40);
                let m = r.range(1, n);
                let sigma = sample_sigma(r, n, m, OrderProtocol::Lattice);
                (n, m, sigma)
            },
            |(n, m, sigma)| {
                let mut sorted = sigma.clone();
                sorted.sort_unstable();
                if sorted != (0..*n).collect::<Vec<_>>() {
                    return Err("not a bijection".into());
                }
                if !sigma[..*m].windows(2).all(|w| w[0] < w[1]) {
                    return Err("prompt not sorted".into());
                }
                if !sigma[*m..].windows(2).all(|w| w[0] < w[1]) {
                    return Err("targets not sorted (Eq. 4 violated)".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_permutation_is_bijection() {
        propcheck::check_no_shrink(
            43,
            200,
            |r: &mut Rng| {
                let n = r.range(2, 40);
                sample_sigma(r, n, 1, OrderProtocol::Permutation)
            },
            |sigma| {
                let mut sorted = sigma.clone();
                sorted.sort_unstable();
                if sorted == (0..sigma.len()).collect::<Vec<_>>() {
                    Ok(())
                } else {
                    Err("not a bijection".into())
                }
            },
        );
    }

    #[test]
    fn schedule_anneals() {
        let s = MaskRateSchedule::paper_default();
        let d0 = s.at(0);
        assert!((d0.lo_frac - 0.85).abs() < 1e-9);
        let dend = s.at(10_000);
        assert!((dend.lo_frac - 0.01).abs() < 1e-9);
        assert!((dend.hi_frac - 0.10).abs() < 1e-9);
        // midpoint is between
        let dm = s.at(250);
        assert!(dm.lo_frac < 0.85 && dm.hi_frac > 0.10);
    }
}
