//! Synthetic story corpus (ROCStories substitute — docs/ARCHITECTURE.md).
//!
//! A templated probabilistic grammar that emits five-sentence stories with
//! consistent protagonists and a simple narrative arc (setup, goal, action,
//! complication, resolution). The distribution is rich enough that the
//! 0.9M-param AS-ARM has something real to learn, and regular enough that
//! infilling the middle sentence(s) is measurably improvable by context
//! (which is what Table 2 needs).

use crate::util::rng::Rng;

const NAMES: &[&str] = &[
    "Tom", "Ana", "Ben", "Mia", "Sam", "Lily", "Max", "Ivy", "Leo", "Zoe",
];
const PLACES: &[&str] = &[
    "the park", "the store", "the lake", "school", "the farm", "the beach", "the library",
    "the market",
];
const OBJECTS: &[&str] = &[
    "a kite", "a book", "an apple", "a map", "a coin", "a hat", "a ball", "a cake",
];
const FEELINGS: &[&str] = &["happy", "proud", "tired", "glad", "calm", "excited"];
const PROBLEMS: &[&str] = &[
    "it started to rain",
    "the wind picked up",
    "the sun went down",
    "a dog ran by",
    "the bag ripped",
    "the road was closed",
];

/// One five-sentence story. Sentences end with ". " except the last ("." only).
pub fn story(rng: &mut Rng) -> Vec<String> {
    let name = NAMES[rng.below(NAMES.len())];
    let place = PLACES[rng.below(PLACES.len())];
    let object = OBJECTS[rng.below(OBJECTS.len())];
    let feeling = FEELINGS[rng.below(FEELINGS.len())];
    let problem = PROBLEMS[rng.below(PROBLEMS.len())];
    let friend = NAMES[rng.below(NAMES.len())];

    let s1 = match rng.below(3) {
        0 => format!("{name} went to {place}."),
        1 => format!("One day {name} walked to {place}."),
        _ => format!("{name} woke up early."),
    };
    let s2 = match rng.below(3) {
        0 => format!("{name} wanted {object}."),
        1 => format!("{name} saw {object} there."),
        _ => format!("{name} met {friend} at {place}."),
    };
    let s3 = match rng.below(3) {
        0 => format!("They looked for {object} together."),
        1 => format!("{name} picked up {object}."),
        _ => format!("{name} played with {object} for hours."),
    };
    let s4 = match rng.below(3) {
        0 => format!("Then {problem}."),
        1 => format!("Suddenly {problem}."),
        _ => format!("But then {problem}."),
    };
    let s5 = match rng.below(3) {
        0 => format!("{name} felt {feeling} at the end."),
        1 => format!("In the end {name} was {feeling}."),
        _ => format!("{name} went home {feeling}."),
    };
    vec![s1, s2, s3, s4, s5]
}

/// Full story as one string.
pub fn story_text(rng: &mut Rng) -> String {
    story(rng).join(" ")
}

/// A corpus of `n` stories.
pub fn corpus(seed: u64, n: usize) -> Vec<String> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| story_text(&mut rng)).collect()
}

/// General filler prose (WikiText substitute) — story sentences drawn
/// independently, so the text is locally coherent English-like bytes.
pub fn prose(rng: &mut Rng, approx_len: usize) -> String {
    let mut out = String::new();
    while out.len() < approx_len {
        out.push_str(&story_text(rng));
        out.push(' ');
    }
    out.truncate(approx_len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn story_has_five_sentences() {
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let s = story(&mut rng);
            assert_eq!(s.len(), 5);
            for sent in &s {
                assert!(sent.ends_with('.'), "{sent}");
                assert!(!sent.is_empty());
            }
        }
    }

    #[test]
    fn story_fits_model_window() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let t = story_text(&mut rng);
            assert!(t.len() <= 160, "story too long ({}): {t}", t.len());
        }
    }

    #[test]
    fn corpus_is_deterministic() {
        assert_eq!(corpus(7, 5), corpus(7, 5));
        assert_ne!(corpus(7, 5), corpus(8, 5));
    }

    #[test]
    fn prose_has_requested_length() {
        let mut rng = Rng::new(2);
        let p = prose(&mut rng, 500);
        assert_eq!(p.len(), 500);
    }

    #[test]
    fn stories_vary() {
        let c = corpus(3, 100);
        let distinct: std::collections::HashSet<_> = c.iter().collect();
        assert!(distinct.len() > 90, "only {} distinct stories", distinct.len());
    }
}
