//! Perf bench (paged KV & prefix cache): slab-vs-paged memory model,
//! warm-vs-cold first-iteration cost (the TTFT proxy — the warm lane
//! seeds from the prefix cache and skips the N² prefill), and the
//! cross-request hit-rate sweep. Hermetic: the analytic MockEngine is
//! the measurement substrate, so `make bench-smoke` and CI run it with
//! no artifacts. Feeds docs/ARCHITECTURE.md §Paged KV & prefix cache.
//!
//! Run: `cargo bench --bench perf_paged`. Writes BENCH_paged.json and
//! FAILS (non-zero exit — the CI regression gate) if warm decode output
//! diverges from cold, if the warm first iteration does not beat the
//! cold one on modeled device compute, if a repeated prompt fails to
//! hit the cache, if the pool's measured peak footprint exceeds the
//! per-lane slab layout it replaced, or if resuming a preempted
//! (checkpointed + parked) request on the engine that sealed its prefix
//! does not beat a cold restore's full re-prefill.

use anyhow::{bail, Result};

use asarm::coordinator::SamplerKind;
use asarm::decode::snapshot::restore;
use asarm::decode::DecodeMachine;
use asarm::draft::{DraftKind, DraftOptions};
use asarm::eval::harness::{build_machine, masked_prose_workload, WorkItem};
use asarm::obs::{chrome, tap, Rung, SpanKind, TraceBuilder, DEFAULT_SPAN_CAP};
use asarm::runtime::mock::MockEngine;
use asarm::runtime::{Engine, IncSpec, PagedKvConfig};
use asarm::util::bench::Table;
use asarm::util::json::Json;

const N: usize = 128;
const V: usize = 258;
/// Byte model for one cached K/V row at deployment scale: K + V across
/// L = 4 layers of D = 128 floats (the same stand-ins as perf_engine's
/// byte model — the mock itself stores one token per row).
const ROW_BYTES: u64 = 2 * 4 * 128 * 4;

fn opts() -> DraftOptions {
    DraftOptions {
        kind: DraftKind::SelfModel,
        max_len: 5,
        adaptive: false,
    }
}

fn prose_item(seed: u64) -> WorkItem {
    masked_prose_workload(N, 1, 0.5, seed).remove(0)
}

/// Drive one request end-to-end through the incremental path on lane 0
/// (reset — i.e. retire-and-seal — afterwards, like the scheduler).
/// Returns (first-call modeled-cells delta, final tokens) and folds the
/// pool's free-block low-water mark into `min_free`.
fn drive_inc(
    engine: &MockEngine,
    item: &WorkItem,
    seed: u64,
    min_free: &mut usize,
) -> Result<(u64, Vec<u32>)> {
    let lane = 0;
    engine.reset_lane(lane);
    let mut machine = build_machine(engine, item, SamplerKind::Assd, opts(), 8, 1.0, seed);
    let mut first = None;
    while !machine.done() {
        let committed = machine.incremental();
        let before = engine.modeled_cells();
        let rows = {
            let req = machine
                .forward_request()
                .expect("machine not done but no request");
            let mut out = match committed {
                Some(committed) => engine.forward_inc(&[IncSpec {
                    spec: req,
                    committed,
                    lane,
                }])?,
                None => engine.forward_ord(std::slice::from_ref(&req))?,
            };
            out.pop().expect("engine returned no row batch")
        };
        machine.absorb(&rows);
        first.get_or_insert(engine.modeled_cells() - before);
        let s = engine.kv_stats().expect("mock engine is paged");
        *min_free = (*min_free).min(s.free_blocks);
    }
    engine.reset_lane(lane);
    Ok((first.unwrap_or(0), machine.outcome().tokens))
}

/// Drive an already-built machine (e.g. one restored from a
/// [`DecodeSnapshot`](asarm::decode::snapshot::DecodeSnapshot)) to
/// completion on `lane`, returning (first-call modeled-cells delta,
/// final tokens). The first call is the resume cost: a lane whose
/// committed prefix is still sealed in the engine's prefix cache seeds
/// from it, a cold engine pays the full re-prefill.
fn drive_machine(
    engine: &MockEngine,
    mut machine: Box<dyn DecodeMachine>,
    lane: usize,
) -> Result<(u64, Vec<u32>)> {
    let mut first = None;
    while !machine.done() {
        let committed = machine.incremental();
        let before = engine.modeled_cells();
        let rows = {
            let req = machine
                .forward_request()
                .expect("machine not done but no request");
            let mut out = match committed {
                Some(committed) => engine.forward_inc(&[IncSpec {
                    spec: req,
                    committed,
                    lane,
                }])?,
                None => engine.forward_ord(std::slice::from_ref(&req))?,
            };
            out.pop().expect("engine returned no row batch")
        };
        machine.absorb(&rows);
        first.get_or_insert(engine.modeled_cells() - before);
    }
    engine.reset_lane(lane);
    Ok((first.unwrap_or(0), machine.outcome().tokens))
}

fn main() -> Result<()> {
    let out_path =
        std::env::var("ASARM_BENCH_PAGED_OUT").unwrap_or_else(|_| "BENCH_paged.json".to_string());

    // --- warm vs cold: first-iteration modeled compute (TTFT proxy) ---
    // Same request twice on one engine with the DEFAULT pool: the cold
    // run pays the N² prefill in its first call; the warm run's lane
    // seeds from the prefix the cold retirement sealed and must not.
    let default_pool = PagedKvConfig::for_seq_len(N);
    let e = MockEngine::new(9, N, V, 1.0);
    let item = prose_item(41);
    let mut min_free = usize::MAX;
    let (cold_first, cold_toks) = drive_inc(&e, &item, 4242, &mut min_free)?;
    let (warm_first, warm_toks) = drive_inc(&e, &item, 4242, &mut min_free)?;
    if warm_toks != cold_toks {
        bail!("warm decode diverged from cold — the prefix cache changed sampled bits");
    }
    let s = e.kv_stats().expect("mock engine is paged");
    if s.prefix_hits < 1 {
        bail!("warm request never hit the prefix cache — nothing was measured");
    }
    if warm_first >= cold_first {
        bail!(
            "warm-TTFT regression gate: warm first iteration {warm_first} cells >= cold \
             {cold_first} — prefix seeding is not skipping prefill"
        );
    }
    let ttft_speedup = cold_first as f64 / warm_first.max(1) as f64;

    // --- memory model: slab layout vs paged pool -----------------------
    // The slab layout this pool replaced kept one full-window K/V slab
    // permanently resident per lane; sized for the same 8-worst-case-lane
    // capability as the default pool. The pool's worst-case bound is the
    // same — the win is that PEAK USE tracks live occupancy + cached
    // prefixes instead of provisioned capacity.
    let slab_lanes = 8u64;
    let slab_bytes = slab_lanes * N as u64 * ROW_BYTES;
    let pool_bound_bytes =
        (default_pool.total_blocks * default_pool.block_rows) as u64 * ROW_BYTES;
    let peak_blocks = s.total_blocks - min_free.min(s.total_blocks);
    let peak_bytes = (peak_blocks * default_pool.block_rows) as u64 * ROW_BYTES;
    if peak_bytes > slab_bytes {
        bail!(
            "paged peak footprint {peak_bytes} B exceeds the {slab_bytes} B slab layout it \
             replaced"
        );
    }

    // --- hit-rate sweep: distinct prompts rotating through one pool ----
    // The pool caches ~4 sealed prefixes; rotating more distinct prompts
    // than that forces LRU eviction and the hit rate collapses — the
    // sweep maps reuse locality to observed hit rate.
    let mut sweep = vec![];
    let mut sweep_table = Table::new(&["distinct", "requests", "hits", "misses", "rate", "evict"]);
    let requests = 16usize;
    for &distinct in &[1usize, 2, 4, 8] {
        let pool = PagedKvConfig {
            block_rows: 16,
            total_blocks: 4 * N.div_ceil(16),
        };
        let e = MockEngine::with_pool(5, N, V, 1.0, pool);
        let items: Vec<WorkItem> = (0..distinct)
            .map(|i| prose_item(100 + i as u64))
            .collect();
        let mut mf = usize::MAX;
        for r in 0..requests {
            drive_inc(&e, &items[r % distinct], 7000 + r as u64, &mut mf)?;
        }
        let s = e.kv_stats().expect("mock engine is paged");
        let looked = s.prefix_hits + s.prefix_misses;
        let hit_rate = s.prefix_hits as f64 / (looked.max(1)) as f64;
        if distinct == 1 && hit_rate < 0.9 {
            bail!(
                "hit-rate gate: a single repeated prompt only hit {:.0}% of the time",
                100.0 * hit_rate
            );
        }
        sweep_table.row(&[
            format!("{distinct}"),
            format!("{requests}"),
            format!("{}", s.prefix_hits),
            format!("{}", s.prefix_misses),
            format!("{hit_rate:.2}"),
            format!("{}", s.evictions),
        ]);
        sweep.push(Json::obj(vec![
            ("distinct_prompts", Json::num(distinct as f64)),
            ("requests", Json::num(requests as f64)),
            ("prefix_hits", Json::num(s.prefix_hits as f64)),
            ("prefix_misses", Json::num(s.prefix_misses as f64)),
            ("hit_rate", Json::num(hit_rate)),
            ("evictions", Json::num(s.evictions as f64)),
            ("cow_copies", Json::num(s.cow_copies as f64)),
        ]));
    }

    // --- preempt → resume: warm park vs cold re-prefill ----------------
    // The scheduler's preemption path in miniature: drive a request
    // partway, checkpoint it, and seal its lane back into the prefix
    // cache (exactly what `park_slot` does). Resuming on the SAME engine
    // must seed from the sealed prefix and beat a cold restore on a
    // fresh engine — which pays the full committed-prefix re-prefill —
    // on first-iteration modeled compute. Both resumes must reproduce
    // the uninterrupted run's tokens bit-for-bit.
    let e_park = MockEngine::new(9, N, V, 1.0);
    let item = prose_item(47);
    let park_at = item.ord.m + 16; // park with 16 tokens committed
    let lane = 0;
    e_park.reset_lane(lane);
    let mut machine = build_machine(&e_park, &item, SamplerKind::Assd, opts(), 8, 1.0, 4747);
    loop {
        let committed = machine.incremental();
        if machine.done() || committed.is_some_and(|c| c >= park_at) {
            break;
        }
        let rows = {
            let req = machine
                .forward_request()
                .expect("machine not done but no request");
            let mut out = match committed {
                Some(committed) => e_park.forward_inc(&[IncSpec {
                    spec: req,
                    committed,
                    lane,
                }])?,
                None => e_park.forward_ord(std::slice::from_ref(&req))?,
            };
            out.pop().expect("engine returned no row batch")
        };
        machine.absorb(&rows);
    }
    if machine.done() {
        bail!("preempt-resume leg: request finished before the park point — nothing to resume");
    }
    let parked_rows = machine.incremental().expect("assd is incremental");
    let warm_snap = machine.checkpoint().expect("assd machines must checkpoint");
    let cold_snap = machine.checkpoint().expect("assd machines must checkpoint");
    drop(machine);
    e_park.reset_lane(lane); // park: seal the committed prefix

    let hits_before = e_park.kv_stats().expect("mock engine is paged").prefix_hits;
    let (warm_resume_first, warm_resume_toks) = drive_machine(&e_park, restore(warm_snap), lane)?;
    let hits_after = e_park.kv_stats().expect("mock engine is paged").prefix_hits;
    let e_cold = MockEngine::new(9, N, V, 1.0);
    let (cold_resume_first, cold_resume_toks) = drive_machine(&e_cold, restore(cold_snap), lane)?;

    // Uninterrupted baseline on its own engine (so neither resume's
    // prefix cache is perturbed).
    let e_base = MockEngine::new(9, N, V, 1.0);
    let mut mf_base = usize::MAX;
    let (_, base_toks) = drive_inc(&e_base, &item, 4747, &mut mf_base)?;
    if warm_resume_toks != base_toks || cold_resume_toks != base_toks {
        bail!("preempt-resume gate: resumed decode diverged from the uninterrupted run");
    }
    if hits_after <= hits_before {
        bail!(
            "preempt-resume gate: warm resume never hit the sealed prefix — nothing was measured"
        );
    }
    if warm_resume_first >= cold_resume_first {
        bail!(
            "preempt-resume gate: warm resume first iteration {warm_resume_first} cells >= cold \
             restore {cold_resume_first} — parking is not sealing the committed prefix"
        );
    }
    let resume_speedup = cold_resume_first as f64 / warm_resume_first.max(1) as f64;

    let report = Json::obj(vec![
        ("engine", Json::str("mock")),
        ("provenance", Json::str("measured (make bench-smoke)")),
        ("seq_len", Json::num(N as f64)),
        ("vocab", Json::num(V as f64)),
        ("row_bytes_modeled", Json::num(ROW_BYTES as f64)),
        ("outputs_identical", Json::Bool(true)),
        (
            "ttft",
            Json::obj(vec![
                ("cold_first_iter_cells", Json::num(cold_first as f64)),
                ("warm_first_iter_cells", Json::num(warm_first as f64)),
                ("speedup_warm_over_cold", Json::num(ttft_speedup)),
            ]),
        ),
        (
            "memory",
            Json::obj(vec![
                ("slab_bytes", Json::num(slab_bytes as f64)),
                ("paged_pool_bound_bytes", Json::num(pool_bound_bytes as f64)),
                ("paged_peak_bytes", Json::num(peak_bytes as f64)),
                (
                    "peak_utilization_vs_slab",
                    Json::num(peak_bytes as f64 / slab_bytes as f64),
                ),
            ]),
        ),
        (
            "preempt_resume",
            Json::obj(vec![
                ("committed_rows_at_park", Json::num(parked_rows as f64)),
                (
                    "warm_resume_first_iter_cells",
                    Json::num(warm_resume_first as f64),
                ),
                (
                    "cold_restore_first_iter_cells",
                    Json::num(cold_resume_first as f64),
                ),
                ("speedup_warm_over_cold", Json::num(resume_speedup)),
                ("outputs_identical", Json::Bool(true)),
            ]),
        ),
        ("hit_rate_sweep", Json::Arr(sweep)),
    ]);
    std::fs::write(&out_path, report.to_string())?;
    eprintln!("perf_paged: wrote {out_path}");

    println!("\n=== perf_paged: warm vs cold first iteration (TTFT proxy) ===");
    println!(
        "cold {cold_first} cells, warm {warm_first} cells ({ttft_speedup:.1}x — the warm lane \
         skipped the N² prefill), outputs identical: true"
    );
    println!("\n=== perf_paged: memory model (ROW_BYTES = {ROW_BYTES} B) ===");
    println!(
        "slab layout {slab_bytes} B, pool bound {pool_bound_bytes} B, measured peak {peak_bytes} \
         B ({:.0}% of slab)",
        100.0 * peak_bytes as f64 / slab_bytes as f64
    );
    println!("\n=== perf_paged: preempt → resume (warm park vs cold re-prefill) ===");
    println!(
        "parked at {parked_rows} committed rows; warm resume {warm_resume_first} cells, cold \
         restore {cold_resume_first} cells ({resume_speedup:.1}x — the sealed prefix skipped \
         re-prefill), outputs identical: true"
    );
    println!("\n=== perf_paged: prefix-cache hit-rate sweep ===");
    sweep_table.print();

    // --- sample trace artifact: one warm request's span timeline -------
    // Hand-built TraceBuilder around the same drive loop (no scheduler
    // in this bench): forward/decode/commit spans per iteration with the
    // actual kernel rung and prefix-probe attribution from the
    // thread-local taps — the same Chrome trace-event shape
    // GET /trace/{id} serves from the coordinator.
    let trace_path = std::env::var("ASARM_TRACE_PAGED_OUT")
        .unwrap_or_else(|_| "TRACE_paged.json".to_string());
    let e = MockEngine::new(9, N, V, 1.0);
    let item = prose_item(43);
    let mut mf = usize::MAX;
    // First drive seals the prefix so the traced re-run records a hit.
    drive_inc(&e, &item, 4400, &mut mf)?;
    let lane = 0;
    e.reset_lane(lane);
    tap::reset();
    let mut tb = TraceBuilder::new(0, 0, "assd", std::time::Instant::now(), DEFAULT_SPAN_CAP);
    let mut machine = build_machine(&e, &item, SamplerKind::Assd, opts(), 8, 1.0, 4400);
    let mut iter = 0u32;
    while !machine.done() {
        let committed = machine.incremental();
        let t_fwd = tb.now_us();
        let rows = {
            let req = machine
                .forward_request()
                .expect("machine not done but no request");
            let mut out = match committed {
                Some(committed) => e.forward_inc(&[IncSpec {
                    spec: req,
                    committed,
                    lane,
                }])?,
                None => e.forward_ord(std::slice::from_ref(&req))?,
            };
            out.pop().expect("engine returned no row batch")
        };
        let rung = tap::take_rung().unwrap_or(Rung::Dense);
        let mut probes = Vec::new();
        tap::take_prefix_probes(&mut probes);
        for (_lane, hit) in probes {
            tb.note_prefix_probe(hit);
        }
        tb.note_rung(rung);
        tb.push(SpanKind::Forward, iter, t_fwd, rung as u64, 1);
        let t_dec = tb.now_us();
        machine.absorb(&rows);
        tb.push(SpanKind::Decode, iter, t_dec, 0, 0);
        let t_commit = tb.now_us();
        let commits = machine.drain_commits();
        if !commits.is_empty() {
            tb.push(SpanKind::Commit, iter, t_commit, commits.len() as u64, 0);
            tb.add_commits(commits.len());
        }
        iter += 1;
    }
    let s = machine.iter_stats();
    e.reset_lane(lane);
    let trace = tb.finish(
        true,
        s.model_nfe,
        s.aux_nfe,
        s.iterations,
        s.proposed,
        s.accepted,
        "self".to_string(),
    );
    if trace.prefix_hits < 1 {
        bail!("traced warm re-run never hit the prefix cache — probe attribution is broken");
    }
    if !trace.theorem2_ok {
        bail!(
            "traced request violated Theorem 2: {} model NFE > {} tokens committed",
            trace.model_nfe,
            trace.tokens_committed
        );
    }
    std::fs::write(&trace_path, chrome::trace_json(&trace).to_string())?;
    eprintln!("perf_paged: wrote {trace_path} (load into chrome://tracing)");
    Ok(())
}
